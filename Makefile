# Convenience targets — CI (.github/workflows/ci.yml) runs exactly these.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint docs coverage bench-quick bench bench-json mpi-demo chaos-demo serve-demo install-dev

test:
	$(PYTHON) -m pytest -x -q

# statement-coverage floor over src/repro. Uses pytest-cov when installed
# (CI's coverage job); otherwise falls back to the stdlib tracer plugin
# tools/coverage_lite.py so hermetic containers still enforce the floor.
# COV_MIN is pinned a few points under the measured seed level — raise it
# as the suite grows, never lower it.
COV_MIN ?= 80
coverage:
	@if $(PYTHON) -c "import pytest_cov" 2>/dev/null; then \
		$(PYTHON) -m pytest -q --cov=repro --cov-fail-under=$(COV_MIN); \
	else \
		echo "pytest-cov not installed — using tools/coverage_lite.py"; \
		COVLITE_MIN=$(COV_MIN) PYTHONPATH=src:. $(PYTHON) -m pytest -q -p tools.coverage_lite; \
	fi

# ruff (config in pyproject.toml); CI's lint job runs exactly this
lint:
	$(PYTHON) -m ruff check src/repro/core src/repro/mpi src/repro/serve tests benchmarks examples

# docs site link-check (README + docs/); CI's docs job runs exactly this
docs:
	$(PYTHON) tools/check_links.py

# fast, pure-python benchmark smoke: repair-time (incl. substitution) + the
# background-repair overlap proof + Eq. 3/4 + N-level scoped-repair scaling
# + MPI-facade transparency overhead + the correlated-failure invariant
# matrix + the serving load curve + peer-restore/adaptive recovery costs
bench-quick:
	$(PYTHON) -m benchmarks.run fig10 overlap optimal_k hierarchy_scaling interposition chaos serve recovery_cost dataplane

# same smoke, plus machine-readable results in BENCH_PR10.json (CI artifact)
bench-json:
	$(PYTHON) -m benchmarks.run --json fig10 overlap optimal_k hierarchy_scaling interposition chaos serve recovery_cost dataplane

# the transparency claim, live: an unmodified MPI-shaped loop surviving faults
mpi-demo:
	$(PYTHON) examples/transparent_mpi.py

# two chaos presets end-to-end, narrated (CI's fault-pipeline smoke test)
chaos-demo:
	$(PYTHON) examples/chaos_campaign.py --preset rack_outage --preset transient_flap

# continuous batching vs the lock-step barrier, narrated (CI serving smoke)
serve-demo:
	$(PYTHON) examples/continuous_serving.py

bench:
	$(PYTHON) -m benchmarks.run

install-dev:
	$(PYTHON) -m pip install -e ".[dev]"
