# Convenience targets — CI (.github/workflows/ci.yml) runs exactly these.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint docs bench-quick bench bench-json mpi-demo chaos-demo serve-demo install-dev

test:
	$(PYTHON) -m pytest -x -q

# ruff (config in pyproject.toml); CI's lint job runs exactly this
lint:
	$(PYTHON) -m ruff check src/repro/core src/repro/mpi src/repro/serve tests benchmarks examples

# docs site link-check (README + docs/); CI's docs job runs exactly this
docs:
	$(PYTHON) tools/check_links.py

# fast, pure-python benchmark smoke: repair-time (incl. substitution) + Eq. 3/4
# + N-level scoped-repair scaling + MPI-facade transparency overhead
# + the correlated-failure invariant matrix + the serving load curve
bench-quick:
	$(PYTHON) -m benchmarks.run fig10 optimal_k hierarchy_scaling interposition chaos serve

# same smoke, plus machine-readable results in BENCH_PR7.json (CI artifact)
bench-json:
	$(PYTHON) -m benchmarks.run --json fig10 optimal_k hierarchy_scaling interposition chaos serve

# the transparency claim, live: an unmodified MPI-shaped loop surviving faults
mpi-demo:
	$(PYTHON) examples/transparent_mpi.py

# two chaos presets end-to-end, narrated (CI's fault-pipeline smoke test)
chaos-demo:
	$(PYTHON) examples/chaos_campaign.py --preset rack_outage --preset transient_flap

# continuous batching vs the lock-step barrier, narrated (CI serving smoke)
serve-demo:
	$(PYTHON) examples/continuous_serving.py

bench:
	$(PYTHON) -m benchmarks.run

install-dev:
	$(PYTHON) -m pip install -e ".[dev]"
