"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.models.ssd import ssd_decode_step

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,K,hd,causal,window,softcap",
    [
        (1, 128, 128, 4, 4, 32, True, 0, 0.0),     # MHA causal
        (2, 128, 128, 8, 2, 32, True, 0, 0.0),     # GQA 4x
        (1, 256, 256, 4, 1, 64, True, 0, 0.0),     # MQA
        (1, 128, 128, 4, 2, 32, True, 64, 0.0),    # sliding window
        (1, 128, 128, 4, 2, 32, True, 0, 30.0),    # grok-style softcap
        (2, 64, 192, 4, 4, 32, False, 0, 0.0),     # cross-attention shape
    ],
)
def test_flash_attention_sweep(B, Sq, Sk, H, K, hd, causal, window, softcap,
                               dtype, key):
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (B, Sq, H, hd), dtype)
    k = rand(ks[1], (B, Sk, K, hd), dtype)
    v = rand(ks[2], (B, Sk, K, hd), dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, logit_softcap=softcap,
        block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              logit_softcap=softcap)
    tol = ATOL[dtype]
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)
    assert out.dtype == q.dtype


def test_flash_attention_q_offset(key):
    """Decode-time block: queries at absolute positions past the KV start."""
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (1, 64, 4, 32), jnp.float32)
    k = rand(ks[1], (1, 128, 4, 32), jnp.float32)
    v = rand(ks[2], (1, 128, 4, 32), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, q_offset=64,
                                 block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, q_offset=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_block_shapes(key):
    """Block-size sweep must not change results (pure tiling)."""
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (1, 256, 4, 32), jnp.float32)
    k = rand(ks[1], (1, 256, 2, 32), jnp.float32)
    v = rand(ks[2], (1, 256, 2, 32), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                     block_k=bk, interpret=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5, err_msg=f"{bq}x{bk}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,G,N,Q",
    [
        (2, 128, 4, 16, 2, 32, 32),
        (1, 256, 8, 32, 2, 64, 64),
        (1, 64, 4, 16, 1, 32, 64),       # S < 2 chunks
        (2, 96, 4, 16, 4, 32, 32),       # G == H
    ],
)
def test_ssd_scan_sweep(B, S, H, P, G, N, Q, dtype, key):
    ks = jax.random.split(key, 6)
    x = rand(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = rand(ks[3], (B, S, G, N), dtype) * 0.3
    Cm = rand(ks[4], (B, S, G, N), dtype) * 0.3
    h0 = jax.random.normal(ks[5], (B, H, P, N)) * 0.1
    y, s = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=Q, initial_state=h0,
                           interpret=True)
    y_ref, s_ref = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=min(Q, S),
                                initial_state=h0)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(s, s_ref, atol=tol, rtol=tol)
    assert y.dtype == x.dtype


def test_ssd_scan_vs_sequential_decode(key):
    """Ground truth: the chunked kernel equals token-by-token recurrence."""
    B, S, H, P, G, N = 1, 40, 2, 8, 1, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    # kernel with chunk 16 over padded length (40 % 16 != 0 -> pad path)
    y, h = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    # sequential oracle
    state = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        yt, state = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], state)
        ys.append(yt)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y, y_seq, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, state, atol=1e-4, rtol=1e-4)


def test_ssd_state_handoff(key):
    """Splitting a sequence across two kernel calls == one call (prefill->decode)."""
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 16
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y_full, h_full = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    y1, h1 = ssd_scan_pallas(x[:, :32], dt[:, :32], A, Bm[:, :32], Cm[:, :32],
                             chunk=32, interpret=True)
    y2, h2 = ssd_scan_pallas(x[:, 32:], dt[:, 32:], A, Bm[:, 32:], Cm[:, 32:],
                             chunk=32, initial_state=h1, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h2, h_full, atol=1e-4, rtol=1e-4)


def test_ops_wrappers_jit(key):
    """ops.py wrappers are jit-compatible and pick interpret mode on CPU."""
    from repro.kernels import ops
    ks = jax.random.split(key, 3)
    q = rand(ks[0], (1, 64, 4, 32), jnp.float32)
    k = rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = rand(ks[2], (1, 64, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# int8 absmax quantization (the compression hop, kernels/quantize.py)
# ---------------------------------------------------------------------------

QUANT_SHAPES = [(4,), (130,), (1000,), (64, 257), (3, 5, 7)]


@pytest.mark.parametrize("shape", QUANT_SHAPES, ids=str)
def test_quantize_kernel_matches_numpy_twin_bitwise(shape):
    """The pinned data-plane invariant: device absmax + HOST-computed scale
    + the quantize kernel == compress_int8_np, bit for bit (the scale is
    runtime data precisely so XLA's divide-by-127 rewrite cannot split the
    backends — see the kernels/quantize.py docstring)."""
    from repro.kernels.quantize import absmax_pallas, quantize_int8_with_scale
    from repro.optim.compression import compress_int8_np
    g = np.random.default_rng(hash(shape) % 2**31).normal(
        size=shape).astype(np.float32) * 3.0
    am = np.float32(np.asarray(absmax_pallas(jnp.asarray(g), interpret=True)))
    scale = np.float32(np.maximum(am, np.float32(1e-12)) / np.float32(127.0))
    q = np.asarray(quantize_int8_with_scale(
        jnp.asarray(g), jnp.float32(scale), interpret=True))
    ref = compress_int8_np(g)
    assert scale.tobytes() == ref.scale.tobytes()
    assert q.tobytes() == ref.q.tobytes()
    assert q.shape == shape and q.dtype == np.int8


@pytest.mark.parametrize("shape", QUANT_SHAPES, ids=str)
def test_quantize_pallas_composed_matches_jitted_reference(shape):
    """The one-jit composition matches the jnp reference in the same jit
    regime (like-for-like: both see XLA's constant-division rewrite)."""
    from repro.kernels.quantize import quantize_int8_pallas
    from repro.optim import compression as C
    g = jnp.asarray(np.random.default_rng(3).normal(
        size=shape).astype(np.float32))
    got = quantize_int8_pallas(g, interpret=True)
    ref = jax.jit(C.compress_int8)(g)
    np.testing.assert_array_equal(np.asarray(got.q), np.asarray(ref.q))
    assert float(got.scale) == float(ref.scale)


def test_quantize_roundtrip_error_bound():
    """Dequantized values sit within half a quantization step."""
    from repro.kernels.quantize import quantize_int8_pallas
    g = jnp.asarray(np.random.default_rng(5).normal(
        size=(513,)).astype(np.float32))
    c = quantize_int8_pallas(g, interpret=True)
    back = np.asarray(c.q, np.float32) * np.float32(c.scale)
    assert np.max(np.abs(back - np.asarray(g))) <= float(c.scale) * 0.5 + 1e-7


def test_quantize_edge_cases():
    from repro.kernels.quantize import quantize_int8_pallas
    # all-zero input: epsilon floor keeps the scale finite, q all zero
    z = quantize_int8_pallas(jnp.zeros((32,), jnp.float32), interpret=True)
    assert not np.any(np.asarray(z.q))
    assert np.isfinite(float(z.scale)) and float(z.scale) > 0
    # single element; value maps to exactly +/-127
    one = quantize_int8_pallas(jnp.asarray([-2.5], jnp.float32),
                               interpret=True)
    assert np.asarray(one.q).tolist() == [-127]
