"""Failure-detection semantics: heartbeats, noticing (P.2/P.3), stragglers."""
from hypothesis import given, strategies as st

from repro.core.detector import (
    FaultInjector,
    HeartbeatDetector,
    StragglerDetector,
    _bcast_children,
    notice_fault,
)
from repro.core.types import NodeState


def test_heartbeat_lifecycle():
    d = HeartbeatDetector(timeout=5.0)
    d.register(0)
    d.register(1)
    d.beat(0, 3.0)
    assert d.sweep(4.0) == []
    assert d.sweep(7.0) == [1]                 # 1 never beat past t=0
    assert d.states[1] == NodeState.SUSPECT
    d.beat(1, 7.5)                             # false suspicion cleared
    assert d.states[1] == NodeState.HEALTHY
    d.confirm_failed(1)
    d.beat(1, 100.0)                           # failed nodes never return
    assert d.states[1] == NodeState.FAILED


@given(size=st.integers(1, 64))
def test_bcast_tree_spans_all(size):
    """The binomial tree from the root reaches every rank exactly once."""
    seen, frontier = {0}, [0]
    while frontier:
        v = frontier.pop()
        for c in _bcast_children(v, size):
            assert c not in seen
            seen.add(c)
            frontier.append(c)
    assert seen == set(range(size))


@given(size=st.integers(2, 48), data=st.data())
def test_bcast_notice_properties(size, data):
    """BNP: noticers = live parents of dead children + unreached survivors."""
    participants = list(range(size))
    n_failed = data.draw(st.integers(1, max(1, size // 3)))
    failed = set(data.draw(st.permutations(participants))[:n_failed])
    root = data.draw(st.sampled_from(participants))
    noticers = notice_fault("bcast", participants, failed, root=root)
    assert noticers.isdisjoint(failed)          # dead ranks notice nothing
    assert noticers <= set(participants)
    if root in failed:
        # root dead -> every survivor is unreached -> everyone notices
        assert noticers == set(participants) - failed


@given(size=st.integers(2, 48), data=st.data())
def test_bcast_partial_notice_is_real(size, data):
    """With a leaf failure, *only* its parent notices — the BNP itself."""
    participants = list(range(size))
    # pick a leaf of the rank-0-rooted tree: a node with no children
    leaves = [v for v in range(size) if not _bcast_children(v, size)]
    victim = data.draw(st.sampled_from(leaves))
    if victim == 0:
        return
    noticers = notice_fault("bcast", participants, {victim}, root=0)
    assert len(noticers) == 1                   # exactly the parent


def test_global_ops_notice_everywhere():
    participants = list(range(16))
    for op in ("reduce", "allreduce", "barrier", "agree"):
        assert notice_fault(op, participants, {3}) == set(range(16)) - {3}
    assert notice_fault("local", participants, {3}) == set()
    assert notice_fault("bcast", participants, set()) == set()


def test_straggler_detection():
    s = StragglerDetector(threshold=3.0, min_latency=0.01, min_samples=2)
    for step in range(4):
        for n in range(4):
            s.observe(n, 0.02)
        s.observe(4, 0.5)                       # 25x median
    assert s.stragglers() == [4]
    s.drop(4)
    assert s.stragglers() == []


def test_fault_injector_schedule():
    inj = FaultInjector.at([(3, 1), (3, 2), (7, 0)])
    assert [e.node for e in inj.due(3)] == [1, 2]
    assert [e.node for e in inj.due(7)] == [0]
    assert inj.due(4) == []


# -- the epoch guard: a repaired-out node cannot resurrect itself -----------

def test_stale_register_cannot_resurrect_failed_node():
    """Regression: a flapping node's re-registration (its heartbeat stream
    restarting after the repair removed it) must be refused unless it
    carries a topology epoch newer than the one its death was confirmed
    in — otherwise the detector diverges from the topology (the
    zombie-member bug the transient_flap chaos preset exercises)."""
    d = HeartbeatDetector(timeout=5.0)
    d.register(3, 0.0, epoch=1)
    d.confirm_failed(3, epoch=2)                # repaired out at epoch 2
    assert not d.register(3, 10.0)              # no epoch: stale by default
    assert not d.register(3, 10.0, epoch=1)     # pre-death epoch
    assert not d.register(3, 10.0, epoch=2)     # the death epoch itself
    assert d.states[3] is NodeState.FAILED
    d.beat(3, 11.0)                             # beats never resurrect either
    assert d.states[3] is NodeState.FAILED
    # a genuinely new incarnation (newer epoch) is allowed back in
    assert d.register(3, 12.0, epoch=3)
    assert d.states[3] is NodeState.HEALTHY


def test_register_tracks_monotone_epochs():
    d = HeartbeatDetector(timeout=5.0)
    assert d.register(0, 0.0, epoch=4)
    assert d.register(0, 1.0, epoch=2)          # healthy: re-register ok...
    assert d.epochs[0] == 4                     # ...but epochs never regress
    d.confirm_failed(0)                         # no epoch given: keeps 4
    assert not d.register(0, 2.0, epoch=4)
    assert d.register(0, 3.0, epoch=5)
