"""Hierarchical collective schedules (paper §V / Fig. 4) and timing model."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.collectives import (
    HierarchicalCollectives,
    LinkModel,
    agreement_time,
    flat_collective_time,
)
from repro.core.hierarchy import LegionTopology


def topo16():
    return LegionTopology.build(list(range(16)), 4)


def test_bcast_delivers_to_all():
    coll = HierarchicalCollectives(topo16())
    payload = np.arange(8, dtype=np.float32)
    res = coll.bcast(5, payload)
    for n in range(16):
        np.testing.assert_array_equal(res.data[n], payload)
    # schedule: root's local first, then global, then others (Fig. 4)
    comms = [s[0] for s in res.stages]
    assert comms[0] == "local_1" and comms[1] == "global"
    assert set(comms[2:]) == {"local_0", "local_2", "local_3"}


def test_reduce_collects_full_sum():
    topo = topo16()
    coll = HierarchicalCollectives(topo)
    contributions = {n: np.full(4, float(n)) for n in topo.nodes}
    res = coll.reduce(9, contributions)
    np.testing.assert_array_equal(res.data[9], np.full(4, float(sum(range(16)))))
    # non-master root costs one extra intra hop
    assert res.stages[-1][0] == "local_2"


def test_allreduce_equals_reduce_plus_bcast():
    topo = topo16()
    coll = HierarchicalCollectives(topo)
    contributions = {n: np.ones(4) for n in topo.nodes}
    res = coll.allreduce(contributions)
    for n in topo.nodes:
        np.testing.assert_array_equal(res.data[n], np.full(4, 16.0))


def test_barrier_touches_everyone():
    res = HierarchicalCollectives(topo16()).barrier()
    assert res.sim_seconds > 0


@given(n=st.integers(13, 512), nbytes=st.sampled_from([64, 4096, 1 << 20]))
def test_hierarchy_confines_cross_traffic(n, nbytes):
    """Only the global_comm stage rides slow links: hierarchical bcast beats
    the flat tree whenever the cross/intra gap is wide (the paper's premise)."""
    topo = LegionTopology.build(list(range(n)),
                                max(2, round((2 * n) ** (1 / 3))))
    link = LinkModel()
    coll = HierarchicalCollectives(topo, link)
    res = coll.bcast(0, np.zeros(nbytes // 8, np.float64))
    flat = flat_collective_time(link, "one_to_all", n, nbytes)
    assert res.sim_seconds < flat


def test_file_ops_are_legion_local():
    topo = topo16()
    coll = HierarchicalCollectives(topo)
    res = coll.file_op(6, 1 << 20)
    assert res.stages[0][0] == "local_1"
    assert res.stages[0][1] == 4                # only the legion participates


def test_comm_creator_needs_world():
    topo = topo16()
    res = HierarchicalCollectives(topo).comm_create()
    assert res.stages[0][0] == "world"
    assert res.stages[0][1] == 16


def test_agreement_overhead_small():
    link = LinkModel()
    # the BNP agreement is a zero-byte allreduce — microseconds, not payload
    assert agreement_time(link, 256) < 1e-3


def test_local_op_free():
    res = HierarchicalCollectives(topo16()).local_op(3)
    assert res.sim_seconds == 0.0


# -- N-level (depth >= 3) schedules -----------------------------------------

def topo64_d3():
    return LegionTopology.build(list(range(64)), 4, depth=3)


def test_depth3_bcast_delivers_and_walks_levels():
    coll = HierarchicalCollectives(topo64_d3())
    payload = np.arange(8, dtype=np.float32)
    res = coll.bcast(5, payload)
    for n in range(64):
        np.testing.assert_array_equal(res.data[n], payload)
    comms = [s[0] for s in res.stages]
    # up-chain: root's legion, its super-legion, the root comm — then the
    # down-sweep over the other super-legions and legions
    assert comms[:3] == ["local_1", "l1_0", "global"]
    assert {c for c in comms if c.startswith("l1_")} == \
        {"l1_0", "l1_1", "l1_2", "l1_3"}
    assert sum(c.startswith("local_") for c in comms) == 16


def test_depth3_reduce_collects_full_sum():
    topo = topo64_d3()
    coll = HierarchicalCollectives(topo)
    contributions = {n: np.full(2, float(n)) for n in topo.nodes}
    res = coll.reduce(9, contributions)
    np.testing.assert_array_equal(
        res.data[9], np.full(2, float(sum(range(64)))))


def test_reduce_without_surviving_contributors_is_a_clear_error():
    """The failure mode is explicit (ValueError), never a bare
    StopIteration leaking from the level walk."""
    topo = topo16()
    coll = HierarchicalCollectives(topo)
    with pytest.raises(ValueError, match="no surviving contributor"):
        coll.reduce(0, {99: np.ones(2)})      # 99 is not in the topology


def test_level_slowdown_scales_upper_hops():
    """Per-level cost accounting: a hop at level l >= 2 costs
    level_slowdown**(l-1) x the first cross hop; the default (1.0) keeps
    every cross hop identical."""
    topo = topo64_d3()
    payload = np.zeros(1024, np.float64)
    scaled = {c: (n, t) for c, n, t in
              HierarchicalCollectives(
                  topo, LinkModel(level_slowdown=4.0)).bcast(0, payload).stages}
    flat = {c: (n, t) for c, n, t in
            HierarchicalCollectives(topo).bcast(0, payload).stages}
    # l1_0 (level 1) and global (level 2) have 4 participants each here
    assert scaled["l1_0"][0] == scaled["global"][0] == 4
    assert scaled["global"][1] == pytest.approx(4.0 * scaled["l1_0"][1])
    assert flat["global"][1] == pytest.approx(flat["l1_0"][1])
    # level-1 hops are not scaled — only levels above the first cross hop
    assert scaled["l1_0"][1] == pytest.approx(flat["l1_0"][1])
