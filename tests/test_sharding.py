"""Sharding rules and the loop-aware HLO cost analyzer."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_smoke_config
from repro.dist.compat import abstract_mesh, make_mesh
from repro.dist.sharding import (
    _batch_dim_axes,
    batch_specs,
    param_specs,
)
from repro.launch import hlo_stats
from repro.models import api


def mesh_1():
    return make_mesh((1, 1), ("data", "model"))


def test_param_spec_rules(key):
    cfg = get_smoke_config("mixtral-8x22b")
    params = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, params, mesh_1())
    layers = specs["layers"]
    assert layers["attn"]["wq"] == P(None, "data", "model")
    assert layers["attn"]["wo"] == P(None, "model", "data")
    assert layers["moe"]["we_in"] == P(None, None, "data", "model")
    assert layers["moe"]["we_out"] == P(None, None, "model", "data")
    assert layers["attn_norm"] == P()                  # replicated (norms)
    assert specs["embed"] == P("model")                # vocab-parallel
    assert specs["final_norm"] == P()


def test_ssm_param_specs(key):
    cfg = get_smoke_config("mamba2-130m")
    params = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, params, mesh_1())
    ssm = specs["layers"]["ssm"]
    assert ssm["in_proj"] == P(None, "data", "model")
    assert ssm["out_proj"] == P(None, "model", "data")
    assert ssm["conv_w"] == P(None, None, "model")


def test_sanitize_spec_drops_nondivisible():
    """jit argument shardings need exact divisibility (constraints pad)."""
    from repro.dist.sharding import sanitize_spec
    mesh = abstract_mesh((16, 16), ("data", "model"))
    # kv-head dim 8 can't shard over model=16 -> dropped; batch 128 can
    s = sanitize_spec(P(None, "data", None, "model", None),
                      (56, 128, 4096, 8, 128), mesh)
    assert s == P(None, "data")          # trailing Nones trimmed
    # odd vocab (mamba2): model axis dropped on dim 0
    s2 = sanitize_spec(P("model", None), (50280, 768), mesh)
    assert s2 == P()
    # divisible: untouched
    s3 = sanitize_spec(P("model", None), (32768, 768), mesh)
    assert s3 == P("model")
    # tuple axes: product must divide
    mp = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    s4 = sanitize_spec(P(("pod", "data"), None), (64, 8), mp)
    assert s4 == P(("pod", "data"))
    s5 = sanitize_spec(P(("pod", "data"), None), (16, 8), mp)
    assert s5 == P()


def test_sanitize_spec_warns_once_per_replicated_dim():
    """Silently replicating a non-dividing dimension is a real capacity
    surprise: the first drop warns (naming param, dim and mesh axes); the
    same (param, dim, axes) never warns again."""
    import warnings
    from repro.dist import sharding
    from repro.dist.sharding import sanitize_spec
    mesh = abstract_mesh((4, 2), ("data", "model"))
    sharding._replication_warned.clear()
    with pytest.warns(UserWarning, match=r"dim 0 of blk\.wq.*'data'"):
        s = sanitize_spec(P("data", "model"), (7, 6), mesh, param="blk.wq")
    assert s == P(None, "model")
    # one-shot: an identical drop is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        sanitize_spec(P("data", "model"), (7, 6), mesh, param="blk.wq")
    # a different param still warns
    with pytest.warns(UserWarning, match="blk.wk"):
        sanitize_spec(P("data", "model"), (7, 6), mesh, param="blk.wk")
    # the anonymous path (in-model constraints) names "array"
    sharding._replication_warned.clear()
    with pytest.warns(UserWarning, match="array"):
        sanitize_spec(P("data"), (9,), mesh)
    sharding._replication_warned.clear()


def test_param_specs_warning_names_the_leaf():
    """The warning carries the dotted tree path of the offending leaf."""
    import warnings
    from repro.dist import sharding
    mesh = abstract_mesh((4, 2), ("data", "model"))
    params = {"layers": {"attn": {"wq": jnp.zeros((7, 6))}}}
    sharding._replication_warned.clear()
    with pytest.warns(UserWarning, match=r"layers\.attn\.wq"):
        specs = param_specs(None, params, mesh)
    assert specs["layers"]["attn"]["wq"] == P(None, "model")
    sharding._replication_warned.clear()


def test_batch_axes_divisibility():
    # AbstractMesh carries shape/axis_names without needing 2 real devices
    mesh = abstract_mesh((2, 1), ("data", "model"))
    assert _batch_dim_axes(mesh, 4) == "data"
    assert _batch_dim_axes(mesh, 1) is None            # long_500k: replicated
    assert _batch_dim_axes(mesh, 3) is None
    mp = abstract_mesh((2, 4, 1), ("pod", "data", "model"))
    assert _batch_dim_axes(mp, 16) == ("pod", "data")
    assert _batch_dim_axes(mp, 4) == "data"            # pod dropped first


def test_batch_specs_shapes():
    cfg = get_smoke_config("llama3.2-3b")
    mesh = mesh_1()
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    specs = batch_specs(cfg, mesh, batch, 8)
    assert specs["tokens"] == P("data", None)


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------

def test_analyzer_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y

    x = jnp.ones((32, 32))
    c = jax.jit(f).lower(x, x).compile()
    cost = hlo_stats.analyze(c.as_text(), 1)
    assert cost.flops == pytest.approx(9 * 2 * 32 ** 3)


def test_analyzer_nested_and_unrolled_agree():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    def unrolled(x, w):
        for _ in range(12):
            x = x @ w
        return x

    x = jnp.ones((16, 16))
    cn = hlo_stats.analyze(jax.jit(nested).lower(x, x).compile().as_text(), 1)
    cu = hlo_stats.analyze(jax.jit(unrolled).lower(x, x).compile().as_text(), 1)
    assert cn.flops == pytest.approx(cu.flops)
    # XLA's own analysis undercounts the scan version 12x
    xla = jax.jit(nested).lower(x, x).compile().cost_analysis()
    if isinstance(xla, list):        # pre-0.5 jax returns one dict per device
        xla = xla[0]
    assert xla["flops"] * 11 < cn.flops


def test_analyzer_collective_wire_model():
    text = """
ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %ag = f32[1024]{0} all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[64]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[64]{0} add(%p0, %p0)
}
"""
    cost = hlo_stats.analyze(text, 16)
    # all-gather: operand 256B, wire = 15 * 256
    assert cost.coll.operand_bytes["all-gather"] == 256
    assert cost.coll.wire_bytes["all-gather"] == pytest.approx(15 * 256)
    # all-reduce over groups of 4: 2*(3/4) * 256
    assert cost.coll.wire_bytes["all-reduce"] == pytest.approx(2 * 0.75 * 256)


def test_roofline_terms_dominance():
    t = hlo_stats.roofline_terms(197e12, 0.0, 0.0)     # 1s of pure compute
    assert t["dominant"] == "compute_s"
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t2 = hlo_stats.roofline_terms(197e11, 819e9, 0.0)  # memory-bound
    assert t2["dominant"] == "memory_s"
    assert t2["roofline_fraction"] == pytest.approx(0.1)


def test_model_flops_moe_uses_active():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    moe = get_config("mixtral-8x22b")
    train = SHAPES["train_4k"]
    mf = hlo_stats.model_flops(moe, train)
    assert mf == pytest.approx(
        6.0 * moe.active_params() * train.global_batch * train.seq_len)
    assert moe.active_params() < 0.45 * moe.total_params()
