"""The correlated-failure zoo: seeded campaigns, preset invariants under
every recovery mode, the partition split-brain guard, and the vectorized
scope scans.

Two flavors where it matters: hypothesis property tests (skipped when
hypothesis is absent — see conftest) plus deterministic mini-campaigns
that pin the same invariants without it.
"""
import random

import pytest
from hypothesis import given, strategies as st

from repro.core.chaos import RECOVERIES, ChaosHarness
from repro.core.faultmodel import FaultCampaign, FaultModel
from repro.core.hierarchy import LegionTopology
from repro.core.types import ChaosAction

N = 64          # auto-policy builds depth 3 / k=4 — racks and subtrees exist


# -- campaign generation ----------------------------------------------------

@given(seed=st.integers(0, 2**32 - 1),
       scenario=st.sampled_from(FaultModel.SCENARIOS))
def test_campaigns_reproducible(seed, scenario):
    """Same (seed, scenario, n) -> byte-identical campaign."""
    a = FaultModel(seed=seed).campaign(scenario, N)
    b = FaultModel(seed=seed).campaign(scenario, N)
    assert a.events == b.events
    assert a.meta == b.meta


def test_campaigns_reproducible_deterministic():
    for scenario in FaultModel.SCENARIOS:
        for seed in (0, 7, 13):
            a = FaultModel(seed=seed).campaign(scenario, N)
            b = FaultModel(seed=seed).campaign(scenario, N)
            assert a.events == b.events and a.meta == b.meta
    # seeds actually steer the generator
    assert (FaultModel(seed=0).campaign("independent", N).events
            != FaultModel(seed=1).campaign("independent", N).events)


def test_campaign_shape():
    c = FaultModel(seed=0).campaign("cascade", N)
    assert isinstance(c, FaultCampaign)
    assert list(c.events) == sorted(c.events, key=lambda e: e.step)
    assert all(0 <= n < N for e in c.events for n in e.nodes)
    assert c.horizon >= max(e.step for e in c.events)
    # the injector carries exactly the CRASH events
    inj = c.injector()
    assert {n for e in c.events if e.action is ChaosAction.CRASH
            for n in e.nodes} == {f.node for f in inj.events}


def test_unknown_scenario_rejected():
    with pytest.raises(ValueError):
        FaultModel().campaign("meteor_strike", N)


def test_rack_outage_targets_interior_legions():
    c = FaultModel(seed=3).campaign("rack_outage", N, racks=2)
    topo = LegionTopology.build(list(range(N)), 4, depth=3)
    subtrees = {r["subtree"] for r in c.meta["racks"]}
    assert len(subtrees) == 2                   # distinct top-level subtrees
    for r in c.meta["racks"]:
        lg = topo.legions[r["legion"]]
        assert sorted(lg.members) == sorted(r["members"])
        assert topo.subtree_of(lg.index) == r["subtree"]


# -- preset invariants across the recovery modes ----------------------------

@pytest.mark.parametrize("scenario", FaultModel.SCENARIOS)
@pytest.mark.parametrize("recovery", RECOVERIES)
def test_train_presets_pass_invariants(scenario, recovery):
    report = ChaosHarness(seed=0).run_train(scenario, N, recovery=recovery)
    assert report.passed, report.failures


@pytest.mark.parametrize("scenario", FaultModel.SCENARIOS)
def test_serve_presets_pass_invariants(scenario):
    report = ChaosHarness(seed=0).run_serve(scenario, N)
    assert report.passed, report.failures


# -- the partition split-brain guard ----------------------------------------

@pytest.mark.parametrize("fence", [True, False])
def test_partition_never_double_repairs(fence):
    """Fenced or not, each node lands in at most one terminal verdict and
    the majority side is never condemned (unfenced relies on the agree
    stage's majority quorum — a plain union would repair both sides)."""
    h = ChaosHarness(seed=3)
    campaign = h.model.campaign("network_partition", N, fence=fence)
    report = h.run_train("network_partition", N, fence=fence)
    assert report.passed, report.failures
    minority = set(campaign.meta["minority"])
    majority = set(campaign.meta["majority"])
    repaired = set(report.counts["repaired"])
    assert repaired == minority
    assert not (repaired & majority)


# -- vectorized scope scans vs the retired reference ------------------------

def _assert_scopes_identical(topo, faults):
    for node in faults:
        assert topo.fault_groups(node) == topo._fault_groups_reference(node)
    assert topo.partition_scopes(set(faults)) == \
        topo._partition_scopes_reference(set(faults))


@given(n=st.integers(3, 150), k=st.integers(2, 10),
       depth=st.integers(1, 4), data=st.data())
def test_vectorized_scopes_match_reference(n, k, depth, data):
    topo = LegionTopology.build(list(range(n)), k, depth=depth)
    count = data.draw(st.integers(1, max(1, n // 3)))
    faults = data.draw(st.permutations(list(topo.nodes)))[:count]
    _assert_scopes_identical(topo, faults)


def test_vectorized_scopes_match_reference_deterministic():
    rnd = random.Random(6)
    for _ in range(25):
        n = rnd.randrange(3, 150)
        k = rnd.randrange(2, 10)
        depth = rnd.randrange(1, 5)
        topo = LegionTopology.build(list(range(n)), k, depth=depth)
        faults = rnd.sample(list(topo.nodes),
                            rnd.randrange(1, max(2, n // 3)))
        _assert_scopes_identical(topo, faults)
