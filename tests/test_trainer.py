"""ResilientTrainer integration: loss progress, faults, restart-only-failed."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import (
    FaultInjector,
    LegionCheckpointer,
    LegioPolicy,
    ResilientTrainer,
    VirtualCluster,
)

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
    attn_block_q=16, attn_block_k=16, xent_chunk=16, remat="none",
    param_dtype="float32", dtype="float32",
)


def make_trainer(nodes=4, injector=None, policy=None, steps=40, **kw):
    tc = TrainConfig(learning_rate=3e-2, total_steps=steps, warmup_steps=4,
                     grad_clip=1.0)
    cl = VirtualCluster(nodes, policy=policy or LegioPolicy(),
                        injector=injector or FaultInjector())
    return ResilientTrainer(TINY, tc, cl, per_shard_batch=4, seq_len=32, **kw)


def test_loss_decreases():
    tr = make_trainer(steps=60)
    reports = tr.run(60)
    first = np.mean([r.loss for r in reports[:5]])
    last = np.mean([r.loss for r in reports[-5:]])
    assert last < first - 0.4, (first, last)


def test_training_survives_faults():
    inj = FaultInjector.at([(10, 1), (20, 3)])
    tr = make_trainer(nodes=4, injector=inj, steps=30)
    reports = tr.run(30)
    assert reports[10].repair is not None
    assert reports[20].repair is not None
    assert reports[10].active_shards == 3
    assert reports[20].active_shards == 2
    assert np.isfinite(reports[-1].loss)
    # loss still trends down with the shrunken cluster
    assert np.mean([r.loss for r in reports[-5:]]) < reports[0].loss


def test_drop_vs_rebalance_batch_sizes():
    inj = FaultInjector.at([(2, 0)])
    tr = make_trainer(nodes=4, injector=inj,
                      policy=LegioPolicy(batch_policy="rebalance"), steps=6)
    reports = tr.run(6)
    # rebalance: survivors pick up the orphan shard -> full batch retained
    batch, _ = tr._global_batch(5)
    assert batch["tokens"].shape[0] == 4 * 4


def test_checkpoint_restart_only_failed(tmp_path):
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    tr = make_trainer(nodes=4, steps=12)
    tr.checkpointer = ck
    tr.tc = tr.tc  # noqa
    for _ in range(6):
        tr.run_step()
    ck.save(6, tr.cluster.topo, tr._state_of, sync=True)
    params_before = jax.tree_flatten_ref = tr.params
    # a "replacement" trainer restores ONLY the dead member's shard
    tr2 = make_trainer(nodes=4, steps=12)
    tr2.restore_from(ck, legion=0, node=1)
    for a, b in zip(
        [np.asarray(x, np.float32) for x in _leaves(tr.params)],
        [np.asarray(x, np.float32) for x in _leaves(tr2.params)],
    ):
        np.testing.assert_allclose(a, b, rtol=1e-6)
    assert tr2.step == 6


def _leaves(tree):
    import jax
    return jax.tree.leaves(tree)


def test_nonfinite_loss_raises():
    tr = make_trainer(steps=4)
    tr.params = jax._nan_params = _nan_like(tr.params)
    with pytest.raises(FloatingPointError):
        tr.run_step()


def _nan_like(tree):
    import jax
    return jax.tree.map(lambda x: x * jnp.nan, tree)


import jax  # noqa: E402  (used by helpers above)
