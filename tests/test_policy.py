"""Eq. 1–4 checks: optimal legion size and the hierarchical threshold."""

from hypothesis import given, strategies as st

from repro.core.policy import (
    LegioPolicy,
    eq3_s_of_k,
    eq4_s_of_k,
    optimal_k_linear,
    optimal_k_quadratic,
)
from repro.core.shrink import ShrinkCostModel, ShrinkEngine


@given(k=st.integers(2, 60))
def test_eq3_roundtrip(k):
    """k -> s(k) -> k must be the identity on exact Eq. 3 points."""
    s = eq3_s_of_k(k)
    assert optimal_k_linear(round(s)) == k


@given(k=st.integers(2, 60))
def test_eq4_roundtrip(k):
    s = eq4_s_of_k(k)
    assert abs(optimal_k_quadratic(round(s)) - k) <= 1


@given(s=st.integers(2, 5000))
def test_optimal_k_bounds(s):
    kl = optimal_k_linear(s)
    kq = optimal_k_quadratic(s)
    assert 1 <= kl <= s
    assert 1 <= kq <= s
    # linear-S optimum k ~ (2s)^(1/3); quadratic ~ sqrt(s·sqrt(3)/2)^(1/2)...
    # sanity: quadratic favors larger legions than linear for big s
    if s > 50:
        assert kq >= kl


@given(s=st.integers(12, 2000))
def test_hierarchical_beats_flat_beyond_threshold(s):
    """Paper: with PURE linear S (no constant term — the paper's Eq. 2
    setting), hierarchy wins for s > 11 (∃k: R_H < S(s))."""
    engine = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=1.0, c=0.0))
    k = optimal_k_linear(s)
    assert engine.expected_repair_cost(s, k) < engine.cost_flat(s)


def test_constant_term_moves_crossover():
    """With a per-shrink constant (agreement+revoke) the crossover moves
    past the paper's s=11 — the master case pays c four times (Eq. 1)."""
    engine = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=1.0, c=0.12))
    assert engine.expected_repair_cost(12, optimal_k_linear(12)) \
        > engine.cost_flat(12)
    s0 = next(s for s in range(12, 4000)
              if min(engine.expected_repair_cost(s, k)
                     for k in range(2, s)) < engine.cost_flat(s))
    assert 12 < s0 < 1000


def test_flat_wins_when_tiny():
    engine = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=1.0))
    # s <= 11: no k strictly better than flat under E[R_H]
    for s in range(2, 8):
        best = min(engine.expected_repair_cost(s, k) for k in range(1, s + 1))
        assert best >= engine.cost_flat(s) * 0.8  # no meaningful win


def test_policy_choose_k():
    p = LegioPolicy(legion_size=5)
    assert p.choose_k(100) == 5
    assert p.choose_k(3) == 3            # capped at cluster size
    auto = LegioPolicy()
    assert auto.choose_k(256) == optimal_k_linear(256)


def test_use_hierarchical_threshold():
    p = LegioPolicy()
    assert not p.use_hierarchical(11)
    assert not p.use_hierarchical(12)
    assert p.use_hierarchical(13)
