"""Integration test of the full dry-run path on a miniature mesh.

Runs in a subprocess so ``--xla_force_host_platform_device_count`` never
leaks into the main test session (smoke tests must see 1 device). Covers:
input_specs -> cell_shardings -> jit(in/out shardings, donation) -> lower
-> compile -> loop-aware HLO analysis, for one train and one decode cell
on a (2,2,2) pod/data/model mesh with a reduced-but-multi-layer config.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax

    from repro.configs.base import ShapeSpec, TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.dist.compat import make_mesh, use_mesh
    from repro.launch import hlo_stats
    from repro.launch.steps import cell_shardings, input_specs, step_fn_for

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("llama3.2-3b").replace(n_layers=4)
    out = {}
    for shape in (ShapeSpec("mini_train", 64, 8, "train"),
                  ShapeSpec("mini_decode", 64, 8, "decode")):
        specs = input_specs(cfg, shape)
        in_sh, out_sh = cell_shardings(cfg, shape, mesh, specs)
        fn = step_fn_for(cfg, shape, TrainConfig())
        with use_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=tuple(in_sh[k] for k in specs),
                             out_shardings=out_sh)
            compiled = jitted.lower(*specs.values()).compile()
        cost = hlo_stats.analyze(compiled.as_text(), 8)
        mem = compiled.memory_analysis()
        out[shape.name] = {
            "flops": cost.flops,
            "wire": cost.coll.total_wire_bytes,
            "arg_bytes": mem.argument_size_in_bytes,
        }
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def mini_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_mini_train_cell_compiles(mini_result):
    r = mini_result["mini_train"]
    assert r["flops"] > 1e6            # fwd+bwd+opt actually lowered
    assert r["wire"] > 0               # gradient reduction present
    # params sharded: per-device arg bytes well under the full model
    assert r["arg_bytes"] > 0


def test_mini_decode_cell_compiles(mini_result):
    r = mini_result["mini_decode"]
    assert r["flops"] > 0
    # decode step is one token: orders less compute than the train step
    assert r["flops"] < mini_result["mini_train"]["flops"] / 10
