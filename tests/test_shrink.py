"""Repair plans and the S(x) cost model (paper §V Fig. 3, Eq. 1)."""
import pytest
from hypothesis import given, strategies as st

from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy
from repro.core.shrink import ShrinkCostModel, ShrinkEngine


def make_engine(**kw):
    return ShrinkEngine(LegioPolicy(), ShrinkCostModel(**kw))


def test_worker_failure_is_local():
    """Non-master failure: one local shrink, cost S(k) — nothing else."""
    topo = LegionTopology.build(list(range(16)), 4)
    eng = make_engine()
    steps = eng.plan(topo, {5})                 # 5 is not master of legion 1
    assert [s.op for s in steps] == ["shrink"]
    assert steps[0].comm == "local_1"
    assert steps[0].cost_units == eng.cost.s_of_x(4)


def test_master_failure_full_plan():
    """Master failure: Fig. 3's six stages, Eq. 1's cost."""
    topo = LegionTopology.build(list(range(16)), 4)
    eng = make_engine()
    steps = eng.plan(topo, {4})                 # master of legion 1
    ops = [s.op for s in steps]
    assert ops == ["shrink", "notify", "shrink", "shrink", "shrink",
                   "promote", "include"]
    comms = [s.comm for s in steps]
    assert comms == ["local_1", "pov_0", "pov_0", "pov_1", "global",
                     "local_1", "global"]
    total = sum(s.cost_units for s in steps)
    expected = eng.cost.hierarchical_cost(16, 4, master_failed=True)
    assert total == pytest.approx(expected)
    # the new master is the next-lowest surviving rank of legion 1
    promote = next(s for s in steps if s.op == "promote")
    assert promote.participants == (5,)


def test_flat_plan():
    topo = LegionTopology.flat(list(range(8)))
    eng = make_engine()
    steps = eng.plan(topo, {3})
    assert len(steps) == 1 and steps[0].comm == "world"
    assert steps[0].cost_units == eng.cost.s_of_x(8)


@given(n=st.integers(4, 80), k=st.integers(2, 8), data=st.data())
def test_repair_removes_exactly_failed(n, k, data):
    topo = LegionTopology.build(list(range(n)), k)
    eng = make_engine()
    n_fail = data.draw(st.integers(1, min(3, n - 1)))
    failed = set(data.draw(st.permutations(list(range(n))))[:n_fail])
    report = eng.repair(topo, failed)
    assert set(topo.nodes) == set(range(n)) - failed
    assert report.survivors == n - len(failed)
    assert report.trigger == tuple(sorted(failed))
    # masters are re-elected everywhere
    for lg in topo.legions:
        assert lg.master == min(lg.members)


@given(s=st.integers(13, 1000))
def test_eq1_master_vs_worker_cost(s):
    eng = make_engine(p=1.0)
    k = LegioPolicy().choose_k(s)
    worker = eng.cost_hierarchical(s, k, False)
    master = eng.cost_hierarchical(s, k, True)
    assert worker == eng.cost.s_of_x(k)
    assert master > worker                      # Eq. 1: master repair dearer
    # Eq. 1 structure: S(k) + 2 S(k+1) + S(s/k)
    assert master == pytest.approx(
        eng.cost.s_of_x(k) + 2 * eng.cost.s_of_x(k + 1)
        + eng.cost.s_of_x(max(1, round(s / k))))


def test_quadratic_model_monotone():
    eng = make_engine(p=2.0)
    costs = [eng.cost_flat(s) for s in (8, 64, 256, 1024)]
    assert all(b > a for a, b in zip(costs, costs[1:]))
    ratios = [b / a for a, b in zip(costs, costs[1:])]
    assert ratios[-1] > 10                      # superlinear growth


def test_multi_failure_one_shrink_per_legion():
    topo = LegionTopology.build(list(range(16)), 4)
    eng = make_engine()
    steps = eng.plan(topo, {1, 2})              # two workers, same legion
    assert [s.op for s in steps] == ["shrink"]
    steps = eng.plan(topo, {1, 5})              # two workers, two legions
    assert [s.op for s in steps] == ["shrink", "shrink"]
