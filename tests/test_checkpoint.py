"""Per-legion checkpoint store: restart-only-failed, checksums, async."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def tree(seed: float):
    return {
        "params": {"w": jnp.full((4, 4), seed, jnp.bfloat16),
                   "b": jnp.arange(4, dtype=jnp.float32) * seed},
        "step": jnp.asarray(int(seed), jnp.int32),
    }


def shards_for(nodes):
    return {(n // 2, n): tree(float(n + 1)) for n in nodes}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    store.save(d, 10, shards_for(range(4)))
    manifest, shards = store.restore(d, 10)
    assert manifest.step == 10
    assert set(shards) == {(0, 0), (0, 1), (1, 2), (1, 3)}
    got = shards[(1, 2)]
    np.testing.assert_array_equal(
        np.asarray(got["params"]["w"], np.float32), np.full((4, 4), 3.0))
    assert got["params"]["w"].dtype == jnp.bfloat16     # bf16 preserved


def test_restore_only_failed_member(tmp_path):
    d = str(tmp_path)
    store.save(d, 7, shards_for(range(6)))
    one = store.restore_member(d, 7, legion=2, node=5)
    np.testing.assert_array_equal(np.asarray(one["step"]), 6)
    # template-driven restore returns the exact tree structure
    t = tree(0.0)
    one_t = store.restore_member(d, 7, legion=2, node=5, template=t)
    assert one_t["params"]["w"].shape == (4, 4)


def test_missing_member_raises(tmp_path):
    d = str(tmp_path)
    store.save(d, 7, shards_for(range(2)))
    with pytest.raises(FileNotFoundError):
        store.restore_member(d, 7, legion=9, node=99)


def test_checksum_detects_corruption(tmp_path):
    d = str(tmp_path)
    store.save(d, 3, shards_for(range(2)))
    # corrupt one member file
    path = os.path.join(d, "step_000003", "legion_00", "member_001.npz")
    with np.load(path) as z:
        arrays = {k: z[k].copy() for k in z.files}
    key = [k for k in arrays if k.endswith("w")][0]
    arrays[key] = arrays[key] + 1
    np.savez(path, **arrays)
    with pytest.raises(IOError):
        store.restore_member(d, 3, legion=0, node=1)
    # unverified read still works (operator override)
    store.restore_member(d, 3, legion=0, node=1, verify=False)


def test_latest_step_and_partial_dirs(tmp_path):
    d = str(tmp_path)
    assert store.latest_step(d) is None
    store.save(d, 1, shards_for(range(2)))
    store.save(d, 5, shards_for(range(2)))
    os.makedirs(os.path.join(d, "step_000009"))    # crashed write: no manifest
    assert store.latest_step(d) == 5


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = store.AsyncCheckpointer(d, keep=2)
    for step in (1, 2, 3, 4):
        block_s = ck.save_async(step, shards_for(range(2)))
        assert block_s < 5.0
    ck.wait()
    # gc kept only the last 2
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert steps == ["step_000003", "step_000004"]
    ck.close()


def test_gc_sweeps_partial_dirs_and_never_counts_them(tmp_path):
    """A crashed write leaves a manifest-less step dir. Retention must count
    complete steps only (a partial dir never consumes a keep slot) and the
    partial dir itself is swept — files and all."""
    d = str(tmp_path)
    ck = store.AsyncCheckpointer(d, keep=2)
    ck.save_async(1, shards_for(range(2)))
    ck.wait()
    # two dead partial dirs, one with stranded member files inside
    os.makedirs(os.path.join(d, "step_000007"))
    stranded = os.path.join(d, "step_000009", "legion_00")
    os.makedirs(stranded)
    with open(os.path.join(stranded, "member_000.npz"), "wb") as f:
        f.write(b"garbage")
    ck.save_async(2, shards_for(range(2)))
    ck.save_async(3, shards_for(range(2)))
    ck.wait()
    steps = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    # partials gone; the keep=2 newest COMPLETE steps survive — step 2 was
    # not evicted to make room for a partial
    assert steps == ["step_000002", "step_000003"]
    ck.close()


def test_restore_member_threads_preparsed_manifest(tmp_path):
    d = str(tmp_path)
    store.save(d, 4, shards_for(range(4)))
    sdir = os.path.join(d, "step_000004")
    manifest = store._read_manifest(sdir)
    one = store.restore_member(d, 4, legion=1, node=3, manifest=manifest)
    np.testing.assert_array_equal(np.asarray(one["step"]), 4)
    # a stale manifest is trusted as handed in: missing rows raise the same
    # FileNotFoundError the unthreaded path would
    manifest.files.pop(store.member_relpath(1, 3))
    with pytest.raises(FileNotFoundError):
        store.restore_member(d, 4, legion=1, node=3, manifest=manifest)


def test_legion_dirs_are_self_contained(tmp_path):
    """No global file: each legion's data lives under its own directory."""
    d = str(tmp_path)
    store.save(d, 2, shards_for(range(4)))
    sdir = os.path.join(d, "step_000002")
    entries = sorted(os.listdir(sdir))
    assert entries == ["legion_00", "legion_01", "manifest.json"]
