"""LegioExecutor end-to-end: transparent detect → agree → repair → continue."""
import numpy as np
import pytest

from repro.core import (
    FaultInjector,
    LegioExecutor,
    LegioPolicy,
    RootFailedError,
    VirtualCluster,
)


def work(node, shard, step):
    return np.ones(4) * (shard + 1)


def test_fault_free_run():
    cl = VirtualCluster(16, policy=LegioPolicy(legion_size=4))
    ex = LegioExecutor(cl, work)
    reports = ex.run(3)
    for r in reports:
        assert r.failed_now == ()
        assert r.reduced[0] == sum(range(1, 17))


def test_worker_fault_discard_and_continue():
    inj = FaultInjector.at([(2, 5)])
    cl = VirtualCluster(16, policy=LegioPolicy(legion_size=4), injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(5)
    assert reports[2].failed_now == (5,)
    assert reports[2].repair is not None
    assert not reports[2].repair.master_failed
    # after repair the reduce covers survivors only (shard 6 dropped)
    assert reports[3].reduced[0] == sum(range(1, 17)) - 6
    assert len(cl.live_nodes) == 15
    # application-visible: reports keep coming, no exception — transparency


def test_master_fault_repair():
    inj = FaultInjector.at([(1, 0)])               # node 0: master of legion 0
    cl = VirtualCluster(16, policy=LegioPolicy(legion_size=4), injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(3)
    rep = reports[1].repair
    assert rep is not None and rep.master_failed and rep.hierarchical
    ops = [s.op for s in rep.steps]
    assert "promote" in ops and "include" in ops
    assert cl.topo.legion_of(1).master == 1        # re-elected


def test_root_policy_stop():
    inj = FaultInjector.at([(1, 0)])
    cl = VirtualCluster(
        8, policy=LegioPolicy(root_failure_policy="stop"), injector=inj)
    ex = LegioExecutor(cl, work, final_collective="reduce", root=0)
    ex.run_step()
    with pytest.raises(RootFailedError):
        ex.run_step()


def test_root_policy_ignore_skips_op():
    inj = FaultInjector.at([(1, 0)])
    cl = VirtualCluster(
        8, policy=LegioPolicy(root_failure_policy="ignore"), injector=inj)
    ex = LegioExecutor(cl, work, final_collective="reduce", root=0)
    ex.run_step()
    r = ex.run_step()
    assert r.skipped_op                             # op skipped, no crash
    r = ex.run_step()
    assert not r.skipped_op                         # next step proceeds


def test_rebalance_preserves_total():
    inj = FaultInjector.at([(1, 3)])
    cl = VirtualCluster(8, policy=LegioPolicy(batch_policy="rebalance"),
                        injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(3)
    # shard 3's work re-appears on a survivor: total unchanged
    assert reports[2].reduced[0] == sum(range(1, 9))
    assert reports[2].grad_scale == 1.0


def test_drop_renormalizes():
    inj = FaultInjector.at([(1, 3)])
    cl = VirtualCluster(8, policy=LegioPolicy(batch_policy="drop"),
                        injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(3)
    assert reports[2].reduced[0] == sum(range(1, 9)) - 4
    assert reports[2].grad_scale == pytest.approx(8 / 7)


def test_elastic_regrow_with_spares():
    inj = FaultInjector.at([(1, 2)])
    cl = VirtualCluster(8, policy=LegioPolicy(spare_nodes=2), injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run(3)
    # the spare (node 8) joined and took over the dropped shard
    assert 8 in cl.topo.nodes
    assert cl.plan.active_shards == 8


def test_cascading_failures_to_minimum():
    pairs = [(i, i) for i in range(6)]
    cl = VirtualCluster(8, injector=FaultInjector.at(pairs))
    ex = LegioExecutor(cl, work)
    reports = ex.run(7)
    assert len(cl.live_nodes) == 2
    assert reports[-1].reduced is not None          # still producing results


def test_simulated_clock_charges_repairs():
    inj = FaultInjector.at([(0, 1)])
    cl = VirtualCluster(16, injector=inj)
    LegioExecutor(cl, work).run(1)
    assert cl.clock.sim_seconds > 0
    assert cl.repairs[0].model_cost > 0
