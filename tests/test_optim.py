"""Optimizer + gradient compression (error feedback) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs.base import TrainConfig
from repro.optim import (
    adamw_init,
    adamw_update,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.optim.compression import (
    compress_int8,
    compress_topk,
    compressed_bytes,
    decompress_int8,
    decompress_topk,
    make_compressor,
)


def test_adamw_minimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                     weight_decay=0.0)
    lr = cosine_schedule(tc)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(150):
        grads = jax.tree.map(lambda w: 2 * w, params)
        updates, opt = adamw_update(grads, opt, params, tc, lr(opt.step))
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert norm == pytest.approx(10.0)
    assert global_norm(clipped) == pytest.approx(1.0, rel=1e-5)
    # below the max: untouched
    same, _ = clip_by_global_norm(grads, 100.0)
    np.testing.assert_allclose(same["a"], grads["a"])


def test_cosine_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr = cosine_schedule(tc)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)
    # monotone decay after warmup
    vals = [float(lr(jnp.asarray(s))) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


@given(seed=st.integers(0, 1000))
def test_int8_roundtrip_error_bounded(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    c = compress_int8(g)
    back = decompress_int8(c)
    # quantization error bounded by scale/2 per entry
    assert float(jnp.max(jnp.abs(back - g))) <= float(c.scale) * 0.51


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 0.0])
    c = compress_topk(g, fraction=0.34)           # k = 2
    back = decompress_topk(c, g.shape)
    np.testing.assert_allclose(back, [0, -5.0, 0, 3.0, 0, 0])


def test_error_feedback_accumulates():
    """With error feedback the compressed sum converges to the true sum."""
    comp, decomp = make_compressor("topk", fraction=0.25)
    g = {"w": jnp.asarray([1.0, 0.5, 0.25, 0.125])}
    residual = None
    total = jnp.zeros(4)
    for _ in range(16):
        payload, residual = comp(g, residual)
        total = total + decomp(payload, g)["w"]
    # every coordinate eventually flushes through the top-k channel
    np.testing.assert_allclose(total / 16, g["w"], atol=0.15)


def test_compressed_bytes_model():
    g = jnp.zeros((1000,), jnp.bfloat16)
    assert compressed_bytes(g, "none") == 2000
    assert compressed_bytes(g, "int8") == 1004
    assert compressed_bytes(g, "topk", 0.05) == 8 * 50


def test_int8_compressor_tree():
    comp, decomp = make_compressor("int8")
    g = {"a": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([[3.0]])}
    payload, residual = comp(g, None)
    back = decomp(payload, g)
    np.testing.assert_allclose(back["a"], g["a"], atol=0.05)
    np.testing.assert_allclose(back["b"], g["b"], atol=0.05)
