"""Data-plane seam: sim/jax backend parity under the simulated control plane.

The pinned contract (src/repro/dist/dataplane.py): schedules, stage lists
and clock charges never depend on the backend, and for integer-exact
payloads the *results* are byte-identical too — across an entire seeded
fault campaign (shrink, substitute, background overlap). The reshard test
runs in a subprocess with 8 forced host devices (the XLA flag must be set
before jax imports; conftest already imported jax), so placement is
exercised on a real multi-device mesh regardless of the host. The CI
data-plane step additionally runs this whole file under 8 forced devices.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import FaultInjector
from repro.core.executor import VirtualCluster
from repro.core.policy import LegioPolicy
from repro.dist.dataplane import (
    JaxDataPlane,
    SimDataPlane,
    default_dataplane,
    make_dataplane,
)
from repro.mpi import Session

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# selection — policy knob -> backend
# ---------------------------------------------------------------------------

def test_policy_validates_data_plane():
    with pytest.raises(ValueError, match="data_plane"):
        LegioPolicy(data_plane="cuda")
    assert LegioPolicy().data_plane == "sim"


def test_make_dataplane_resolution():
    import jax
    assert isinstance(make_dataplane(LegioPolicy(data_plane="sim")),
                      SimDataPlane)
    # explicit "jax" is honored at any device count
    assert isinstance(make_dataplane(LegioPolicy(data_plane="jax")),
                      JaxDataPlane)
    auto = make_dataplane(LegioPolicy(data_plane="auto"))
    expect = JaxDataPlane if len(jax.devices()) > 1 else SimDataPlane
    assert isinstance(auto, expect)
    # default plane is the shared sim singleton (collectives built without
    # a cluster behave exactly as before the seam existed)
    assert default_dataplane() is default_dataplane()
    assert default_dataplane().name == "sim"


def test_session_surfaces_data_plane_name():
    sess = Session(4, policy=LegioPolicy(data_plane="sim"))
    assert sess.data_plane == "sim"


# ---------------------------------------------------------------------------
# plane-level parity (any device count; real motion under the CI 8-dev step)
# ---------------------------------------------------------------------------

def _integer_exact(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(-50, 50, size=shape).astype(np.float32)


def test_reduce_parity_integer_exact():
    sim, jx = SimDataPlane(), JaxDataPlane()
    parts = [_integer_exact((33,), s) for s in range(5)]
    for op in (np.add, np.maximum, np.minimum):
        a = sim.reduce([p.copy() for p in parts], op)
        b = jx.reduce([p.copy() for p in parts], op)
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes()


def test_reduce_unsupported_falls_back_to_sim():
    jx = JaxDataPlane()
    parts = [np.arange(4, dtype=np.float64), np.ones(4)]  # x64: canonicalized
    out = jx.reduce(parts, np.add)
    assert out.dtype == np.float64
    np.testing.assert_array_equal(out, np.arange(4) + 1.0)
    # unknown op: sim fold
    out2 = jx.reduce([np.ones(3, np.float32)] * 2, np.subtract)
    np.testing.assert_array_equal(out2, np.zeros(3))


def test_bcast_and_gather_bit_roundtrip():
    jx = JaxDataPlane()
    payload = np.random.default_rng(1).normal(size=17).astype(np.float32)
    out = jx.bcast_payload(payload)
    assert out.tobytes() == payload.tobytes()
    vals = [_integer_exact((6,), s) for s in range(3)]
    back = jx.gather_arrays(vals)
    assert len(back) == 3
    for a, b in zip(vals, back):
        assert a.tobytes() == np.asarray(b).tobytes()


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_compress_parity_bitwise(scheme):
    """Arbitrary (non-integer) f32: the compression hop is byte-identical
    across backends — host-computed scale, IEEE-exact elementwise ops,
    stable top-k tie-breaking (see kernels/quantize.py)."""
    sim, jx = SimDataPlane(), JaxDataPlane()
    for shape, seed in [((4,), 0), ((130,), 1), ((64, 257), 2), ((1000,), 3)]:
        g = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
        a = sim.compress(g, scheme, 0.05)
        b = jx.compress(g, scheme, 0.05)
        assert a.shape == b.shape
        assert a.tobytes() == b.tobytes(), f"{scheme} {shape}"


# ---------------------------------------------------------------------------
# campaign parity: the full facade loop, faults and all
# ---------------------------------------------------------------------------

def _campaign_pair(policy_kwargs, faults, n=16):
    def mk(plane):
        return Session(
            n, policy=LegioPolicy(legion_size=4, data_plane=plane,
                                  **policy_kwargs),
            injector=FaultInjector.at(list(faults)))
    return mk("sim"), mk("jax")


def _assert_result_parity(res_s, res_j, ctx):
    assert res_s.stages == res_j.stages, f"{ctx}: stage lists diverged"
    assert res_s.sim_seconds == res_j.sim_seconds, f"{ctx}: clock diverged"
    assert set(res_s.data) == set(res_j.data), f"{ctx}: membership diverged"
    for node in res_s.data:
        a, b = np.asarray(res_s.data[node]), np.asarray(res_j.data[node])
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), \
            f"{ctx}: node {node} diverged"


@pytest.mark.parametrize("mode_kwargs", [
    {"recovery_mode": "shrink"},
    {"recovery_mode": "substitute", "spare_nodes": 2},
    {"recovery_mode": "shrink", "repair_overlap": True},
], ids=["shrink", "substitute", "overlap"])
def test_fault_campaign_parity(mode_kwargs):
    """Byte-identical allreduce/bcast/reduce results and identical stage
    lists between backends at every step of a seeded campaign that kills a
    legion master and a member mid-flight."""
    faults = [(2, 9), (4, 0)]
    sess_s, sess_j = _campaign_pair(mode_kwargs, faults)
    for step in range(7):
        sess_s.advance(step)
        sess_j.advance(step)
        assert sess_s.cluster.topo.nodes == sess_j.cluster.topo.nodes, \
            f"step {step}: topologies diverged"
        comm_s, comm_j = sess_s.world, sess_j.world
        def contrib(sess):
            return {m: (np.arange(8, dtype=np.float32) % 5.0) * (m + 1)
                    for m in sess.world.members
                    if m not in sess.cluster.failed}
        _assert_result_parity(comm_s.allreduce(contrib(sess_s)),
                              comm_j.allreduce(contrib(sess_j)),
                              f"step {step} allreduce")
        root = sorted(comm_s.members)[0]
        payload = np.arange(16, dtype=np.float32) - 3.0
        _assert_result_parity(comm_s.bcast(payload, root=root),
                              comm_j.bcast(payload, root=root),
                              f"step {step} bcast")
        _assert_result_parity(comm_s.reduce(contrib(sess_s), root=root),
                              comm_j.reduce(contrib(sess_j), root=root),
                              f"step {step} reduce")
    # the campaign actually exercised repair on both sides
    assert sess_s.world.stats.repair_rounds >= 2
    assert sess_j.world.stats.repair_rounds >= 2


def test_compressed_campaign_parity_topk():
    """The top-k cross hop stays byte-identical across a fault campaign:
    decompressed top-k values are the original (integer-exact) partials, so
    every downstream sum stays exact too. Equal stage lists => the wire-byte
    accounting (control plane) is identical by construction."""
    sess_s, sess_j = _campaign_pair(
        {"grad_compression": "topk"}, [(2, 5)])
    g = (np.arange(32, dtype=np.float32) % 11.0) - 5.0
    for step in range(5):
        sess_s.advance(step)
        sess_j.advance(step)
        def contrib(sess):
            return {m: g * np.float32(m % 3 + 1)
                    for m in sess.world.members
                    if m not in sess.cluster.failed}
        _assert_result_parity(sess_s.world.allreduce(contrib(sess_s)),
                              sess_j.world.allreduce(contrib(sess_j)),
                              f"step {step} topk allreduce")


def test_compressed_campaign_int8_accounting_parity():
    """int8: the hop itself is bitwise across backends (pinned above), but
    summing the *decompressed* (non-integer) partials may legally differ by
    1 ulp between a vectorized and a sequential fold — so the campaign pins
    identical stage lists/clock charges (the accounting) plus tight
    numerical agreement, not payload bytes."""
    sess_s, sess_j = _campaign_pair(
        {"grad_compression": "int8"}, [(2, 5)])
    g = np.random.default_rng(7).normal(size=32).astype(np.float32)
    for step in range(5):
        sess_s.advance(step)
        sess_j.advance(step)
        def contrib(sess):
            return {m: g * np.float32(m % 3 + 1)
                    for m in sess.world.members
                    if m not in sess.cluster.failed}
        res_s = sess_s.world.allreduce(contrib(sess_s))
        res_j = sess_j.world.allreduce(contrib(sess_j))
        assert res_s.stages == res_j.stages
        assert res_s.sim_seconds == res_j.sim_seconds
        assert set(res_s.data) == set(res_j.data)
        for node in res_s.data:
            np.testing.assert_allclose(res_s.data[node], res_j.data[node],
                                       rtol=1e-6, atol=1e-5)


def test_gather_rides_the_dataplane():
    sess = Session(4, policy=LegioPolicy(data_plane="jax"))
    sess.advance(0)
    vals = {m: _integer_exact((5,), m) for m in sess.world.members}
    out = sess.world.gather(vals)
    assert set(out) == set(vals)
    for m, v in vals.items():
        assert np.asarray(out[m]).tobytes() == v.tobytes()
    # mixed payloads stay host-side untouched
    mixed = {0: np.ones(2), 1: "text", 2: np.ones(3)}
    out2 = sess.world.gather(mixed)
    assert out2[1] == "text"


# ---------------------------------------------------------------------------
# fault-driven resharding: mesh shrink + param_specs placement
# ---------------------------------------------------------------------------

_RESHARD_SCRIPT = r"""
import numpy as np
import jax
assert len(jax.devices()) == 8, jax.devices()
from jax.sharding import NamedSharding
from repro.core import FaultInjector
from repro.core.policy import LegioPolicy
from repro.dist.sharding import param_specs
from repro.mpi import Session

sess = Session(8, policy=LegioPolicy(legion_size=4, data_plane="jax"),
               injector=FaultInjector.at([(1, 3)]))
cl = sess.cluster
state = {
    "wq": jax.numpy.ones((8, 16), jax.numpy.float32),    # ("data","model")
    "bias": jax.numpy.zeros((16,), jax.numpy.float32),   # replicated
}
holder = {"state": state}
sess.register_sharded_state("params", lambda: holder["state"],
                            lambda s: holder.update(state=s))
t0 = cl.clock.sim_seconds
for step in range(3):
    sess.advance(step)
    sess.world.allreduce({m: np.ones(4, np.float32)
                          for m in sess.world.members
                          if m not in cl.failed})
assert 3 not in cl.topo.nodes                       # the shrink landed
assert cl.reshards, "no ReshardReport logged after repair"
rep = cl.reshards[-1]
assert rep.n_devices == 7, rep                      # 8 devices - 1 dead
assert rep.mesh_shape == (7, 1), rep
assert rep.wall_seconds > 0.0
assert cl.clock.sim_seconds > t0                    # measured charge landed
# every surviving leaf sits exactly where param_specs places it
mesh = cl.dataplane.mesh_for(cl.topo.view())
specs = param_specs(None, holder["state"], mesh)
for name, leaf in holder["state"].items():
    want = NamedSharding(mesh, specs[name])
    assert leaf.sharding.is_equivalent_to(want, leaf.ndim), (
        name, leaf.sharding, want)
print("RESHARD_OK")
"""


def test_reshard_after_shrink_places_leaves_on_survivors():
    """Subprocess with 8 forced host devices: a mid-campaign node death
    rebuilds the mesh from the 7 survivors, re-places every registered leaf
    per param_specs, and charges the measured wall time to the clock."""
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run([sys.executable, "-c", _RESHARD_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=300, cwd=str(REPO))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "RESHARD_OK" in proc.stdout


def test_sim_plane_reshard_is_free():
    cl = VirtualCluster(4, policy=LegioPolicy(data_plane="sim"))
    cl.register_sharded_state("x", lambda: {"a": np.ones(3)})
    assert cl.dataplane.reshard_registered(cl.topo.view()) is None
    assert cl.reshards == []


# ---------------------------------------------------------------------------
# transparency: no consumer reaches around the seam
# ---------------------------------------------------------------------------

def test_consumers_never_import_dataplane_directly():
    """serve/, launch/ and examples/ select backends only via
    LegioPolicy.data_plane — grep-clean transparency."""
    roots = [REPO / "src" / "repro" / "serve",
             REPO / "src" / "repro" / "launch",
             REPO / "examples"]
    offenders = []
    for root in roots:
        for path in root.rglob("*.py"):
            text = path.read_text()
            if "dist.dataplane" in text or "DataPlane" in text:
                offenders.append(str(path.relative_to(REPO)))
    assert not offenders, f"consumers import the data plane: {offenders}"
