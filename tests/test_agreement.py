"""Fault agreement: the BNP fix (paper §IV) and the in-program bitmap reduce."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core.agreement import (
    agree_bitmap_inprogram,
    agree_fault,
    agreement_rounds,
)
from repro.dist.compat import make_mesh


@given(data=st.data())
def test_agreement_union_properties(data):
    n = data.draw(st.integers(2, 32))
    nodes = list(range(n))
    failed = set(data.draw(st.lists(st.sampled_from(nodes), max_size=n // 2)))
    live = [x for x in nodes if x not in failed]
    # each live observer sees an arbitrary subset of the failures
    observations = {
        obs: set(data.draw(st.lists(st.sampled_from(sorted(failed)))))
        if failed else set()
        for obs in live
    }
    verdict = agree_fault(observations, live)
    # verdict == union of live observations
    expected = set()
    for obs in live:
        expected |= observations[obs]
    assert verdict == expected
    # dead observers' claims are ignored
    observations[sorted(failed)[0] if failed else -1] = {0}
    assert agree_fault(observations, live) == expected


def test_agreement_resolves_bnp():
    """Partial noticing (some observers saw nothing) -> identical verdict."""
    live = [0, 1, 2, 3]
    obs = {0: {7}, 1: set(), 2: set(), 3: {7}}
    v = agree_fault(obs, live)
    assert v == {7}                              # everyone adopts {7}


def test_agreement_rounds_log():
    assert agreement_rounds(1) == 1
    assert agreement_rounds(2) == 1
    assert agreement_rounds(256) == 8


def test_liveness_psum_single_axis():
    mesh = make_mesh((1,), ("data",))
    bitmaps = jnp.array([[1, 0, 1, 1]], jnp.int32)
    out = agree_bitmap_inprogram(mesh, bitmaps)
    np.testing.assert_array_equal(out, [1, 0, 1, 1])


def test_bitmap_and_reduce_host():
    """Multiple shards, host fallback path: AND of all rows."""
    mesh = make_mesh((1,), ("x",))
    bitmaps = jnp.array([[1, 1, 0], [1, 0, 1]], jnp.int32)
    out = agree_bitmap_inprogram(mesh, bitmaps)
    np.testing.assert_array_equal(out, [1, 0, 0])
