"""Fault pipeline: stage flow, topology epochs, strategies, elastic re-spawn."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (
    FaultInjector,
    FaultSource,
    LegionTopology,
    LegioExecutor,
    LegioPolicy,
    RecoveryStrategy,
    TopologyTornError,
    VirtualCluster,
    available_strategies,
    make_strategy,
    register_strategy,
)


def work(node, shard, step):
    return np.ones(4) * (shard + 1)


# ---------------------------------------------------------------------------
# strategy registry (the ladder replacement)
# ---------------------------------------------------------------------------

def test_registry_covers_all_policy_modes():
    assert {"shrink", "substitute", "substitute_nonblocking"} <= \
        set(available_strategies())
    for kwargs, key in [
        (dict(), "shrink"),
        (dict(recovery_mode="substitute", spare_nodes=1), "substitute"),
        (dict(recovery_mode="substitute_then_shrink", spare_nodes=1,
              nonblocking_substitution=True), "substitute_nonblocking"),
    ]:
        pol = LegioPolicy(**kwargs)
        strat = make_strategy(pol)
        assert isinstance(strat, RecoveryStrategy)
        assert strat.name == key == pol.strategy_key


def test_new_strategy_is_one_registered_class():
    """The refactor's point: a new recovery mode plugs in without touching
    the executor — register, instantiate, repair."""

    @register_strategy("noop_for_test")
    class NoopStrategy:
        def __init__(self, policy):
            self.policy = policy

        def repair(self, cluster, verdict):
            # handle the fault by ignoring it (worst strategy ever)
            from repro.core import RepairReport
            return RepairReport(trigger=tuple(sorted(verdict)),
                                hierarchical=False, master_failed=False,
                                survivors=cluster.topo.size, mode="noop")

    assert "noop_for_test" in available_strategies()
    cl = VirtualCluster(8)
    cl.strategy = NoopStrategy(cl.policy)
    report = cl.repair({3})
    assert report.mode == "noop" and cl.repairs == [report]


# ---------------------------------------------------------------------------
# property (a): every injected fault -> exactly one terminal RecoveryAction
# ---------------------------------------------------------------------------

@given(n=st.integers(8, 24), data=st.data())
def test_each_fault_yields_exactly_one_terminal_action(n, data):
    mode = data.draw(st.sampled_from(
        ["shrink", "substitute_then_shrink", "substitute"]))
    n_fail = data.draw(st.integers(1, min(4, n - 2)))
    victims = data.draw(st.permutations(list(range(n))))[:n_fail]
    steps = sorted(data.draw(
        st.lists(st.integers(1, 6), min_size=n_fail, max_size=n_fail)))
    pol = LegioPolicy(legion_size=4, recovery_mode=mode,
                      spare_fraction=0.5 if mode != "shrink" else 0.0)
    cl = VirtualCluster(n, policy=pol,
                        injector=FaultInjector.at(list(zip(steps, victims))))
    ex = LegioExecutor(cl, work)
    reports = ex.run(9)
    actions = [a for r in reports for a in r.actions]
    for victim in victims:
        hits = [a for a in actions if victim in a.verdict and a.terminal]
        assert len(hits) == 1, f"node {victim}: {hits}"
        assert hits[0].report is not None
        assert set(hits[0].stage_seconds) == \
            {"detect", "notice", "agree", "plan", "apply"}


# ---------------------------------------------------------------------------
# property (b): the topology epoch never changes while a view is live
# ---------------------------------------------------------------------------

@given(n=st.integers(4, 32), k=st.integers(2, 6), data=st.data())
def test_epoch_frozen_while_view_pinned(n, k, data):
    topo = LegionTopology.build(list(range(n)), k)
    victim = data.draw(st.integers(0, n - 1))
    with topo.pinned() as tv:
        epoch_before = topo.epoch
        assert tv.epoch == epoch_before
        for mutate in (lambda: topo.remove(victim),
                       lambda: topo.substitute(victim, n + 1),
                       lambda: topo.expand(0, n + 2)):
            with pytest.raises(TopologyTornError):
                mutate()
        assert topo.epoch == epoch_before      # nothing slipped through
        assert tv.nodes == sorted(range(n))    # snapshot intact
    # released: mutation proceeds and bumps the epoch
    topo.remove(victim)
    assert topo.epoch == epoch_before + 1
    assert tv.nodes == sorted(range(n))        # old snapshot still frozen


def test_view_is_read_only_and_epoch_stamped():
    topo = LegionTopology.build(list(range(8)), 4)
    tv = topo.view()
    with pytest.raises(TypeError):
        tv.remove(0)
    topo.remove(0)
    assert tv.epoch == topo.epoch - 1          # view pins the old epoch
    assert 0 in tv.nodes and 0 not in topo.nodes


# ---------------------------------------------------------------------------
# property (c): re-spawned spares obey finality, never demote a master
# ---------------------------------------------------------------------------

@given(data=st.data())
def test_respawned_spares_preserve_finality_and_masters(data):
    n = data.draw(st.integers(12, 20))
    n_fail = data.draw(st.integers(3, 6))
    victims = data.draw(st.permutations(list(range(n))))[:n_fail]
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      spare_nodes=1, spare_refill_watermark=1,
                      spare_provision_delay_steps=1, spare_churn_cap=16)
    cl = VirtualCluster(n, policy=pol, injector=FaultInjector.at(
        [(2, v) for v in victims]))
    ex = LegioExecutor(cl, work)
    seen_ids: set[int] = set(range(n)) | set(cl.spare_pool.available)
    for _ in range(14):
        ex.run_step()
        for node in cl.provisioner.delivered:
            assert node >= n                       # above every initial id
        for lg in cl.topo.legions:
            assert lg.master == min(lg.members)    # lowest-rank master rule
            for m in lg.members:
                assert cl.topo.home[m] == lg.index  # assignment is final
        seen_ids |= set(cl.topo.nodes)
    # monotone id allocation: the provisioner never reuses an id
    delivered = cl.provisioner.delivered
    assert delivered == sorted(delivered)
    assert len(set(delivered)) == len(delivered)
    # every surviving original member outranks any spliced spare in its legion
    for lg in cl.topo.legions:
        originals = [m for m in lg.members if m < n]
        if originals:
            assert lg.master == min(originals)


# ---------------------------------------------------------------------------
# heartbeat detector: unknown beats must not poison the sweep (regression)
# ---------------------------------------------------------------------------

def test_heartbeat_beat_from_unregistered_node_does_not_break_sweep():
    """beat() on a never-registered node used to write last_seen without a
    states entry, so the next sweep() raised KeyError. Unknown beats now
    auto-register the node instead."""
    from repro.core import HeartbeatDetector

    det = HeartbeatDetector(timeout=5.0)
    det.register(0)
    det.beat(99, 1.0)                       # never registered before
    assert det.sweep(2.0) == []             # no KeyError, nothing suspect
    assert det.states[99].value == "healthy"
    # the auto-registered node participates in detection like any other
    assert det.sweep(8.0) == [0, 99]
    det.beat(99, 9.0)
    assert det.states[99].value == "healthy"   # suspicion cleared by beat
    # and a beat from a confirmed-failed node stays ignored (permanent)
    det.confirm_failed(0)
    det.beat(0, 10.0)
    assert det.states[0].value == "failed"


# ---------------------------------------------------------------------------
# heartbeat channel (previously dead code) reaches agreement
# ---------------------------------------------------------------------------

def test_heartbeat_timeout_alone_triggers_repair():
    """Acceptance: with final_collective="none" there is no collective error
    channel at all — the dead node is detected purely by its heartbeat going
    stale, and the suspicion flows detect → notice → agree → plan → apply."""
    pol = LegioPolicy(legion_size=4, heartbeat_timeout=3.0)
    cl = VirtualCluster(16, policy=pol, injector=FaultInjector.at([(2, 5)]))
    ex = LegioExecutor(cl, work, final_collective="none")
    reports = ex.run(10)
    assert 5 not in cl.topo.nodes and cl.topo.size == 15
    hits = [(r, a) for r in reports for a in r.actions if 5 in a.verdict]
    assert len(hits) == 1
    report, action = hits[0]
    assert action.sources == (FaultSource.HEARTBEAT,)
    assert report.failed_now == (5,) and report.repair is action.report
    # detection is by timeout, so it lands AFTER the fault step, once the
    # sim clock has advanced past heartbeat_timeout
    assert action.step > 2


def test_collective_channel_still_detects_immediately():
    """The unified pipeline keeps the fast path: collective errors confirm
    at the fault step, well before any heartbeat could expire."""
    pol = LegioPolicy(legion_size=4, heartbeat_timeout=1000.0)
    cl = VirtualCluster(16, policy=pol, injector=FaultInjector.at([(2, 5)]))
    ex = LegioExecutor(cl, work)
    reports = ex.run(4)
    assert reports[2].failed_now == (5,)
    assert FaultSource.COLLECTIVE in reports[2].actions[0].sources


# ---------------------------------------------------------------------------
# straggler soft-fails are surfaced (satellite fix)
# ---------------------------------------------------------------------------

def test_straggler_repair_surfaces_in_step_report():
    """Straggler soft-fails used to be repaired invisibly — cl.repair was
    called but the report discarded and failed_now omitted the lagging
    nodes. Through the pipeline they are first-class actions."""
    import time as _time

    def slow_for_3(node, shard, step):
        if node == 3:
            _time.sleep(0.12)
        return np.ones(4)

    pol = LegioPolicy(legion_size=4, straggler_threshold=2.0)
    cl = VirtualCluster(8, policy=pol)
    cl.straggler.min_latency = 0.05
    cl.straggler.min_samples = 2
    ex = LegioExecutor(cl, slow_for_3)
    reports = ex.run(4)
    lagged = [r for r in reports if 3 in r.failed_now]
    assert lagged, "straggler never surfaced in failed_now"
    action = next(a for a in lagged[0].actions if 3 in a.verdict)
    assert action.sources == (FaultSource.STRAGGLER,)
    assert action.report is not None               # the repair is visible
    assert 3 not in cl.topo.nodes                  # soft-failed out
    # the straggler's contribution still counted in the step it lagged
    assert lagged[0].results.get(3) is not None


# ---------------------------------------------------------------------------
# elastic re-spawn (acceptance e2e)
# ---------------------------------------------------------------------------

def test_e2e_provisioner_restores_full_capacity_after_exhaustion():
    """Acceptance: a campaign with MORE faults than initially-provisioned
    spares under substitute_then_shrink returns to full n_initial capacity
    once the provisioner refills the pool."""
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      spare_nodes=2, spare_refill_watermark=2,
                      spare_provision_delay_steps=2, spare_churn_cap=8)
    cl = VirtualCluster(16, policy=pol, injector=FaultInjector.at(
        [(2, 1), (2, 2), (2, 5), (2, 9)]))     # 4 faults > 2 spares
    ex = LegioExecutor(cl, work)
    reports = ex.run(12)
    # fault step: pool covers 2 slots, the other 2 shrink (degraded).
    # The verdict spans three legions, so the drain emits one scoped action
    # per subtree — the pool exhausts across them.
    fault_reports = [a.report for a in reports[2].actions]
    assert sum(len(r.unfilled) for r in fault_reports) == 2
    assert {r.mode for r in fault_reports} == \
        {"substitute", "substitute_then_shrink"}
    assert min(r.survivors for r in fault_reports) == 14
    # the provisioner re-spawned spares and the backlog healed through the
    # pending-splice path: full capacity is back
    assert cl.topo.size == 16
    assert cl.plan.active_shards == 16
    assert cl.backlog == [] and cl.provisioner.spawned <= 8
    respawn_steps = [r.step for r in reports if r.respawned]
    heal_steps = [r.step for r in reports if r.expanded]
    assert respawn_steps and heal_steps
    assert min(heal_steps) > min(respawn_steps) >= 2 + \
        pol.spare_provision_delay_steps
    # and the pool itself is back at the watermark for the NEXT fault
    assert len(cl.spare_pool.available) >= pol.spare_refill_watermark
    # steady throughput after healing: the full 16-shard reduce returns
    full = sum(range(1, 17))
    spare_shards = sorted(s for a in cl.plan.assignments for s in a.shards)
    assert spare_shards == list(range(16))
    assert reports[-1].reduced[0] == full


def test_provisioner_respects_churn_cap():
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      spare_nodes=1, spare_refill_watermark=1,
                      spare_provision_delay_steps=1, spare_churn_cap=2)
    cl = VirtualCluster(16, policy=pol, injector=FaultInjector.at(
        [(1, 1), (3, 2), (5, 3), (7, 4), (9, 5)]))   # 5 faults, cap 2 respawns
    ex = LegioExecutor(cl, work)
    ex.run(14)
    assert cl.provisioner.spawned == 2             # hard churn ceiling
    # 1 original + 2 re-spawned spares absorbed 3 of 5 faults
    assert cl.topo.size == 16 - 2


def test_provisioner_disabled_without_watermark():
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      spare_nodes=1)
    cl = VirtualCluster(16, policy=pol,
                        injector=FaultInjector.at([(1, 1), (2, 2)]))
    ex = LegioExecutor(cl, work)
    ex.run(8)
    assert not cl.provisioner.enabled
    assert cl.provisioner.spawned == 0 and cl.backlog == []
    assert cl.topo.size == 15                      # stays degraded (PR-1 era)
