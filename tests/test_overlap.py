"""Background (revoke-then-repair) repair: the property hardening pass.

Four properties, each in two flavors (hypothesis for CI, deterministic
campaigns when hypothesis is absent — the conftest stub skips @given):

  a. healthy-subtree collectives never observe a torn epoch mid-repair —
     every schedule issued while a window is open runs over a view whose
     epoch is post-repair and whose node set excludes the window's
     verdict (the structural repair landed before the window opened);
  b. exactly one terminal action per fault, overlap mode included;
  c. reconciliation converges — the overlap path ends at the same final
     topology as the blocking path under the same injector schedule
     (blocking drain as oracle);
  d. message-ledger conservation holds when p2p traffic targets a busy
     (repairing but alive) participant: the envelope stays pending across
     the window, is delivered exactly once after the merge, never
     discarded.
"""
import random

import numpy as np
from hypothesis import given, strategies as st

from repro.core import (
    FaultInjector,
    LegioExecutor,
    LegioPolicy,
    VirtualCluster,
)
from repro.mpi import MsgState, Session


def overlap_policy(k: int = 4, mode: str = "shrink",
                   **kw) -> LegioPolicy:
    extra = {}
    if mode != "shrink":
        extra["spare_fraction"] = 0.5
    extra.update(kw)
    return LegioPolicy(legion_size=k, recovery_mode=mode,
                       repair_overlap=True, **extra)


def campaign_faults(rng: random.Random, n: int,
                    steps: int) -> list[tuple[int, int]]:
    victims = rng.sample(range(n), rng.randint(1, min(4, n - 2)))
    return sorted((rng.randint(1, steps - 2), v) for v in victims)


def work(node, shard, step):
    return np.ones(2) * (shard + 1)


# ---------------------------------------------------------------------------
# property (a): no collective ever observes a torn epoch mid-repair
# ---------------------------------------------------------------------------

def run_epoch_campaign(seed: int, n: int = 24, steps: int = 9) -> int:
    rng = random.Random(seed)
    faults = campaign_faults(rng, n, steps)
    sess = Session(n, policy=overlap_policy(),
                   injector=FaultInjector.at(faults))
    cl = sess.cluster
    comm = sess.world
    observed: list[tuple[int, frozenset]] = []
    comm.attach(lambda op, view: observed.append(
        (view.epoch, view.node_set,
         tuple((br.scope.verdict, br.open_epoch) for br in cl.background))),
        key="torn-check")
    # stamp the post-repair epoch on each window as it opens
    orig = cl._open_window

    def stamping(scope, report):
        orig(scope, report)
        cl.background[-1].open_epoch = cl.topo.epoch
    cl._open_window = stamping

    mid_repair_calls = 0
    for step in range(steps):
        sess.advance(step)
        comm.allreduce({m: np.array([1.0]) for m in cl.live_nodes})
    for epoch, node_set, windows in observed:
        for verdict, open_epoch in windows:
            mid_repair_calls += 1
            # the structure the schedule ran over is post-repair: the
            # torn scope's dead are gone and the epoch is at least the
            # one stamped when the repair landed
            assert not (set(verdict) & node_set)
            assert epoch >= open_epoch
    assert len(cl.live_nodes) == n - len(faults)
    return mid_repair_calls


@given(seed=st.integers(0, 10_000))
def test_no_torn_epoch_mid_repair_property(seed):
    run_epoch_campaign(seed)


def test_no_torn_epoch_mid_repair_deterministic():
    hits = sum(run_epoch_campaign(seed) for seed in range(10))
    assert hits > 0              # the property was actually exercised


# ---------------------------------------------------------------------------
# property (b): exactly one terminal action per fault, overlap mode included
# ---------------------------------------------------------------------------

def run_terminal_campaign(seed: int, n: int = 20, steps: int = 9) -> None:
    rng = random.Random(seed)
    mode = rng.choice(["shrink", "substitute_then_shrink"])
    faults = campaign_faults(rng, n, steps)
    cl = VirtualCluster(n, policy=overlap_policy(mode=mode),
                        injector=FaultInjector.at(faults))
    ex = LegioExecutor(cl, work)
    reports = ex.run(steps)
    actions = [a for r in reports for a in r.actions]
    for _, victim in faults:
        hits = [a for a in actions if victim in a.verdict and a.terminal]
        assert len(hits) == 1, f"node {victim}: {hits}"
        assert hits[0].overlapped     # the charge went to a window


@given(seed=st.integers(0, 10_000))
def test_one_terminal_action_per_fault_property(seed):
    run_terminal_campaign(seed)


def test_one_terminal_action_per_fault_deterministic():
    for seed in range(10):
        run_terminal_campaign(seed)


# ---------------------------------------------------------------------------
# property (c): overlap converges to the blocking path's final topology
# ---------------------------------------------------------------------------

def topo_fingerprint(cl: VirtualCluster):
    return (sorted(cl.topo.nodes),
            sorted((lg.index, tuple(sorted(lg.members)))
                   for lg in cl.topo.legions if lg.members),
            dict(cl.topo.home))


def run_convergence_campaign(seed: int, n: int = 24, steps: int = 9) -> None:
    rng = random.Random(seed)
    mode = rng.choice(["shrink", "substitute_then_shrink"])
    faults = campaign_faults(rng, n, steps)
    finals = []
    for overlap in (False, True):
        pol = overlap_policy(mode=mode) if overlap else LegioPolicy(
            legion_size=4, recovery_mode=mode,
            spare_fraction=0.5 if mode != "shrink" else 0.0)
        cl = VirtualCluster(n, policy=pol,
                            injector=FaultInjector.at(faults))
        ex = LegioExecutor(cl, work)
        ex.run(steps)
        Session.adopt(cl).sync()          # merge any tail window
        finals.append(topo_fingerprint(cl))
    assert finals[0] == finals[1]


@given(seed=st.integers(0, 10_000))
def test_overlap_converges_to_blocking_oracle_property(seed):
    run_convergence_campaign(seed)


def test_overlap_converges_to_blocking_oracle_deterministic():
    for seed in range(10):
        run_convergence_campaign(seed)


# ---------------------------------------------------------------------------
# property (d): ledger conservation with p2p deferred across a window
# ---------------------------------------------------------------------------

def run_deferred_p2p_campaign(seed: int, n: int = 16,
                              steps: int = 8) -> None:
    rng = random.Random(seed)
    fault_step = rng.randint(1, 3)
    victim = rng.randrange(n)
    sess = Session(n, policy=overlap_policy(),
                   injector=FaultInjector.at([(fault_step, victim)]))
    cl = sess.cluster
    comm = sess.world
    deferred, received = [], []

    def drain_deferred():
        for env in deferred:
            if env.state is MsgState.POSTED and comm.probe(env.dst, env.src):
                received.append(comm.recv(env.dst, env.src))

    for step in range(steps):
        sess.advance(step)
        comm.allreduce({m: np.array([1.0]) for m in cl.live_nodes})
        busy = sorted(cl.repairing_participants())
        if busy:
            # mid-window traffic addressed to a repairing-but-alive
            # participant: buffered, never discarded (busy is not dead)
            src = rng.choice([m for m in cl.live_nodes if m not in busy])
            comm.send(src, busy[0], ("deferred", len(deferred)))
            deferred.append(comm.ledger.envelopes[-1])
        else:
            drain_deferred()
    drain_deferred()
    assert deferred                       # the window was actually hit
    ledger = comm.ledger
    assert ledger.posted >= len(deferred)
    assert ledger.conserved()
    # every deferred envelope was delivered exactly once after the merge —
    # the busy destination was alive throughout, so none was discarded
    assert all(e.state is MsgState.DELIVERED for e in deferred)
    assert len(received) == len(deferred)
    assert len(set(received)) == len(received)    # no double delivery


@given(seed=st.integers(0, 10_000))
def test_deferred_p2p_conservation_property(seed):
    run_deferred_p2p_campaign(seed)


def test_deferred_p2p_conservation_deterministic():
    for seed in range(10):
        run_deferred_p2p_campaign(seed)
