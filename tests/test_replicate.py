"""Ring-replicated shard checkpoints: buddy map, settle/rehome, restore ladder."""
import numpy as np
import pytest

from repro.checkpoint.replicate import (
    REPLICA_TAG,
    ReplicaIntegrityError,
    ReplicaUnavailable,
    ShardReplicator,
)
from repro.core import (
    FaultInjector,
    LegionCheckpointer,
    LegionTopology,
    LegioExecutor,
    LegioPolicy,
    VirtualCluster,
    restore_member_state,
)
from repro.core.collectives import LinkModel
from repro.mpi import Session


def work(node, shard, step):
    return np.ones(4) * (shard + 1)


def sub_policy(**kw):
    kw.setdefault("legion_size", 4)
    kw.setdefault("recovery_mode", "substitute_then_shrink")
    kw.setdefault("spare_fraction", 0.25)
    return LegioPolicy(**kw)


def shards_for(topo, width=8):
    return {(lg.index, n): {"w": np.full(width, n, dtype=np.float32)}
            for lg in topo.legions for n in lg.members}


# ---------------------------------------------------------------------------
# buddy map (the POV ring generalized to all members)
# ---------------------------------------------------------------------------

def test_buddy_lives_in_successor_legion():
    topo = LegionTopology.build(list(range(16)), 4)
    for lg in topo.legions:
        succ = topo.successor(lg.index)
        for pos, node in enumerate(lg.members):
            buddy = topo.buddy_of(node)
            assert buddy == succ.members[pos % len(succ.members)]
            assert topo.legion_of(buddy).index == succ.index
    # the master's buddy is exactly the successor master the POV comm names
    for lg in topo.legions:
        assert topo.buddy_of(lg.master) == topo.successor(lg.index).master


def test_buddy_none_with_single_legion():
    topo = LegionTopology.build(list(range(4)), 4)
    assert all(topo.buddy_of(n) is None for n in topo.nodes)
    # and a standalone push on such a topology replicates nothing
    repl = ShardReplicator(link=LinkModel())
    assert repl.push_map(0, topo, shards_for(topo)) == 0
    assert repl.replicas == {} and repl.pushes == 0


def test_buddy_uneven_successor_wraps():
    """Positions wrap mod the successor's size, so every member has a buddy
    even when the successor legion is smaller."""
    topo = LegionTopology.build(list(range(16)), 4)
    topo.remove(9)                       # legion 2 now [8, 10, 11]
    lg1 = topo.legion_of(4)
    succ = topo.successor(lg1.index)
    for node in lg1.members:
        assert topo.buddy_of(node) in succ.members


# ---------------------------------------------------------------------------
# standalone replicator (no ledger: pushes commit directly)
# ---------------------------------------------------------------------------

def test_push_then_restore_roundtrip():
    topo = LegionTopology.build(list(range(16)), 4)
    repl = ShardReplicator(link=LinkModel())
    assert repl.push_map(0, topo, shards_for(topo)) == 16
    assert repl.pushes == 16 and repl.delivered == 16
    state, served = repl.restore(5, topo, failed=set())
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  np.full(8, 5.0, np.float32))
    assert served.node == 5 and served.holder == topo.buddy_of(5)
    assert served.transfer_seconds == repl.transfer_seconds(served.nbytes)
    # consumed: the splice owns it now
    assert 5 not in repl.replicas
    with pytest.raises(ReplicaUnavailable):
        repl.restore(5, topo, failed=set())


def test_restore_refuses_dead_holder_correlated_loss():
    topo = LegionTopology.build(list(range(16)), 4)
    repl = ShardReplicator(link=LinkModel())
    repl.push_map(0, topo, shards_for(topo))
    buddy = topo.buddy_of(5)
    with pytest.raises(ReplicaUnavailable):
        repl.restore(5, topo, failed={buddy})
    assert repl.lost == 1 and 5 not in repl.replicas


def test_restore_refuses_corrupt_replica():
    topo = LegionTopology.build(list(range(16)), 4)
    repl = ShardReplicator(link=LinkModel())
    repl.push_map(0, topo, shards_for(topo))
    repl.replicas[5].arrays["w"][0] += 1.0       # bitrot on the holder
    with pytest.raises(ReplicaIntegrityError):
        repl.restore(5, topo, failed=set())
    assert repl.corrupt == 1 and 5 not in repl.replicas


def test_rehome_follows_ring_mutation():
    """Removing a member shifts the survivors' ring positions: their
    replicas move to the new buddies (live holders), while the removed
    owner's replica is kept for a pending splice."""
    topo = LegionTopology.build(list(range(16)), 4)
    repl = ShardReplicator(link=LinkModel())
    repl.push_map(0, topo, shards_for(topo))
    old_holder = {n: repl.replicas[n].holder for n in (1, 2, 3, 5, 6, 7)}
    topo.remove(4)                       # legion 1 now [5, 6, 7]
    repl.tick(topo, failed={4}, step=1)
    # legion 1's own members shifted position AND legion 0's buddies (who
    # live in legion 1) shifted with them — six rehomes to live holders
    for n in (1, 2, 3, 5, 6, 7):
        assert repl.replicas[n].holder == topo.buddy_of(n)
        assert repl.replicas[n].holder != old_holder[n]
    assert repl.rehomed == 6
    # node 0's replica was held by the dead node 4: correlated loss
    assert 0 not in repl.replicas and repl.lost == 1
    # owner gone, holder alive: the replica waits for the splice
    assert repl.replicas[4].holder == 8


# ---------------------------------------------------------------------------
# the restore ladder (peer first, store on correlated loss)
# ---------------------------------------------------------------------------

def test_splice_restores_from_peer(tmp_path):
    """With the buddy alive, the substituted rank warm-starts from the ring
    replica: RestartRecord.source == "peer" and the charge is the O(shard)
    link transfer, not the store's restore_seconds."""
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    inj = FaultInjector.at([(3, 5)])
    cl = VirtualCluster(16, policy=sub_policy(), injector=inj,
                        checkpointer=ck)
    ex = LegioExecutor(cl, work)
    ex.run(2)
    state = {n: {"w": np.full((2,), float(n))} for n in cl.topo.nodes}
    ck.save(2, cl.topo, lambda n: state[n], sync=True)
    # the pushes ride the session ledger: in flight until the next boundary
    assert len(cl.replicator.inflight) == 16
    ex.run(3)
    assert cl.repairs[-1].substitutions == ((5, 16),)
    np.testing.assert_array_equal(
        np.asarray(cl.restored_state[16]["w"]), np.full((2,), 5.0))
    assert ck.restarts[-1].source == "peer"
    assert len(cl.replicator.served) == 1
    # the splice's restore step was re-costed to the peer transfer
    restore_steps = [st for st in cl.repairs[-1].steps if st.op == "restore"]
    assert restore_steps[0].cost_units < cl.substitute.cost.restore_seconds


def test_correlated_loss_falls_back_to_store(tmp_path):
    """Owner and buddy die together (rack outage spanning adjacent legions):
    the peer rung fails and the splice reads the checkpoint store —
    RestartRecord.source == "checkpoint", state still restored."""
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    buddy = 9                            # buddy_of(5) at n=16, k=4
    inj = FaultInjector.at([(3, 5), (3, buddy)])
    cl = VirtualCluster(16, policy=sub_policy(), injector=inj,
                        checkpointer=ck)
    assert cl.topo.buddy_of(5) == buddy
    ex = LegioExecutor(cl, work)
    ex.run(2)
    state = {n: {"w": np.full((2,), float(n))} for n in cl.topo.nodes}
    ck.save(2, cl.topo, lambda n: state[n], sync=True)
    ex.run(3)
    sources = {r.node: r.source for r in ck.restarts}
    assert sources[5] == "checkpoint"    # buddy dead -> store fallback
    # 5 and 9 live in different legions -> disjoint scopes, one report each
    spare_of = dict(s for r in cl.repairs for s in r.substitutions)
    np.testing.assert_array_equal(
        np.asarray(cl.restored_state[spare_of[5]]["w"]), np.full((2,), 5.0))


def test_checksum_mismatch_falls_back_to_store(tmp_path):
    """A corrupt replica is dropped — never spliced — and the store serves
    the restore instead; the run does not crash."""
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    inj = FaultInjector.at([(3, 5)])
    cl = VirtualCluster(16, policy=sub_policy(), injector=inj,
                        checkpointer=ck)
    ex = LegioExecutor(cl, work)
    ex.run(2)
    state = {n: {"w": np.full((2,), float(n))} for n in cl.topo.nodes}
    ck.save(2, cl.topo, lambda n: state[n], sync=True)
    # the push is still in flight — rot the bits before it settles
    record = next(r for _, r in cl.replicator.inflight if r.owner == 5)
    record.arrays["w"][0] += 1.0
    ex.run(3)
    assert cl.replicator.corrupt == 1
    assert ck.restarts[-1].source == "checkpoint"
    np.testing.assert_array_equal(
        np.asarray(cl.restored_state[16]["w"]), np.full((2,), 5.0))


def test_ladder_without_checkpoint_or_replica_is_cold(tmp_path):
    cl = VirtualCluster(16, policy=sub_policy())
    outcome = restore_member_state(cl, 1, 5)
    assert outcome.state is None and outcome.source == "none"
    assert outcome.cost_seconds == cl.substitute.cost.restore_seconds


def test_peer_replication_off_is_store_only(tmp_path):
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    inj = FaultInjector.at([(3, 5)])
    cl = VirtualCluster(16, policy=sub_policy(peer_replication=False),
                        injector=inj, checkpointer=ck)
    ex = LegioExecutor(cl, work)
    ex.run(2)
    ck.save(2, cl.topo, lambda n: {"w": np.full((2,), float(n))}, sync=True)
    assert cl.replicator.pushes == 0
    ex.run(3)
    assert ck.restarts[-1].source == "checkpoint"


# ---------------------------------------------------------------------------
# ledger-borne replication (in flight across a step boundary)
# ---------------------------------------------------------------------------

def test_replication_rides_the_ledger_and_conserves():
    """Synthetic heartbeat replication through a live Session: envelopes
    post under REPLICA_TAG, settle at the next boundary, a dead holder's
    copy is lost (never delivered twice), and the world ledger's
    conservation invariant holds with replication in flight."""
    with Session(16, policy=sub_policy(),
                 injector=FaultInjector.at([(3, 5)])) as mpi:
        cl = mpi.cluster
        cl.replicator.heartbeat_every = 1
        for step in range(6):
            mpi.advance(step)
            mpi.world.allreduce(
                {n: np.ones(2) for n in cl.live_nodes})
        ledger = mpi.world.ledger
        replica_envs = [e for e in ledger.envelopes if e.tag == REPLICA_TAG]
        assert replica_envs, "no replication traffic on the ledger"
        assert ledger.conserved()
        assert cl.replicator.delivered > 0
        # settled replicas match the current ring
        for owner, record in cl.replicator.replicas.items():
            if owner in cl.topo.nodes:
                buddy = cl.topo.buddy_of(owner)
                assert buddy is None or record.holder in cl.topo.nodes
        # no envelope settles twice: every delivery and every in-flight
        # record traces back to a distinct push (`lost` can tally a replica
        # that settled and was later dropped with its holder, so it is not
        # part of this identity)
        assert (cl.replicator.delivered + len(cl.replicator.inflight)
                <= cl.replicator.pushes)
        # node 5's death cost at least one replica its holder
        assert cl.replicator.lost >= 1
