"""End-to-end behaviour tests: the drivers and the paper's headline claims."""
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_with_faults(capsys):
    rc = train_mod.main([
        "--arch", "llama3.2-3b", "--steps", "8", "--nodes", "8",
        "--fail", "3:2", "--per-shard-batch", "2", "--seq-len", "32",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "REPAIR" in out
    assert "7 survivors" in out


def test_stop_policy_via_executor():
    """The STOP root policy lives at the collective seam (paper §IV) —
    training has no rooted op, so the executor path is where it fires."""
    import numpy as np
    from repro.core import (FaultInjector, LegioExecutor, LegioPolicy,
                            RootFailedError, VirtualCluster)
    cl = VirtualCluster(4, policy=LegioPolicy(root_failure_policy="stop"),
                        injector=FaultInjector.at([(0, 0)]))
    ex = LegioExecutor(cl, lambda n, s, t: np.ones(2), final_collective="bcast",
                       root=0)
    with pytest.raises(RootFailedError):
        ex.run_step()


def test_serve_driver_requeue(capsys):
    rc = serve_mod.main([
        "--requests", "12", "--nodes", "4", "--batch-per-node", "2",
        "--decode-tokens", "2", "--prompt-len", "16", "--fail", "1:1",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "completed: 12" in out


def test_serve_driver_drop_abandons(capsys):
    rc = serve_mod.main([
        "--requests", "12", "--nodes", "4", "--batch-per-node", "2",
        "--decode-tokens", "2", "--prompt-len", "16", "--fail", "1:1",
        "--no-requeue",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "repairs: 1" in out


def test_headline_claim_no_restart():
    """The paper's core claim: the run CONTINUES through a fault — total
    steps executed equals the requested count, never a restart-from-zero."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.core import FaultInjector, ResilientTrainer, VirtualCluster

    cfg = get_smoke_config("llama3.2-3b")
    tc = TrainConfig(total_steps=10, warmup_steps=2)
    cl = VirtualCluster(6, injector=FaultInjector.at([(4, 1), (4, 2)]))
    tr = ResilientTrainer(cfg, tc, cl, per_shard_batch=2, seq_len=32)
    reports = tr.run(10)
    assert [r.step for r in reports] == list(range(10))
    assert reports[4].repair is not None
    assert len(cl.live_nodes) == 4
    assert np.isfinite(reports[-1].loss)
