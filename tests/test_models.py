"""Per-arch smoke tests + decode/forward consistency (assignment item (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models import api


def smoke_batch(cfg, key, B=2, S=32):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["embeds"] = jax.random.normal(key, (B, 24, cfg.d_model))
    elif cfg.frontend == "patch":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch, key):
    """One forward/train step on the reduced config: shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, key)
    batch = smoke_batch(cfg, key)
    loss, metrics = api.train_loss(cfg, params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss)
    assert metrics["accuracy"] >= 0
    grads = jax.grad(lambda p: api.train_loss(cfg, p, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode(arch, key):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, key)
    B, S, MAX = 2, 16, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["embeds"] = jax.random.normal(key, (B, 16, cfg.d_model))
    elif cfg.frontend == "patch":
        kw["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    logits, cache = api.prefill(cfg, params, tokens, MAX, **kw)
    assert logits.shape == (B, 1, cfg.vocab_size)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(cfg, params, cache, nxt)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-130m", "hymba-1.5b"])
def test_decode_matches_forward(arch, key):
    """Teacher-forced decode must reproduce the full forward's logits."""
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, key)
    B, S = 1, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    # teacher-forced decode from a 4-token prefill
    _, cache = api.prefill(cfg, params, tokens[:, :4], S + 4)
    stepwise = {}
    for t in range(4, S):
        logits, cache = api.decode_step(cfg, params, cache, tokens[:, t:t + 1])
        stepwise[t] = logits[:, 0]
    # spot-check three positions against the full-prefix forward
    for t in (5, 8, 11):
        ref_logits, _ = api.prefill(cfg, params, tokens[:, :t + 1], S + 4)
        np.testing.assert_allclose(
            np.asarray(stepwise[t]), np.asarray(ref_logits[:, -1]),
            atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full-size config carries the published numbers (sanity pin)."""
    cfg = get_config(arch)
    published = {
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }[arch]
    L, D, H, K, F, V = published
    assert cfg.n_layers == L and cfg.d_model == D and cfg.vocab_size == V
    if cfg.family != "ssm":
        assert cfg.n_heads == H and cfg.n_kv_heads == K and cfg.d_ff == F
    if arch == "mamba2-130m":
        assert cfg.ssm_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
    if arch == "gemma-7b":
        assert cfg.head_dim == 256 and cfg.activation == "geglu"
    if arch in ("mixtral-8x22b", "grok-1-314b"):
        assert cfg.n_experts == 8 and cfg.experts_per_token == 2
    if arch == "mixtral-8x22b":
        assert cfg.sliding_window > 0


def test_param_counts_match_formula(key):
    """api.count_params == ModelConfig.total_params on real smoke params."""
    for arch in ("llama3.2-3b", "mixtral-8x22b", "mamba2-130m", "whisper-tiny",
                 "hymba-1.5b"):
        cfg = get_smoke_config(arch)
        params = api.init_params(cfg, key)
        assert api.count_params(params) == cfg.total_params(), arch


def test_shape_applicability_grid():
    """40 cells: long_500k runs only for sub-quadratic archs (DESIGN.md)."""
    # starcoder2-7b ships a 4096 sliding window (faithful config), so its
    # 524k-decode is ring-buffer-bounded too
    expect_500k = {"mixtral-8x22b", "starcoder2-7b", "mamba2-130m", "hymba-1.5b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, _ = shape_applicable(cfg, SHAPES["long_500k"])
        assert ok == (arch in expect_500k), arch
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            ok, _ = shape_applicable(cfg, SHAPES[s])
            assert ok


def test_moe_routing_properties(key):
    """Top-2 routing: combine weights sum to <=1, dropped fraction sane."""
    from repro.models.moe import moe_ffn
    cfg = get_smoke_config("mixtral-8x22b")
    params = api.init_params(cfg, key)
    lp = jax.tree.map(lambda x: x[0], params["layers"])  # layer 0
    x = jax.random.normal(key, (64, cfg.d_model), jnp.float32)
    y, m = moe_ffn(cfg, lp["moe"], x)
    assert y.shape == x.shape
    assert 0.0 <= float(m.dropped_fraction) < 0.5
    assert float(m.aux_loss) > 0.5               # ~1 when balanced (E·Σf·p)
