"""Gradient compression on the cross-legion hop (beyond-paper feature)."""
import numpy as np

from repro.core import (
    FaultInjector,
    HierarchicalCollectives,
    LegioExecutor,
    LegioPolicy,
    VirtualCluster,
)
from repro.core.hierarchy import LegionTopology


def topo16():
    return LegionTopology.build(list(range(16)), 4)


def test_int8_cross_hop_accuracy_and_volume():
    topo = topo16()
    residuals = {}
    plain = HierarchicalCollectives(topo)
    comp = HierarchicalCollectives(topo, compression="int8",
                                   residuals=residuals)
    rng = np.random.default_rng(0)
    contributions = {n: rng.normal(size=256).astype(np.float32)
                     for n in topo.nodes}
    exact = plain.reduce(0, contributions).data[0]
    approx = comp.reduce(0, contributions).data[0]
    # int8 per-master quantization: small relative error on the sum
    err = np.abs(approx - exact).max() / np.abs(exact).max()
    assert err < 0.05
    # the slow (global) stage moved ~4x fewer bytes -> less sim time
    t_plain = [s for s in plain.reduce(0, contributions).stages if s[0] == "global"]
    t_comp = [s for s in comp.reduce(0, contributions).stages if s[0] == "global"]
    assert t_comp[0][2] < t_plain[0][2]
    assert residuals  # error feedback persisted per master


def test_error_feedback_converges_over_steps():
    """Repeated compressed reductions of the SAME gradient: the running mean
    converges to the exact value (error feedback flushes the residual)."""
    topo = topo16()
    residuals = {}
    comp = HierarchicalCollectives(topo, compression="topk",
                                   topk_fraction=0.25, residuals=residuals)
    rng = np.random.default_rng(1)
    contributions = {n: rng.normal(size=64).astype(np.float32)
                     for n in topo.nodes}
    exact = HierarchicalCollectives(topo).reduce(0, contributions).data[0]
    acc = np.zeros(64)
    n_steps = 12
    for _ in range(n_steps):
        acc += comp.reduce(0, contributions).data[0]
    np.testing.assert_allclose(acc / n_steps, exact, atol=0.35 * np.abs(exact).max())


def test_executor_with_compression_policy():
    cl = VirtualCluster(
        16, policy=LegioPolicy(legion_size=4, grad_compression="int8"),
        injector=FaultInjector.at([(1, 5)]))
    ex = LegioExecutor(cl, lambda n, s, t: np.ones(8, np.float32) * (s + 1))
    reports = ex.run(3)
    # results still correct within quantization error, faults still handled
    expected = float(sum(range(1, 17)) - 6)
    assert abs(reports[2].reduced[0] - expected) / expected < 0.05
    assert reports[1].repair is not None
    assert cl.compress_residuals          # persisted on the cluster


def test_compression_skipped_for_nonsum_ops():
    """max-reduce is not sum-compatible: compression must bypass."""
    topo = topo16()
    comp = HierarchicalCollectives(topo, compression="int8", residuals={})
    contributions = {n: np.full(4, float(n)) for n in topo.nodes}
    res = comp.reduce(0, contributions, np.maximum)
    np.testing.assert_array_equal(res.data[0], np.full(4, 15.0))