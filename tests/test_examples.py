"""Every example under examples/ imports cleanly and exposes main().

Import-only by design: the walkthroughs themselves are budgeted at ~60 s
each (resilient_training regressed past that once — the cap is now part of
its contract), which is example-runner territory, not tier-1. An import
still catches the common breakage: a renamed symbol in repro.* that an
example references.
"""
import importlib.util
import pathlib

import pytest

EXAMPLES_DIR = pathlib.Path(__file__).parent.parent / "examples"
EXAMPLE_FILES = sorted(EXAMPLES_DIR.glob("*.py"))


def _load(path: pathlib.Path):
    spec = importlib.util.spec_from_file_location(f"example_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_examples_exist():
    assert len(EXAMPLE_FILES) >= 7


@pytest.mark.parametrize("path", EXAMPLE_FILES, ids=lambda p: p.stem)
def test_example_imports_and_has_main(path):
    mod = _load(path)
    assert callable(getattr(mod, "main", None)), \
        f"{path.name} must expose a main() entry point"
