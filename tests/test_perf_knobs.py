"""Performance knobs must never change model semantics.

Every §Perf lever (two-level scan, ZeRO-2 gather, remat policy, xent chunk,
activation sharding mode, MoE group size) is a pure execution-plan change:
the loss on identical params/batch must match the default configuration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import api

KNOBS = [
    {"scan_block": 2},
    {"fsdp_gather": "step"},
    {"remat": "dots"},
    {"remat": "none"},
    {"scan_block": 2, "fsdp_gather": "step", "remat": "dots"},
    {"xent_chunk": 8},
    {"act_shard": "none"},
    {"act_shard": "batch_seq"},
]


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x22b"])
def test_knobs_preserve_loss(arch, key):
    cfg = get_smoke_config(arch).replace(n_layers=4, remat="full")
    params = api.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    base, _ = api.train_loss(cfg, params, batch)
    for kw in KNOBS:
        if arch == "mixtral-8x22b" and kw.get("scan_block"):
            continue  # 2 layers after replace(n_layers=4)? keep divisible
        loss, _ = api.train_loss(cfg.replace(**kw), params, batch)
        np.testing.assert_allclose(float(base), float(loss), rtol=1e-5,
                                   err_msg=str(kw))


def test_moe_group_size_invariance(key):
    """Group size only affects capacity granularity at full load; with a
    loose capacity factor the output is identical across group sizes."""
    cfg = get_smoke_config("mixtral-8x22b").replace(
        moe_capacity_factor=8.0, remat="none")
    params = api.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    # compare the cross-entropy (the routed OUTPUT): the load-balance aux
    # metric legitimately varies with grouping (per-group f_e·p_e averages)
    nlls = []
    for gs in (32, 64, 128):
        _, metrics = api.train_loss(cfg.replace(moe_group_size=gs), params, batch)
        nlls.append(float(metrics["nll"]))
    np.testing.assert_allclose(nlls[0], nlls[1], rtol=2e-5)
    np.testing.assert_allclose(nlls[0], nlls[2], rtol=2e-5)


def test_gradients_match_across_knobs(key):
    """Remat/scan restructuring must leave gradients identical too."""
    cfg = get_smoke_config("llama3.2-3b").replace(n_layers=4, remat="full")
    params = api.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    def gnorm(c):
        g = jax.grad(lambda p: api.train_loss(c, p, batch)[0])(params)
        return float(jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                                  for x in jax.tree.leaves(g))))

    base = gnorm(cfg)
    for kw in ({"scan_block": 2}, {"remat": "dots"}, {"fsdp_gather": "step"}):
        np.testing.assert_allclose(base, gnorm(cfg.replace(**kw)), rtol=1e-4,
                                   err_msg=str(kw))
