"""Counter-based data pipeline: restart-exactness properties."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.data.pipeline import global_batch_for_step, make_batch


@given(seed=st.integers(0, 2**20), step=st.integers(0, 1000),
       shard=st.integers(0, 64))
def test_determinism(seed, step, shard):
    a = make_batch(seed, step, shard, batch=2, seq_len=16, vocab_size=97)
    b = make_batch(seed, step, shard, batch=2, seq_len=16, vocab_size=97)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_shards_differ():
    a = make_batch(0, 0, 0, batch=2, seq_len=32, vocab_size=97)
    b = make_batch(0, 0, 1, batch=2, seq_len=32, vocab_size=97)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_steps_differ():
    a = make_batch(0, 0, 0, batch=2, seq_len=32, vocab_size=97)
    b = make_batch(0, 1, 0, batch=2, seq_len=32, vocab_size=97)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


@given(seed=st.integers(0, 100), step=st.integers(0, 50))
def test_next_token_alignment(seed, step):
    b = make_batch(seed, step, 0, batch=2, seq_len=24, vocab_size=53)
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))


def test_tokens_in_vocab():
    b = make_batch(3, 7, 2, batch=4, seq_len=64, vocab_size=31)
    assert int(jnp.max(b["tokens"])) < 31
    assert int(jnp.min(b["tokens"])) >= 0


def test_global_batch_is_shard_concat():
    g = global_batch_for_step(0, 5, global_batch=8, seq_len=16,
                              vocab_size=97, n_shards=4)
    s1 = make_batch(0, 5, 1, batch=2, seq_len=16, vocab_size=97)
    np.testing.assert_array_equal(
        np.asarray(g["tokens"][2:4]), np.asarray(s1["tokens"]))


def test_structure_is_learnable():
    """The Markov stream must beat uniform entropy — a bigram table predicts
    most transitions (this is what makes example losses decrease)."""
    b = make_batch(0, 0, 0, batch=8, seq_len=256, vocab_size=64)
    toks = np.asarray(b["tokens"])
    # count repeated (prev -> next) transitions
    trans = {}
    for row in toks:
        for a, c in zip(row[:-1], row[1:]):
            trans.setdefault(int(a), []).append(int(c))
    agree = sum(max(np.bincount(v).max(), 0) for v in trans.values())
    total = sum(len(v) for v in trans.values())
    # the (a, b) affine params vary per sequence, so a global bigram table
    # is an underestimate of the structure — still far above uniform (1/64)
    assert agree / total > 0.15
