"""Serve subsystem: at-least-once re-enqueue, exactly-once completion,
no stall on healthy legions — for every recovery mode; plus the
continuous-batching surface (phase split, decode migration, slack
scheduling, admission control, deterministic dispatch)."""
import math

import pytest
from hypothesis import given, strategies as st

from repro.core import FaultInjector, LegioPolicy, VirtualCluster
from repro.serve import (
    RECOVERY_PRESETS as MODES,
    Arrival,
    LegionQueue,
    MicroBatcher,
    Request,
    RequestRouter,
    ServeEngine,
    TrafficGenerator,
    recovery_preset,
)


def work(node, batch, step):
    return {r.rid: float(r.rid) for r in batch}


def make_engine(n=16, mode="shrink", faults=(), microbatch=3, **kw):
    pol = LegioPolicy(legion_size=4, serve_microbatch=microbatch,
                      **recovery_preset(mode, spare_fraction=0.5))
    cl = VirtualCluster(n, policy=pol,
                        injector=FaultInjector.at(list(faults)))
    return ServeEngine(cl, work, **kw)


def queued_rids(engine):
    return {r.rid for q in engine.router.queues.values() for r in q._q}


def inflight_rids(engine):
    return {r.rid for b in engine._inflight.values() for r in b}


# ---------------------------------------------------------------------------
# property: no request is lost or double-completed across an injected fault
# ---------------------------------------------------------------------------

@given(data=st.data())
def test_no_request_lost_or_double_completed(data):
    mode = data.draw(st.sampled_from(sorted(MODES)))
    n = data.draw(st.integers(8, 24))
    n_fail = data.draw(st.integers(1, min(4, n - 4)))
    victims = data.draw(st.permutations(list(range(n))))[:n_fail]
    steps = data.draw(st.lists(st.integers(0, 4),
                               min_size=n_fail, max_size=n_fail))
    total = data.draw(st.integers(20, 120))
    eng = make_engine(n=n, mode=mode, faults=list(zip(steps, victims)))
    eng.submit(total)
    rep = eng.serve(max_rounds=200)
    # exactly-once from the client's view: every id, once, no extras
    assert sorted(eng.completed) == list(range(total))
    assert rep.completed == total
    m = rep.metrics_summary
    assert m["parked"] == 0 and m["abandoned"] == 0
    # completions are unique per id in the metrics ledger too
    seen = [r.rid for r in eng.metrics.completions]
    assert len(seen) == len(set(seen))


# ---------------------------------------------------------------------------
# deterministic coverage of the same property (runs without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", sorted(MODES))
def test_zero_loss_across_fault_each_mode(mode):
    """A mid-campaign fault with batches in flight loses nothing: the
    verdict node's requests are re-enqueued (at-least-once) and every id
    completes exactly once."""
    eng = make_engine(mode=mode, faults=[(1, 5), (2, 0)])
    eng.submit(150)
    rep = eng.serve(max_rounds=100)
    assert sorted(eng.completed) == list(range(150))
    m = rep.metrics_summary
    assert m["requeues"] > 0, "faults landed mid-flight: must redeliver"
    assert m["duplicates_suppressed"] == 0
    assert m["max_attempts_seen"] >= 2   # a redelivered request completed
    rids = [r.rid for r in eng.metrics.completions]
    assert len(rids) == len(set(rids)) == 150


def test_request_accounting_invariant_every_round():
    """At every round boundary each request id is in exactly one bucket:
    queued, in-flight, or completed (the queue.py ownership invariant)."""
    eng = make_engine(mode="nonblocking", faults=[(1, 3), (3, 8)])
    eng.submit(90)
    submitted = set(range(90))
    for _ in range(40):
        if not eng.pending:
            break
        eng.run_round()
        q, f, c = queued_rids(eng), inflight_rids(eng), set(eng.completed)
        assert q | f | c == submitted
        assert not (q & f) and not (q & c) and not (f & c)
    assert set(eng.completed) == submitted


# ---------------------------------------------------------------------------
# dedup guard: redelivery of a completed request is suppressed
# ---------------------------------------------------------------------------

def test_dedup_guard_suppresses_double_completion():
    eng = make_engine()
    eng.submit(4)
    eng.run_round()
    assert 0 in eng.completed
    ghost = Request(rid=0, enqueue_step=0, attempts=1)
    eng._redeliver(ghost)                    # stale redelivery of a done id
    assert eng.metrics.duplicates_suppressed == 1
    assert eng.metrics.requeues == 0
    assert len(eng.completed) == 4           # nothing re-entered the system


def test_partial_work_result_redelivers_not_completes():
    """A work_fn that drops an id (partial result dict) is a delivery
    failure: the request redelivers instead of completing as None."""
    first_try_dropped = []

    def flaky(node, batch, step):
        out = {}
        for r in batch:
            if r.rid == 7 and r.attempts == 1:
                first_try_dropped.append(r.rid)
                continue
            out[r.rid] = float(r.rid)
        return out

    cl = VirtualCluster(16, policy=LegioPolicy(legion_size=4,
                                               serve_microbatch=3))
    eng = ServeEngine(cl, flaky)
    eng.submit(30)
    eng.serve(max_rounds=20)
    assert first_try_dropped == [7]
    assert eng.completed[7] == 7.0          # real result, via redelivery
    assert sorted(eng.completed) == list(range(30))
    assert eng.metrics.requeues >= 1


def test_completed_results_are_write_once():
    eng = make_engine()
    eng.submit(2)
    eng.run_round()
    first = eng.completed[1]
    eng._complete(Request(rid=1, enqueue_step=0), -999.0, 1, 0)
    assert eng.completed[1] == first
    assert eng.metrics.duplicates_suppressed == 1


# ---------------------------------------------------------------------------
# DROP (requeue=False) and the redelivery ceiling
# ---------------------------------------------------------------------------

def test_drop_mode_abandons_instead_of_requeueing():
    eng = make_engine(faults=[(0, 2)], requeue=False)
    eng.submit(48)
    rep = eng.serve(max_rounds=50)
    m = rep.metrics_summary
    assert m["abandoned"] > 0 and m["requeues"] == 0
    assert rep.completed + m["abandoned"] == 48
    assert not set(eng.metrics.abandoned) & set(eng.completed)


def test_max_attempts_parks_not_drops():
    pol = LegioPolicy(legion_size=4, serve_microbatch=3,
                      serve_max_attempts=1)
    cl = VirtualCluster(16, policy=pol,
                        injector=FaultInjector.at([(0, 5)]))
    eng = ServeEngine(cl, work)
    eng.submit(48)
    eng.serve(max_rounds=50)
    parked = set(eng.metrics.parked)
    assert parked, "requests on the dead node hit the ceiling"
    assert not parked & set(eng.completed)
    assert parked | set(eng.completed) == set(range(48))


# ---------------------------------------------------------------------------
# router: queues survive topology changes
# ---------------------------------------------------------------------------

def test_whole_legion_death_rehomes_its_queue():
    """All members of one legion die in one round — its undispatched queue
    must re-home to surviving legions, not strand."""
    eng = make_engine(faults=[(0, 4), (0, 5), (0, 6), (0, 7)], microbatch=1)
    eng.submit(160)                      # deep queues: plenty undispatched
    rep = eng.serve(max_rounds=200)
    assert rep.completed == 160
    assert eng.router.rerouted > 0, "the dead legion's queue was re-homed"
    assert all(idx != 1 for idx in eng.router.queues), \
        "legion 1 left the ring; its queue must be gone"


def test_router_least_loaded_sharding():
    router = RequestRouter()
    cl = VirtualCluster(16, policy=LegioPolicy(legion_size=4))
    reqs = [Request(rid=i) for i in range(40)]
    router.submit(reqs, cl.topo.view())
    sizes = {i: len(q) for i, q in router.queues.items()}
    assert sum(sizes.values()) == 40
    assert max(sizes.values()) - min(sizes.values()) <= 1


# ---------------------------------------------------------------------------
# e2e: a mid-campaign fault keeps p99 bounded on healthy legions
# ---------------------------------------------------------------------------

def test_e2e_p99_bounded_on_healthy_legions():
    """Structural acceptance (no wall-clock): during the repair, legions
    untouched by the fault keep dispatching every round, and their
    round-latency p99 does not exceed the campaign-wide p99 — serving
    overlaps the repair instead of barriering on it."""
    faults = [(2, 1), (3, 5)]
    eng = make_engine(mode="nonblocking", faults=faults, microbatch=2)
    cl = eng.cluster
    submitted = 0
    rounds = 0
    while submitted < 240 or eng.pending:
        if rounds < 8:
            eng.submit(30)
            submitted += 30
        eng.run_round()
        rounds += 1
        assert rounds < 100
    assert sorted(eng.completed) == list(range(240))

    fault_legions = {cl.topo.home[v] for _, v in faults}
    healthy = [lg.index for lg in cl.topo.legions
               if lg.members and lg.index not in fault_legions]
    assert healthy, "the campaign must leave untouched legions"
    # no stall: every repair-window round dispatched on every healthy legion
    for lg in healthy:
        assert eng.metrics.stalled_rounds(lg, 2, 4) == 0
    p99_all = eng.metrics.latency_percentile(99)
    p99_healthy = eng.metrics.latency_percentile(99, set(healthy))
    assert p99_healthy <= p99_all
    # the repaired cluster is back at full capacity (nonblocking splices)
    assert cl.topo.size == 16


def test_healthy_legions_dispatch_during_repair_round():
    """The round that repairs legion L still dispatches real batches on
    every other legion (the RoundReport shows both in one round)."""
    eng = make_engine(mode="nonblocking", faults=[(1, 5)])
    eng.submit(200)
    eng.run_round()                                   # round 0: warm
    rep = eng.run_round()                             # round 1: fault + repair
    assert any(5 in a.verdict for a in rep.actions)
    victim_legion = eng.cluster.topo.home[5]
    dispatched_legions = {
        eng.cluster.topo.home.get(n, victim_legion)
        for n in rep.dispatched}
    assert len(dispatched_legions - {victim_legion}) >= 3, \
        "all other legions dispatched in the repair round"


# ---------------------------------------------------------------------------
# continuous batching: multi-tick service, phase split, in-flight windows
# ---------------------------------------------------------------------------

def arr(prefill=1, decode=0, slo=math.inf, user=0):
    return Arrival(user=user, slo_class="standard", slo_seconds=slo,
                   prefill_ticks=prefill, decode_ticks=decode)


def test_multi_tick_service_spans_rounds_with_phase_accounting():
    """A prefill-2/decode-3 request occupies its slot for five ticks, then
    completes; every tick lands in the right phase bucket."""
    eng = make_engine(n=4, microbatch=1)
    eng.submit([arr(prefill=2, decode=3)])
    for _ in range(4):
        eng.run_round()
        assert not eng.completed, "5 ticks of service cannot finish in 4"
    eng.run_round()
    assert sorted(eng.completed) == [0]
    assert eng.metrics.phase_ticks == {"prefill": 2, "decode": 3}
    rec = eng.metrics.completions[0]
    assert rec.latency_sim == pytest.approx(
        5 * eng.cluster.policy.step_sim_seconds)


def test_window_admits_while_previous_batch_still_decoding():
    """With window=2 a node takes a second micro-batch while its first is
    mid-decode — the in-flight window replaces the round barrier."""
    eng = make_engine(n=4, microbatch=1, window=2)
    eng.submit([arr(decode=6), arr(decode=6)])
    eng.run_round()
    inflight = {n: len(b) for n, b in eng._inflight.items()}
    assert sum(inflight.values()) == 2, "both admitted before either done"


def test_default_specs_match_legacy_single_round_completion():
    """Payload-less submits (1 prefill tick, 0 decode) complete in the
    round they are dispatched — byte-compatible with the pre-window
    engine."""
    eng = make_engine()
    eng.submit(9)
    rep = eng.run_round()
    assert rep.completed_now == 9
    assert eng.metrics.phase_ticks == {"prefill": 9, "decode": 0}


def test_round_seconds_records_sim_and_wall():
    """Every round records its duration on both clocks: the simulated one
    (deterministic — one tick per continuous round) and perf_counter."""
    eng = make_engine(n=8)
    eng.submit(12)
    eng.serve(max_rounds=10)
    tick = eng.cluster.policy.step_sim_seconds
    assert eng.metrics.round_seconds, "rounds must be recorded"
    for row in eng.metrics.round_seconds.values():
        assert row["sim"] == pytest.approx(tick)
        assert row["wall"] >= 0.0


# ---------------------------------------------------------------------------
# decode-state migration: progress survives the node, never double-completes
# ---------------------------------------------------------------------------

def test_migration_preserves_decode_progress():
    """A request mid-decode on a dying node re-enters a queue with its
    decode progress intact: total decode ticks spent equal the spec, with
    the preserved ticks never re-spent."""
    eng = make_engine(n=16, mode="nonblocking", microbatch=1,
                      faults=[(3, 0)])
    eng.submit([arr(decode=8)])         # lands on legion 0 / node 0
    eng.serve(max_rounds=40)
    assert sorted(eng.completed) == [0]
    assert eng.metrics.migrations == 1
    assert eng.metrics.decode_ticks_preserved >= 1
    # preserved ticks were not re-executed: spend equals the spec exactly
    assert eng.metrics.phase_ticks["decode"] == 8
    assert len(eng.metrics.completions) == 1
    assert eng.metrics.completions[0].migrated


def test_migration_disabled_restarts_from_prefill():
    """serve_migrate_decode=False is the restart baseline: same fault,
    zero migrations, and the decode ticks before the fault are re-spent."""
    pol = LegioPolicy(legion_size=4, serve_microbatch=1,
                      serve_migrate_decode=False,
                      **recovery_preset("nonblocking", spare_fraction=0.5))
    cl = VirtualCluster(16, policy=pol, injector=FaultInjector.at([(3, 0)]))
    eng = ServeEngine(cl, work)
    eng.submit([arr(decode=8)])
    eng.serve(max_rounds=40)
    assert sorted(eng.completed) == [0]
    assert eng.metrics.migrations == 0
    assert eng.metrics.phase_ticks["decode"] > 8, \
        "restart must re-spend the pre-fault decode ticks"
    assert len(eng.metrics.completions) == 1


@pytest.mark.parametrize("mode", sorted(MODES))
def test_migration_never_double_completes_under_faults(mode):
    """Decode-heavy traffic + mid-campaign faults in every recovery mode:
    exactly one completion per id, migrated or not."""
    eng = make_engine(n=16, mode=mode, microbatch=2,
                      faults=[(2, 1), (3, 5)])
    eng.submit([arr(decode=4, user=i) for i in range(60)])
    eng.serve(max_rounds=120)
    assert sorted(eng.completed) == list(range(60))
    rids = [r.rid for r in eng.metrics.completions]
    assert len(rids) == len(set(rids)) == 60
    assert eng.metrics.starved_rounds() == 0


# ---------------------------------------------------------------------------
# lock-step baseline: the barrier stretches rounds; continuous beats it
# ---------------------------------------------------------------------------

def test_lockstep_round_stretches_to_slowest_batch():
    eng = make_engine(n=4, microbatch=1, continuous=False)
    eng.submit([arr(decode=5), arr(decode=0)])
    rep = eng.run_round()
    tick = eng.cluster.policy.step_sim_seconds
    assert rep.completed_now == 2, "lock-step drains everything per round"
    assert rep.sim_seconds == pytest.approx(6 * tick), \
        "the round lasts as long as its slowest batch (1+5 ticks)"


def test_continuous_beats_lockstep_p99_at_same_offered_load():
    """The tentpole claim in miniature: identical arrival schedule, same
    faults — continuous batching's p99 (sim-seconds) is strictly better
    than the lock-step barrier's."""
    gen = TrafficGenerator(8.0, seed=3)
    sched = []
    for t in range(12):
        sched.extend((float(t + 1), a)
                     for a in gen.arrivals(float(t), float(t + 1)))
    p99 = {}
    for continuous in (True, False):
        eng = make_engine(n=16, mode="nonblocking", microbatch=2,
                          faults=[(3, 5)], continuous=continuous)
        i, rounds = 0, 0
        while rounds < 200:
            now = eng.cluster.clock.sim_seconds
            while i < len(sched) and sched[i][0] <= now:
                j = i
                while j < len(sched) and sched[j][0] <= now:
                    j += 1
                eng.submit([a for _, a in sched[i:j]])
                i = j
            if i >= len(sched) and not eng.pending:
                break
            eng.run_round()
            rounds += 1
        assert len(eng.completed) == len(sched)
        p99[continuous] = eng.metrics.latency_percentile(99, unit="sim")
    assert p99[True] < p99[False]


# ---------------------------------------------------------------------------
# deadline-aware scheduling: slack orders the batch, FIFO is preserved
# ---------------------------------------------------------------------------

def test_batcher_picks_tightest_slack_first():
    q = LegionQueue(legion=0)
    loose = Request(rid=0, deadline_sim=100.0, decode_ticks=1)
    none = Request(rid=1)                          # no deadline: inf slack
    tight = Request(rid=2, deadline_sim=10.0, decode_ticks=1)
    for r in (loose, none, tight):
        q.push(r)
    batch = MicroBatcher(2).form_one(q, now=0.0, tick_seconds=1.0)
    assert [r.rid for r in batch] == [2, 0], "tightest deadline leaves first"
    assert [r.rid for r in q._q] == [1]


def test_batcher_stays_fifo_without_deadlines():
    q = LegionQueue(legion=0)
    for i in range(5):
        q.push(Request(rid=i))
    assert [r.rid for r in MicroBatcher(3).form_one(q)] == [0, 1, 2]


def test_equal_slack_keeps_queue_order():
    """Front-pushed redeliveries retain priority among equal slack — the
    tie-break is queue position, never rid or dict order."""
    q = LegionQueue(legion=0)
    a = Request(rid=5, deadline_sim=20.0)
    b = Request(rid=1, deadline_sim=20.0)
    q.push(a)
    q.push_front(b)                                # redelivery: skip the line
    batch = q.pop_batch(2, key=lambda r: r.slack(0.0, 1.0))
    assert [r.rid for r in batch] == [1, 5]


# ---------------------------------------------------------------------------
# admission control: backpressure before the queues blow past feasibility
# ---------------------------------------------------------------------------

def test_admission_shed_rejects_infeasible_load():
    """A flood of tight-deadline arrivals on a tiny cluster: admission
    sheds what cannot meet its SLO, the ledger stays conserved, and
    nothing shed ever completes."""
    pol = LegioPolicy(legion_size=4, serve_microbatch=1,
                      serve_admission="shed")
    eng = ServeEngine(VirtualCluster(4, policy=pol), work)
    eng.submit([arr(decode=3, slo=6.0, user=i) for i in range(200)])
    eng.serve(max_rounds=300)
    shed = set(eng.metrics.shed)
    assert shed, "infeasible load must be shed at the door"
    assert not shed & set(eng.completed)
    assert shed | set(eng.completed) == set(range(200))


def test_admission_park_keeps_ids_out_of_completions():
    pol = LegioPolicy(legion_size=4, serve_microbatch=1,
                      serve_admission="park")
    eng = ServeEngine(VirtualCluster(4, policy=pol), work)
    eng.submit([arr(decode=3, slo=6.0, user=i) for i in range(200)])
    eng.serve(max_rounds=300)
    parked = set(eng.metrics.parked)
    assert parked and not parked & set(eng.completed)
    assert len(eng.metrics.shed) == 0
    assert parked | set(eng.completed) == set(range(200))


def test_admission_none_queues_everything():
    eng = make_engine(n=8)
    eng.submit([arr(decode=3, slo=0.5, user=i) for i in range(50)])
    assert eng.router.backlog + sum(
        len(b) for b in eng._inflight.values()) == 50
    assert not eng.metrics.shed and not eng.metrics.parked


# ---------------------------------------------------------------------------
# parking + DROP semantics across every recovery mode (ledger coverage)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", sorted(MODES))
def test_parking_path_across_modes(mode):
    """serve_max_attempts=1 with a mid-campaign fault: everything the dead
    node held parks (never silently lost, never completed twice)."""
    pol = LegioPolicy(legion_size=4, serve_microbatch=3,
                      serve_max_attempts=1,
                      **recovery_preset(mode, spare_fraction=0.5))
    cl = VirtualCluster(16, policy=pol, injector=FaultInjector.at([(0, 5)]))
    eng = ServeEngine(cl, work)
    eng.submit(48)
    eng.serve(max_rounds=60)
    parked = set(eng.metrics.parked)
    assert parked, f"{mode}: the dead node's requests must park"
    assert not parked & set(eng.completed)
    assert parked | set(eng.completed) == set(range(48))
    assert not eng.metrics.abandoned


@pytest.mark.parametrize("mode", sorted(MODES))
def test_drop_semantics_across_modes(mode):
    """requeue=False in every recovery mode: the dead node's requests are
    abandoned explicitly — counted, disjoint from completions, and the
    ledger still adds up."""
    eng = make_engine(mode=mode, faults=[(0, 2)], requeue=False)
    eng.submit(48)
    rep = eng.serve(max_rounds=60)
    m = rep.metrics_summary
    assert m["abandoned"] > 0 and m["requeues"] == 0, \
        f"{mode}: DROP must abandon, not requeue"
    abandoned = set(eng.metrics.abandoned)
    assert not abandoned & set(eng.completed)
    assert abandoned | set(eng.completed) == set(range(48))


# ---------------------------------------------------------------------------
# determinism: identical seeds -> byte-identical dispatch traces
# ---------------------------------------------------------------------------

def _dispatch_trace(seed):
    gen = TrafficGenerator(6.0, seed=seed)
    eng = make_engine(n=16, mode="nonblocking", microbatch=2,
                      faults=[(2, 5)])
    t_prev = 0.0
    for _ in range(25):
        now = eng.cluster.clock.sim_seconds
        if now > t_prev:
            eng.submit(gen.arrivals(t_prev, now))
            t_prev = now
        if t_prev >= 12.0 and not eng.pending:
            break
        eng.run_round()
    return (eng.metrics.dispatch_trace,
            [r.rid for r in eng.metrics.completions],
            [(r.rid, r.complete_sim) for r in eng.metrics.completions])


@given(seed=st.integers(0, 2**31 - 1))
def test_dispatch_trace_byte_identical_across_runs(seed):
    """The tie-break property: at a fixed seed, two independent runs over
    the same traffic produce identical dispatch traces and identical
    completion orders — no dict-order or hash-seed dependence anywhere in
    router selection, slack scheduling, or window admission."""
    assert _dispatch_trace(seed) == _dispatch_trace(seed)


def test_dispatch_trace_deterministic_fixed_seed():
    """Deterministic coverage of the same property (runs without
    hypothesis)."""
    for seed in (0, 7, 123457):
        assert _dispatch_trace(seed) == _dispatch_trace(seed)
