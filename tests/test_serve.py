"""Serve subsystem: at-least-once re-enqueue, exactly-once completion,
no stall on healthy legions — for every recovery mode."""
import pytest
from hypothesis import given, strategies as st

from repro.core import FaultInjector, LegioPolicy, VirtualCluster
from repro.serve import (
    RECOVERY_PRESETS as MODES,
    Request,
    RequestRouter,
    ServeEngine,
    recovery_preset,
)


def work(node, batch, step):
    return {r.rid: float(r.rid) for r in batch}


def make_engine(n=16, mode="shrink", faults=(), microbatch=3, **kw):
    pol = LegioPolicy(legion_size=4, serve_microbatch=microbatch,
                      **recovery_preset(mode, spare_fraction=0.5))
    cl = VirtualCluster(n, policy=pol,
                        injector=FaultInjector.at(list(faults)))
    return ServeEngine(cl, work, **kw)


def queued_rids(engine):
    return {r.rid for q in engine.router.queues.values() for r in q._q}


def inflight_rids(engine):
    return {r.rid for b in engine._inflight.values() for r in b}


# ---------------------------------------------------------------------------
# property: no request is lost or double-completed across an injected fault
# ---------------------------------------------------------------------------

@given(data=st.data())
def test_no_request_lost_or_double_completed(data):
    mode = data.draw(st.sampled_from(sorted(MODES)))
    n = data.draw(st.integers(8, 24))
    n_fail = data.draw(st.integers(1, min(4, n - 4)))
    victims = data.draw(st.permutations(list(range(n))))[:n_fail]
    steps = data.draw(st.lists(st.integers(0, 4),
                               min_size=n_fail, max_size=n_fail))
    total = data.draw(st.integers(20, 120))
    eng = make_engine(n=n, mode=mode, faults=list(zip(steps, victims)))
    eng.submit(total)
    rep = eng.serve(max_rounds=200)
    # exactly-once from the client's view: every id, once, no extras
    assert sorted(eng.completed) == list(range(total))
    assert rep.completed == total
    m = rep.metrics_summary
    assert m["parked"] == 0 and m["abandoned"] == 0
    # completions are unique per id in the metrics ledger too
    seen = [r.rid for r in eng.metrics.completions]
    assert len(seen) == len(set(seen))


# ---------------------------------------------------------------------------
# deterministic coverage of the same property (runs without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", sorted(MODES))
def test_zero_loss_across_fault_each_mode(mode):
    """A mid-campaign fault with batches in flight loses nothing: the
    verdict node's requests are re-enqueued (at-least-once) and every id
    completes exactly once."""
    eng = make_engine(mode=mode, faults=[(1, 5), (2, 0)])
    eng.submit(150)
    rep = eng.serve(max_rounds=100)
    assert sorted(eng.completed) == list(range(150))
    m = rep.metrics_summary
    assert m["requeues"] > 0, "faults landed mid-flight: must redeliver"
    assert m["duplicates_suppressed"] == 0
    assert m["max_attempts_seen"] >= 2   # a redelivered request completed
    rids = [r.rid for r in eng.metrics.completions]
    assert len(rids) == len(set(rids)) == 150


def test_request_accounting_invariant_every_round():
    """At every round boundary each request id is in exactly one bucket:
    queued, in-flight, or completed (the queue.py ownership invariant)."""
    eng = make_engine(mode="nonblocking", faults=[(1, 3), (3, 8)])
    eng.submit(90)
    submitted = set(range(90))
    for _ in range(40):
        if not eng.pending:
            break
        eng.run_round()
        q, f, c = queued_rids(eng), inflight_rids(eng), set(eng.completed)
        assert q | f | c == submitted
        assert not (q & f) and not (q & c) and not (f & c)
    assert set(eng.completed) == submitted


# ---------------------------------------------------------------------------
# dedup guard: redelivery of a completed request is suppressed
# ---------------------------------------------------------------------------

def test_dedup_guard_suppresses_double_completion():
    eng = make_engine()
    eng.submit(4)
    eng.run_round()
    assert 0 in eng.completed
    ghost = Request(rid=0, enqueue_step=0, attempts=1)
    eng._redeliver(ghost)                    # stale redelivery of a done id
    assert eng.metrics.duplicates_suppressed == 1
    assert eng.metrics.requeues == 0
    assert len(eng.completed) == 4           # nothing re-entered the system


def test_partial_work_result_redelivers_not_completes():
    """A work_fn that drops an id (partial result dict) is a delivery
    failure: the request redelivers instead of completing as None."""
    first_try_dropped = []

    def flaky(node, batch, step):
        out = {}
        for r in batch:
            if r.rid == 7 and r.attempts == 1:
                first_try_dropped.append(r.rid)
                continue
            out[r.rid] = float(r.rid)
        return out

    cl = VirtualCluster(16, policy=LegioPolicy(legion_size=4,
                                               serve_microbatch=3))
    eng = ServeEngine(cl, flaky)
    eng.submit(30)
    eng.serve(max_rounds=20)
    assert first_try_dropped == [7]
    assert eng.completed[7] == 7.0          # real result, via redelivery
    assert sorted(eng.completed) == list(range(30))
    assert eng.metrics.requeues >= 1


def test_completed_results_are_write_once():
    eng = make_engine()
    eng.submit(2)
    eng.run_round()
    first = eng.completed[1]
    eng._complete(Request(rid=1, enqueue_step=0), -999.0, 1, 0)
    assert eng.completed[1] == first
    assert eng.metrics.duplicates_suppressed == 1


# ---------------------------------------------------------------------------
# DROP (requeue=False) and the redelivery ceiling
# ---------------------------------------------------------------------------

def test_drop_mode_abandons_instead_of_requeueing():
    eng = make_engine(faults=[(0, 2)], requeue=False)
    eng.submit(48)
    rep = eng.serve(max_rounds=50)
    m = rep.metrics_summary
    assert m["abandoned"] > 0 and m["requeues"] == 0
    assert rep.completed + m["abandoned"] == 48
    assert not set(eng.metrics.abandoned) & set(eng.completed)


def test_max_attempts_parks_not_drops():
    pol = LegioPolicy(legion_size=4, serve_microbatch=3,
                      serve_max_attempts=1)
    cl = VirtualCluster(16, policy=pol,
                        injector=FaultInjector.at([(0, 5)]))
    eng = ServeEngine(cl, work)
    eng.submit(48)
    eng.serve(max_rounds=50)
    parked = set(eng.metrics.parked)
    assert parked, "requests on the dead node hit the ceiling"
    assert not parked & set(eng.completed)
    assert parked | set(eng.completed) == set(range(48))


# ---------------------------------------------------------------------------
# router: queues survive topology changes
# ---------------------------------------------------------------------------

def test_whole_legion_death_rehomes_its_queue():
    """All members of one legion die in one round — its undispatched queue
    must re-home to surviving legions, not strand."""
    eng = make_engine(faults=[(0, 4), (0, 5), (0, 6), (0, 7)], microbatch=1)
    eng.submit(160)                      # deep queues: plenty undispatched
    rep = eng.serve(max_rounds=200)
    assert rep.completed == 160
    assert eng.router.rerouted > 0, "the dead legion's queue was re-homed"
    assert all(idx != 1 for idx in eng.router.queues), \
        "legion 1 left the ring; its queue must be gone"


def test_router_least_loaded_sharding():
    router = RequestRouter()
    cl = VirtualCluster(16, policy=LegioPolicy(legion_size=4))
    reqs = [Request(rid=i) for i in range(40)]
    router.submit(reqs, cl.topo.view())
    sizes = {i: len(q) for i, q in router.queues.items()}
    assert sum(sizes.values()) == 40
    assert max(sizes.values()) - min(sizes.values()) <= 1


# ---------------------------------------------------------------------------
# e2e: a mid-campaign fault keeps p99 bounded on healthy legions
# ---------------------------------------------------------------------------

def test_e2e_p99_bounded_on_healthy_legions():
    """Structural acceptance (no wall-clock): during the repair, legions
    untouched by the fault keep dispatching every round, and their
    round-latency p99 does not exceed the campaign-wide p99 — serving
    overlaps the repair instead of barriering on it."""
    faults = [(2, 1), (3, 5)]
    eng = make_engine(mode="nonblocking", faults=faults, microbatch=2)
    cl = eng.cluster
    submitted = 0
    rounds = 0
    while submitted < 240 or eng.pending:
        if rounds < 8:
            eng.submit(30)
            submitted += 30
        eng.run_round()
        rounds += 1
        assert rounds < 100
    assert sorted(eng.completed) == list(range(240))

    fault_legions = {cl.topo.home[v] for _, v in faults}
    healthy = [lg.index for lg in cl.topo.legions
               if lg.members and lg.index not in fault_legions]
    assert healthy, "the campaign must leave untouched legions"
    # no stall: every repair-window round dispatched on every healthy legion
    for lg in healthy:
        assert eng.metrics.stalled_rounds(lg, 2, 4) == 0
    p99_all = eng.metrics.latency_percentile(99)
    p99_healthy = eng.metrics.latency_percentile(99, set(healthy))
    assert p99_healthy <= p99_all
    # the repaired cluster is back at full capacity (nonblocking splices)
    assert cl.topo.size == 16


def test_healthy_legions_dispatch_during_repair_round():
    """The round that repairs legion L still dispatches real batches on
    every other legion (the RoundReport shows both in one round)."""
    eng = make_engine(mode="nonblocking", faults=[(1, 5)])
    eng.submit(200)
    eng.run_round()                                   # round 0: warm
    rep = eng.run_round()                             # round 1: fault + repair
    assert any(5 in a.verdict for a in rep.actions)
    victim_legion = eng.cluster.topo.home[5]
    dispatched_legions = {
        eng.cluster.topo.home.get(n, victim_legion)
        for n in rep.dispatched}
    assert len(dispatched_legions - {victim_legion}) >= 3, \
        "all other legions dispatched in the repair round"
