"""DROP / REBALANCE shard reassignment (paper §IV rank-translation analogue)."""
from hypothesis import given, strategies as st

from repro.core.batch import gradient_scale, initial_assignment, reassign


@given(n=st.integers(1, 64), spn=st.integers(1, 4))
def test_initial_assignment_covers_all(n, spn):
    plan = initial_assignment(list(range(n)), spn)
    shards = [s for a in plan.assignments for s in a.shards]
    assert sorted(shards) == list(range(n * spn))
    assert plan.active_shards == n * spn


@given(n=st.integers(2, 48), spn=st.integers(1, 3), data=st.data())
def test_drop_conservation(n, spn, data):
    plan = initial_assignment(list(range(n)), spn)
    failed = set(data.draw(st.lists(st.integers(0, n - 1), min_size=1,
                                    max_size=n - 1, unique=True)))
    dropped = reassign(plan, failed, "drop")
    live_shards = [s for a in dropped.assignments for s in a.shards]
    # survivors keep exactly their own shards
    assert all(a.node not in failed for a in dropped.assignments)
    assert len(live_shards) + len(dropped.dropped_shards) == n * spn
    assert set(live_shards).isdisjoint(dropped.dropped_shards)


@given(n=st.integers(2, 48), spn=st.integers(1, 3), data=st.data())
def test_rebalance_conservation(n, spn, data):
    plan = initial_assignment(list(range(n)), spn)
    failed = set(data.draw(st.lists(st.integers(0, n - 1), min_size=1,
                                    max_size=n - 1, unique=True)))
    reb = reassign(plan, failed, "rebalance")
    shards = sorted(s for a in reb.assignments for s in a.shards)
    assert shards == list(range(n * spn))       # nothing lost, no dupes
    assert reb.dropped_shards == ()
    # balance: max-min spread <= 1 after round-robin over equal buckets
    sizes = [len(a.shards) for a in reb.assignments]
    assert max(sizes) - min(sizes) <= max(1, spn)


def test_sequential_failures_accumulate():
    plan = initial_assignment(list(range(4)), 1)
    plan = reassign(plan, {0}, "drop")
    plan = reassign(plan, {1}, "drop")
    assert plan.dropped_shards == (0, 1)
    assert plan.active_shards == 2


def test_gradient_scale():
    plan = initial_assignment(list(range(4)), 2)
    assert gradient_scale(plan, 8) == 1.0
    dropped = reassign(plan, {0, 1}, "drop")
    assert gradient_scale(dropped, 8) == 2.0    # 8 / 4 surviving shards
    rebal = reassign(plan, {0, 1}, "rebalance")
    assert gradient_scale(rebal, 8) == 1.0      # exact batch preserved
