"""Depth >= 3 invariants of the recursive N-level topology (paper §V
claims (a)/(b)/(c) generalized per level) plus the scoped-repair partition.

Two flavors per invariant: a hypothesis property test (CI runs these; the
conftest stub skips them when hypothesis is absent) and a deterministic
hand-driven campaign that exercises the same invariant locally.
"""
import random

import pytest
from hypothesis import given, strategies as st

from repro.core.hierarchy import (
    LegionTopology,
    StaleLegionError,
    make_topology,
)
from repro.core.policy import LegioPolicy, optimal_kd

nodes_st = st.integers(min_value=2, max_value=200)
k_st = st.integers(min_value=2, max_value=8)
depth_st = st.integers(min_value=1, max_value=4)


def check_structure(topo: LegionTopology) -> None:
    """Every invariant the recursive tree must keep at every level."""
    # member index coherent with the legion lists
    for lg in topo.legions:
        for m in lg.members:
            assert topo.legion_of(m) is lg
    assert sorted(topo._by_member) == topo.nodes
    # (a) communicator count stays linear in n
    assert topo.n_communicators() <= 3 * max(topo.size, 1) + 2
    n_groups = sum(len(topo.groups(level))
                   for level in range(max(topo.depth - 1, 1)))
    assert topo.n_communicators() == 2 * n_groups + 2
    # each level partitions the one below; the top level is a single root
    lv = topo.levels()
    assert len(lv) == topo.depth - 1
    child_indices = [lg.index for lg in topo.legions if lg.members]
    for groups in lv:
        seen = sorted(ci for g in groups for ci in g.children)
        assert seen == sorted(child_indices)        # disjoint + complete
        for g in groups:
            assert g.master == min(g.members)       # lowest-rank master rule
        child_indices = [g.index for g in groups]
    if lv:
        assert len(lv[-1]) == 1                     # exactly one root comm
    # every level's POV ring closes: following successors visits every
    # group exactly once and returns to the start
    for level in range(max(topo.depth - 1, 1)):
        ring = topo.groups(level)
        if not ring:
            continue
        start = ring[0].index
        seen, idx = [], start
        for _ in range(len(ring)):
            seen.append(idx)
            idx = topo.successor_at(level, idx).index
        assert idx == start                          # the ring closes
        assert sorted(seen) == sorted(g.index for g in ring)
        for g in ring:
            assert topo.predecessor_at(
                level, topo.successor_at(level, g.index).index).index == g.index
            pov = topo.pov_at(level, g.index)
            assert set(g.members) <= set(pov)
            assert len(pov) <= len(g.members) + 1


def check_paths(topo: LegionTopology, pairs) -> None:
    """(b)/(c): exactly one master path, hop-legal at every step."""
    for src, dst in pairs:
        path = topo.path(src, dst)
        assert path[0] == src and path[-1] == dst
        assert len(path) <= 2 * topo.depth
        assert len(set(path)) == len(path)           # no revisits
        chains = {n: topo.master_chain(n) for n in (src, dst)}
        for hop in path[1:-1]:
            # every intermediate hop is on one endpoint's master chain
            assert hop in chains[src] or hop in chains[dst]
        for a, b in zip(path, path[1:]):
            assert _share_comm(topo, a, b), (a, b, path)


def _share_comm(topo: LegionTopology, a: int, b: int) -> bool:
    if topo.legion_of(a).index == topo.legion_of(b).index:
        return True
    for level in range(1, topo.depth):
        for g in topo.groups(level):
            if a in g.members and b in g.members:
                return True
    return False


# ---------------------------------------------------------------------------
# property tests (CI)
# ---------------------------------------------------------------------------

@given(n=nodes_st, k=k_st, depth=depth_st)
def test_build_invariants_any_depth(n, k, depth):
    topo = LegionTopology.build(list(range(n)), k, depth=depth)
    if depth > 1:
        assert topo.depth == depth
    check_structure(topo)


@given(n=st.integers(8, 120), k=st.integers(2, 5), data=st.data())
def test_unique_master_path_depth3(n, k, data):
    topo = LegionTopology.build(list(range(n)), k, depth=3)
    src = data.draw(st.integers(0, n - 1))
    dst = data.draw(st.integers(0, n - 1))
    check_paths(topo, [(src, dst)])


@given(n=st.integers(12, 100), k=st.integers(2, 5),
       depth=st.integers(2, 4), data=st.data())
def test_rings_close_after_arbitrary_mutations(n, k, depth, data):
    """Every level's POV ring survives arbitrary remove/compact/substitute
    sequences (the satellite invariant)."""
    topo = LegionTopology.build(list(range(n)), k, depth=depth)
    spare = n
    for _ in range(data.draw(st.integers(1, 12))):
        nodes = topo.nodes
        if len(nodes) <= 2:
            break
        action = data.draw(st.sampled_from(["remove", "compact", "substitute"]))
        victim = data.draw(st.sampled_from(nodes))
        if action == "remove":
            topo.remove(victim)
        elif action == "substitute":
            topo.substitute(victim, spare)
            spare += 1
        topo.compact()
        check_structure(topo)


@given(n=st.integers(20, 120), data=st.data())
def test_scope_partition_covers_verdict_disjointly(n, data):
    topo = LegionTopology.build(list(range(n)), 4, depth=3)
    n_fail = data.draw(st.integers(1, 6))
    verdict = set(data.draw(st.permutations(list(range(n))))[:n_fail])
    scopes = topo.partition_scopes(verdict)
    covered = [v for s in scopes for v in s.verdict]
    assert sorted(covered) == sorted(verdict)        # partition, no overlap
    for i, a in enumerate(scopes):
        assert not set(a.participants) & verdict
        for b in scopes[i + 1:]:
            assert not set(a.participants) & set(b.participants)


# ---------------------------------------------------------------------------
# deterministic campaigns (always run, hypothesis or not)
# ---------------------------------------------------------------------------

def test_depth3_structure_and_paths_campaign():
    rng = random.Random(0)
    for n, k, depth in [(64, 4, 3), (100, 3, 4), (27, 3, 3), (200, 5, 3)]:
        topo = LegionTopology.build(list(range(n)), k, depth=depth)
        check_structure(topo)
        check_paths(topo, [(rng.randrange(n), rng.randrange(n))
                           for _ in range(20)])


def test_depth3_rings_survive_random_mutations():
    rng = random.Random(1)
    for trial in range(30):
        n, k = rng.choice([(48, 4), (60, 3), (90, 5)])
        depth = rng.choice([2, 3, 4])
        topo = LegionTopology.build(list(range(n)), k, depth=depth)
        spare = n
        for _ in range(rng.randrange(1, 15)):
            nodes = topo.nodes
            if len(nodes) <= 2:
                break
            action = rng.choice(["remove", "remove", "substitute", "compact"])
            if action == "remove":
                topo.remove(rng.choice(nodes))
            elif action == "substitute":
                topo.substitute(rng.choice(nodes), spare)
                spare += 1
            topo.compact()
            check_structure(topo)
        live = topo.nodes
        check_paths(topo, [(rng.choice(live), rng.choice(live))
                           for _ in range(5)])


def test_communicator_count_linear_at_depth3():
    counts = {n: LegionTopology.build(list(range(n)), 4, depth=3)
              .n_communicators() for n in (64, 128, 256, 512)}
    # doubling n at most doubles the communicator count (+ constant)
    for n in (64, 128, 256):
        assert counts[2 * n] <= 2 * counts[n] + 2


def test_stale_index_raises_topology_error_not_stopiteration():
    topo = LegionTopology.build(list(range(12)), 2, depth=3)
    topo.remove(4)
    topo.remove(5)
    topo.compact()                                   # legion 2 left the ring
    for fn in (topo.successor, topo.predecessor, topo.pov):
        with pytest.raises(StaleLegionError):
            fn(2)
        with pytest.raises(StaleLegionError):
            fn(99)
    with pytest.raises(StaleLegionError):
        topo.group_at(1, 99)
    with pytest.raises(StaleLegionError):
        topo.pov_at(1, 99)
    # StaleLegionError is a KeyError, so pre-hardening callers that caught
    # KeyError keep working
    assert issubclass(StaleLegionError, KeyError)


def test_member_index_matches_linear_scan():
    topo = LegionTopology.build(list(range(40)), 4, depth=3)
    rng = random.Random(2)
    spare = 40
    for _ in range(25):
        nodes = topo.nodes
        if len(nodes) <= 2:
            break
        action = rng.choice(["remove", "substitute", "expand"])
        if action == "remove":
            topo.remove(rng.choice(nodes))
            topo.compact()
        elif action == "substitute":
            topo.substitute(rng.choice(nodes), spare)
            spare += 1
        else:
            legion = rng.choice([lg.index for lg in topo.legions])
            topo.expand(legion, spare)
            spare += 1
        for node in topo.nodes:
            by_index = topo.legion_of(node)
            by_scan = next(lg for lg in topo.legions if node in lg.members)
            assert by_index is by_scan
    with pytest.raises(KeyError):
        topo.legion_of(-1)


def test_flat_and_depth2_unchanged_by_default():
    """Back-compat: the default policy still yields the paper's pair."""
    pol = LegioPolicy()
    assert make_topology(list(range(8)), pol).depth == 1
    assert make_topology(list(range(16)), pol).depth == 2
    t = make_topology(list(range(16)), LegioPolicy(hierarchy_depth=3,
                                                   legion_size=4))
    assert t.depth == 3 and len(t.levels()) == 2
    check_structure(t)


def test_optimal_kd_balances_levels():
    assert optimal_kd(64, 2) == 5                    # Eq. 3 verbatim at d=2
    assert optimal_kd(64, 3) == 4                    # 64^(1/3)
    assert optimal_kd(10_000, 3) == 22
    # deeper trees want smaller k
    assert optimal_kd(10_000, 4) < optimal_kd(10_000, 3)


def test_choose_depth_recursive_threshold():
    pol = LegioPolicy()
    assert pol.choose_depth(12) == 1                 # paper: flat below s=12
    assert pol.choose_depth(100) >= 2
    k, d = pol.choose_kd(10_000)
    assert d >= 3                                    # master comm outgrew it
    # explicit knob pins the depth
    assert LegioPolicy(hierarchy_depth=5).choose_depth(10_000) == 5
    with pytest.raises(ValueError):
        LegioPolicy(hierarchy_depth=-1)
