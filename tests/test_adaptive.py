"""Adaptive recovery (CostModelStrategy): scoring, dispatch, EWMA fitting."""
import numpy as np
import pytest

from repro.core import (
    CostModelStrategy,
    FaultInjector,
    LegionCheckpointer,
    LegioExecutor,
    LegioPolicy,
    VirtualCluster,
    available_strategies,
    make_strategy,
)


def work(node, shard, step):
    return np.ones(4) * (shard + 1)


def adaptive_policy(**kw):
    kw.setdefault("legion_size", 4)
    kw.setdefault("recovery_mode", "adaptive")
    return LegioPolicy(**kw)


SCORED = ("shrink", "substitute", "substitute_nonblocking", "restart")


def test_registered_and_selected_by_policy():
    assert "adaptive" in available_strategies()
    strat = make_strategy(adaptive_policy())
    assert isinstance(strat, CostModelStrategy)
    assert strat.overlap_safe            # inherits the built-ins' guarantee


def test_every_candidate_scored_restart_never_dispatched():
    inj = FaultInjector.at([(1, 5), (3, 9)])
    cl = VirtualCluster(16, policy=adaptive_policy(spare_fraction=0.25),
                        injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run(5)
    decisions = cl.strategy.decisions
    assert len(decisions) == 2
    for d in decisions:
        assert set(d.scores) == set(SCORED)
        assert d.chosen in CostModelStrategy.DISPATCHABLE
        assert d.scores[d.chosen] == min(d.scores[m]
                                         for m in CostModelStrategy.DISPATCHABLE)


def test_spares_available_substitution_wins():
    """One dead worker, warm pool, default horizon: paying the splice beats
    forfeiting the slot's throughput for adaptive_horizon_steps."""
    inj = FaultInjector.at([(2, 5)])
    cl = VirtualCluster(16, policy=adaptive_policy(spare_fraction=0.25),
                        injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run(5)
    d = cl.strategy.decisions[-1]
    assert d.chosen in ("substitute", "substitute_nonblocking")
    assert d.verdict == (5,)
    assert cl.topo.size == 16            # capacity restored
    assert cl.plan.active_shards == 16


def test_empty_pool_collapses_to_shrink_never_raises():
    """No spares: the substitution candidates price at shrink-or-worse and
    the tie-break prefers shrink — adaptive never raises
    SparePoolExhausted and never schedules a splice."""
    inj = FaultInjector.at([(1, 5), (2, 9)])
    cl = VirtualCluster(16, policy=adaptive_policy(), injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run(5)                            # would raise under strict substitute
    assert [d.chosen for d in cl.strategy.decisions] == ["shrink", "shrink"]
    assert cl.topo.size == 14 and cl.pending == []
    for d in cl.strategy.decisions:
        assert d.scores["shrink"] <= d.scores["substitute"]


def test_pool_drained_mid_campaign_degrades_gracefully():
    """More faults than spares: early faults substitute, later ones shrink —
    the scorer re-reads the live pool every drain."""
    inj = FaultInjector.at([(1, 5), (3, 9), (5, 13)])
    cl = VirtualCluster(16, policy=adaptive_policy(spare_nodes=1),
                        injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run(7)
    chosen = [d.chosen for d in cl.strategy.decisions]
    assert chosen[0] in ("substitute", "substitute_nonblocking")
    assert chosen[1:] == ["shrink", "shrink"]
    assert cl.spare_pool.exhausted


def test_short_horizon_prefers_shrink():
    """Near end-of-campaign (tiny adaptive_horizon_steps) the capacity a
    shrink forfeits is cheap — shrink wins even with a warm pool."""
    inj = FaultInjector.at([(2, 5)])
    cl = VirtualCluster(16, policy=adaptive_policy(
        spare_fraction=0.25, adaptive_horizon_steps=1), injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run(4)
    assert cl.strategy.decisions[-1].chosen == "shrink"
    assert len(cl.spare_pool) == 4       # no spare spent


def test_restore_cost_is_peer_aware(tmp_path):
    """A live ring replica prices the restore at the O(shard) transfer; a
    dead buddy (or no replica) prices it at the store read."""
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    cl = VirtualCluster(16, policy=adaptive_policy(spare_fraction=0.25),
                        checkpointer=ck)
    strat = cl.strategy
    store_cost = cl.substitute.cost.restore_seconds
    assert strat._restore_cost(cl, 5) == store_cost      # nothing pushed yet
    ck.save(0, cl.topo, lambda n: {"w": np.full(4, float(n))}, sync=True)
    assert strat._restore_cost(cl, 5) < store_cost       # replica committed
    buddy = cl.topo.buddy_of(5)
    cl.failed.add(buddy)
    assert strat._restore_cost(cl, 5) == store_cost      # correlated loss
    cl.failed.discard(buddy)
    # the peer discount shows up in the substitute score itself
    with_peer = strat.score(cl, {5})
    cl.replicator.drop(5)
    without = strat.score(cl, {5})
    assert with_peer["substitute"] < without["substitute"]


def test_ewma_ingest_tracks_pipeline_traces():
    inj = FaultInjector.at([(1, 5), (3, 9)])
    cl = VirtualCluster(16, policy=adaptive_policy(spare_fraction=0.25),
                        injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run(5)
    strat = cl.strategy
    # _ingest runs at repair time, BEFORE the current drain's own trace is
    # appended — each drain fits on everything up to the previous one
    assert strat._seen_traces == len(cl.pipeline.traces) - 1 > 0
    # single-node verdicts land in bucket 1 with the non-apply stages fitted
    stages = {stage for (stage, bucket) in strat._ewma if bucket == 1}
    assert {"detect", "notice", "agree", "plan"} <= stages
    assert strat.fitted_overhead(1) >= 0.0
    # the recorded decision carries the fit, not the argmin
    d = cl.strategy.decisions[-1]
    assert d.pipeline_overhead == pytest.approx(strat.fitted_overhead(1))


def test_ewma_bucket_is_power_of_two():
    assert [CostModelStrategy._bucket(n) for n in (1, 2, 3, 5, 8, 9)] == \
        [1, 2, 4, 8, 8, 16]


def test_restart_baseline_dominated_with_fresh_checkpoint(tmp_path):
    """With a checkpoint one step old, restart still loses: it pays every
    survivor's store restore while the dispatched mode restores one shard."""
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    inj = FaultInjector.at([(2, 5)])
    cl = VirtualCluster(16, policy=adaptive_policy(spare_fraction=0.25),
                        injector=inj, checkpointer=ck)
    ex = LegioExecutor(cl, work)
    ex.run(2)
    ck.save(1, cl.topo, lambda n: {"w": np.full(4, float(n))}, sync=True)
    ex.run(3)
    d = cl.strategy.decisions[-1]
    assert d.scores["restart"] > d.scores[d.chosen]
