import os
import sys

# tests run on the single real CPU device — the 512-device fake platform is
# exclusively the dry-run's business (see launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

from hypothesis import settings  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
