import os
import sys
import types

# tests run on the single real CPU device — the 512-device fake platform is
# exclusively the dry-run's business (see launch/dryrun.py)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

try:
    from hypothesis import settings  # noqa: E402

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
except ModuleNotFoundError:
    # Degrade gracefully: property tests skip instead of killing collection.
    # Test modules do `from hypothesis import given, strategies as st` at
    # import time, so a stub module must be in sys.modules before they load.

    class _AnyStrategy:
        """Stands in for any strategy constructor/combinator at collect time."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    def _given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _AnyStrategy()
    _stub.strategies = _AnyStrategy()
    sys.modules["hypothesis"] = _stub


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
