"""repro.mpi facade: conformance vs direct collectives, transparency under
fault campaigns, and fault-aware point-to-point conservation.

Two flavors per property (matching tests/test_hierarchy_depth.py): a
hypothesis test (CI) and a deterministic hand-driven campaign that runs
when hypothesis is absent (the conftest stub skips the @given flavors).
"""
import random

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (
    FaultInjector,
    HierarchicalCollectives,
    LegioPolicy,
    VirtualCluster,
)
from repro.mpi import (
    MPISessionError,
    MsgState,
    PeerFailedError,
    RecvWouldDeadlockError,
    Session,
)


def healthy_session(n: int, k: int = 4) -> Session:
    return Session(n, policy=LegioPolicy(legion_size=k))


# ---------------------------------------------------------------------------
# conformance: every collective is byte-identical through the facade
# ---------------------------------------------------------------------------

def assert_conformance(n: int, k: int, payload: np.ndarray) -> None:
    """On a healthy cluster the facade must add bookkeeping only: same
    payload bytes at every node, and exactly the same schedule stages as a
    direct HierarchicalCollectives call (zero extra collective stages)."""
    sess = healthy_session(n, k)
    comm = sess.world
    direct = HierarchicalCollectives(sess.cluster.topo, sess.cluster.link)
    contributions = {m: payload * (m + 1) for m in comm.members}

    fac = comm.bcast(payload, root=comm.members[0])
    ref = direct.bcast(comm.members[0], payload)
    assert sorted(fac.data) == sorted(ref.data)
    assert all(fac.data[m].tobytes() == ref.data[m].tobytes()
               for m in ref.data)
    assert fac.stages == ref.stages

    fac = comm.reduce(contributions, root=comm.members[0])
    ref = direct.reduce(comm.members[0], dict(contributions))
    assert fac.data[comm.members[0]].tobytes() == \
        ref.data[comm.members[0]].tobytes()
    assert fac.stages == ref.stages

    fac = comm.allreduce(contributions)
    ref = direct.allreduce(dict(contributions))
    assert sorted(fac.data) == sorted(ref.data)
    assert all(fac.data[m].tobytes() == ref.data[m].tobytes()
               for m in ref.data)
    assert fac.stages == ref.stages

    fac = comm.barrier()
    ref = direct.barrier()
    assert sorted(fac.data) == sorted(ref.data)
    assert len(fac.stages) == len(ref.stages)

    # fault-free bookkeeping is O(1) per call: exactly one pipeline drain
    # per op, zero repair rounds
    assert comm.stats.calls == 4
    assert comm.stats.drains == comm.stats.calls
    assert comm.stats.repair_rounds == 0


@given(n=st.integers(4, 48), k=st.integers(2, 6),
       width=st.integers(1, 64))
def test_collective_conformance_property(n, k, width):
    assert_conformance(n, k, np.arange(width, dtype=np.float64))


def test_collective_conformance_deterministic():
    for n, k in [(4, 2), (8, 4), (16, 4), (24, 5), (40, 4)]:
        assert_conformance(n, k, np.arange(16, dtype=np.float64))


# ---------------------------------------------------------------------------
# transparency: an MPI-shaped loop survives injected faults untouched
# ---------------------------------------------------------------------------

def test_allreduce_campaign_is_transparent():
    """Zero fault-handling code: the loop below never mentions faults, yet
    two nodes (one a legion master) die mid-campaign and every allreduce
    returns the exact survivor sum."""
    sess = Session(16, policy=LegioPolicy(legion_size=4),
                   injector=FaultInjector.at([(2, 9), (4, 0)]))
    comm = sess.world
    for step in range(7):
        sess.advance(step)
        res = comm.allreduce({m: np.array([float(m + 1)])
                              for m in comm.members
                              if m not in sess.cluster.failed})
        live = sess.cluster.live_nodes
        assert res.data[live[0]][0] == sum(m + 1 for m in live)
    assert comm.size == 14
    assert 9 not in comm.members and 0 not in comm.members
    assert comm.stats.repair_rounds >= 2          # both faults trapped


def test_root_failure_surfaces_once_then_rehomes():
    sess = Session(8, policy=LegioPolicy(legion_size=4),
                   injector=FaultInjector.at([(1, 0)]))
    comm = sess.world
    contribs = lambda: {m: np.ones(2) for m in sess.cluster.live_nodes}  # noqa: E731
    sess.advance(0)
    comm.reduce(contribs(), root=0)
    sess.advance(1)
    with pytest.raises(PeerFailedError) as exc:
        comm.reduce(contribs(), root=0)
    assert exc.value.peers == (0,)
    assert 0 not in comm.members                  # repair already landed
    sess.advance(2)
    res = comm.reduce(contribs(), root=0)         # re-homed, no error
    assert res.data[comm.members[0]][0] == comm.size


# ---------------------------------------------------------------------------
# point-to-point: fault-aware matching, discard semantics, conservation
# ---------------------------------------------------------------------------

def test_p2p_roundtrip_and_fifo_order():
    sess = healthy_session(8)
    comm = sess.world
    comm.send(1, 2, "a")
    comm.send(1, 2, "b")
    assert comm.probe(2, 1)
    assert comm.recv(2, 1) == "a"                 # non-overtaking
    assert comm.recv(2, 1) == "b"
    with pytest.raises(RecvWouldDeadlockError):
        comm.recv(2, 1)                           # live peer, nothing posted


def test_message_posted_before_sender_death_still_delivers():
    sess = Session(8, policy=LegioPolicy(legion_size=4),
                   injector=FaultInjector.at([(1, 3)]))
    comm = sess.world
    sess.advance(0)
    comm.send(3, 5, "in-flight")
    sess.advance(1)                               # node 3 dies mid-flight
    assert comm.recv(5, 3) == "in-flight"         # buffered payload survives
    # a second recv from the now-dead peer resolves to the discard outcome
    # (and repairs the communicator) instead of deadlocking
    with pytest.raises(PeerFailedError) as exc:
        comm.recv(5, 3)
    assert exc.value.discarded
    assert 3 not in comm.members


def test_messages_to_dead_destination_are_discarded_on_repair():
    sess = Session(8, policy=LegioPolicy(legion_size=4),
                   injector=FaultInjector.at([(1, 6)]))
    comm = sess.world
    sess.advance(0)
    comm.send(2, 6, "doomed")
    sess.advance(1)
    comm.barrier()                                # any call repairs node 6
    assert 6 not in comm.members
    assert comm.ledger.discarded == 1             # envelope resolved, not lost
    assert comm.ledger.conserved()
    with pytest.raises(PeerFailedError):
        comm.send(2, 6, "late")                   # dead peer: clean error


def run_p2p_campaign(seed: int, n: int = 12, steps: int = 8) -> None:
    """Random sends/recvs under a random fault schedule: no message may be
    lost (posted == delivered + discarded + pending) and none delivered
    twice."""
    rng = random.Random(seed)
    victims = rng.sample(range(n), rng.randint(1, 3))
    faults = [(rng.randint(1, steps - 2), v) for v in victims]
    sess = Session(n, policy=LegioPolicy(legion_size=4),
                   injector=FaultInjector.at(faults))
    comm = sess.world
    sent, received = 0, []
    for step in range(steps):
        sess.advance(step)
        for _ in range(rng.randint(1, 5)):
            live = sess.cluster.live_nodes
            if len(live) < 2:
                break
            src = rng.choice(live)
            dst = rng.choice([m for m in comm.members if m != src])
            try:
                comm.send(src, dst, ("payload", sent))
                sent += 1
            except PeerFailedError:
                pass                              # dead peer: clean surfacing
        for _ in range(rng.randint(1, 5)):
            live = sess.cluster.live_nodes
            if len(live) < 2:
                break
            dst = rng.choice(live)
            src = rng.choice([m for m in comm.members if m != dst])
            if comm.probe(dst, src):
                received.append(comm.recv(dst, src))
    comm.barrier()                                # flush any pending repair
    ledger = comm.ledger
    assert ledger.posted == sent
    assert ledger.delivered == len(received)
    assert len(set(received)) == len(received)    # no double delivery
    assert ledger.conserved()                     # no loss
    live = set(sess.cluster.live_nodes)
    for env in ledger.envelopes:                  # nothing pending to a ghost
        if env.state is MsgState.POSTED:
            assert env.dst in live


@given(seed=st.integers(0, 10_000))
def test_p2p_campaign_conservation_property(seed):
    run_p2p_campaign(seed)


def test_p2p_campaign_conservation_deterministic():
    for seed in range(12):
        run_p2p_campaign(seed)


# ---------------------------------------------------------------------------
# comm creators: split/dup isolation (paper §V comm-creator class)
# ---------------------------------------------------------------------------

def test_comm_split_scopes_collectives_to_the_subgroup():
    sess = healthy_session(16)
    comm = sess.world
    subs = comm.comm_split({m: m % 2 for m in comm.members})
    assert sorted(subs) == [0, 1]
    assert subs[0].size == subs[1].size == 8
    res = subs[1].allreduce({m: np.array([1.0]) for m in subs[1].members})
    assert set(res.data) == set(subs[1].members)  # nobody outside the color
    assert res.data[1][0] == 8.0
    assert subs[1].rank_of(subs[1].members[0]) == 0


def test_comm_split_subgroup_shrinks_with_faults():
    sess = Session(16, policy=LegioPolicy(legion_size=4),
                   injector=FaultInjector.at([(1, 2)]))
    comm = sess.world
    evens = comm.comm_split({m: m % 2 for m in comm.members})[0]
    sess.advance(0)
    sess.advance(1)
    res = evens.allreduce({m: np.array([1.0]) for m in evens.members
                           if m not in sess.cluster.failed})
    assert 2 not in evens.members and evens.size == 7
    assert res.data[0][0] == 7.0


def test_comm_split_mid_repair_matches_blocking_oracle():
    """The latent split-ordering hazard, pinned: a comm_split issued while
    a background repair window is in flight must build from the surviving
    post-repair groups — never observe a half-applied group (a dead node
    still present, or a busy-but-alive participant missing). The blocking
    path under the identical fault schedule is the oracle."""
    def split_after_fault(overlap: bool):
        pol = LegioPolicy(legion_size=4, hierarchy_depth=3,
                          recovery_mode="shrink", repair_overlap=overlap)
        sess = Session(64, policy=pol, injector=FaultInjector.at([(1, 20)]))
        comm = sess.world
        sess.advance(0)
        comm.allreduce({m: np.array([1.0]) for m in sess.cluster.live_nodes})
        sess.advance(1)                           # node 20 (a master) dies
        comm.allreduce({m: np.array([1.0])        # trap + repair (+ window)
                        for m in sess.cluster.live_nodes})
        if overlap:
            assert sess.cluster.background        # window really in flight
        subs = comm.comm_split({m: m % 3 for m in comm.members})
        groups = {c: tuple(sub.members) for c, sub in subs.items()}
        return sess, subs, groups

    blocking = split_after_fault(overlap=False)[2]
    sess, subs, groups = split_after_fault(overlap=True)
    assert groups == blocking                     # oracle: identical groups
    assert all(20 not in g for g in groups.values())   # the dead never appear
    busy = sess.cluster.repairing_participants()
    assert busy                                   # split ran mid-window...
    for node in busy:                             # ...but busy stay members
        assert node in set(groups[node % 3])
    # the sub-comm is immediately usable mid-window: its schedule excludes
    # the busy participants, yet membership keeps them
    color = next(c for c, g in groups.items() if set(g) & busy)
    res = subs[color].allreduce({m: np.array([1.0])
                                 for m in subs[color].members
                                 if m not in sess.cluster.failed})
    assert set(res.data) == set(subs[color].members) - busy
    # after the window reconciles, the same sub-comm runs full-membership
    for step in (2, 3):
        sess.advance(step)
    assert not sess.cluster.background
    res = subs[color].allreduce({m: np.array([1.0])
                                 for m in subs[color].members})
    assert set(res.data) == set(subs[color].members)
    assert sess.cluster.clock.residual_seconds == 0.0


def test_comm_split_mid_repair_nonblocking_substitution():
    """Same hazard under the non-blocking substitute strategy: the split
    mid-window reads the post-shrink group, and the spare's later splice
    lands in the world comm without resurrecting the dead node in the
    fixed-group sub-comms."""
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      nonblocking_substitution=True, spare_fraction=0.25,
                      repair_overlap=True)
    sess = Session(16, policy=pol, injector=FaultInjector.at([(1, 5)]))
    comm = sess.world
    sess.advance(0)
    comm.allreduce({m: np.array([1.0]) for m in sess.cluster.live_nodes})
    sess.advance(1)
    comm.allreduce({m: np.array([1.0]) for m in sess.cluster.live_nodes})
    subs = comm.comm_split({m: m % 2 for m in comm.members})
    assert 5 not in subs[1].members               # shrunk out, mid-window
    for step in range(2, 7):                      # splice + window merge
        sess.advance(step)
        comm.allreduce({m: np.array([1.0]) for m in sess.cluster.live_nodes})
    assert not sess.cluster.background
    spares = [n for n in comm.members if n >= 16]
    assert spares                                 # the splice landed (world)
    assert 5 not in subs[1].members               # sub-group stays shrunk
    assert not set(spares) & set(subs[0].members + subs[1].members)


def test_comm_dup_is_a_separate_matching_context():
    sess = healthy_session(8)
    comm = sess.world
    dup = comm.comm_dup()
    comm.send(1, 2, "original-context")
    assert not dup.probe(2, 1)                    # contexts never cross-match
    assert comm.recv(2, 1) == "original-context"
    dup.free()
    with pytest.raises(MPISessionError):          # use-after-free is loud,
        dup.barrier()                             # never a silent skip


def test_keyed_attach_replaces_instead_of_stacking():
    """The world comm is shared per cluster: a consumer re-attached under
    the same key must replace its hook, not accumulate duplicates."""
    sess = healthy_session(8)
    calls = []
    sess.world.attach(lambda op, view: calls.append("a"), key="k")
    sess.world.attach(lambda op, view: calls.append("b"), key="k")
    sess.world.barrier()
    assert calls == ["b"]                         # one hook, the latest
    sess.world.detach("k")
    sess.world.barrier()
    assert calls == ["b"]


def test_send_from_a_dead_caller_is_a_driver_bug():
    """A node dead since the boundary is still a topology member, but the
    simulation never runs code on it — send/recv *from* it must be loud."""
    sess = Session(8, policy=LegioPolicy(legion_size=4),
                   injector=FaultInjector.at([(0, 3)]))
    sess.world.barrier()                          # register step-0 state
    sess.cluster.inject(0)                        # node 3 dies, unrepaired
    assert 3 in sess.world.members                # ULFM window: still member
    with pytest.raises(ValueError):
        sess.world.send(3, 1, "ghost")
    with pytest.raises(ValueError):
        sess.world.recv(3, 1)


# ---------------------------------------------------------------------------
# session lifecycle
# ---------------------------------------------------------------------------

def test_finalize_freezes_the_surface():
    with healthy_session(8) as sess:
        sess.world.barrier()
    with pytest.raises(MPISessionError):
        sess.world.barrier()
    with pytest.raises(MPISessionError):
        sess.advance()
    # post-mortems stay readable after finalize
    assert sess.cluster.topo.size == 8


def test_adopt_is_shared_per_cluster():
    cl = VirtualCluster(8)
    assert Session.adopt(cl) is Session.adopt(cl)
    assert Session.adopt(cl).cluster is cl
