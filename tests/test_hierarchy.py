"""Property tests for the legion topology (paper §V claims (a)/(b)/(c))."""
from hypothesis import given, strategies as st

from repro.core.hierarchy import LegionTopology, make_topology
from repro.core.policy import LegioPolicy

nodes_st = st.integers(min_value=1, max_value=200)
k_st = st.integers(min_value=1, max_value=24)


@given(n=nodes_st, k=k_st)
def test_build_partitions_nodes(n, k):
    topo = LegionTopology.build(list(range(n)), k)
    seen = [m for lg in topo.legions for m in lg.members]
    assert sorted(seen) == list(range(n))          # disjoint + complete
    assert all(len(lg) <= k for lg in topo.legions)
    # paper: node r -> legion r // k
    for lg in topo.legions:
        for m in lg.members:
            assert m // k == lg.index


@given(n=nodes_st, k=k_st)
def test_linear_communicator_count(n, k):
    """Property (a): #communicators scales linearly with #nodes."""
    topo = LegionTopology.build(list(range(n)), k)
    n_comms = topo.n_communicators()
    n_legions = (n + k - 1) // k
    assert n_comms == 2 * n_legions + 2
    assert n_comms <= 2 * n + 2


@given(n=st.integers(2, 80), k=st.integers(1, 12),
       data=st.data())
def test_unique_master_path(n, k, data):
    """Properties (b)/(c): any node reaches any other via exactly the
    src -> master(src) -> master(dst) -> dst relay."""
    topo = LegionTopology.build(list(range(n)), k)
    src = data.draw(st.integers(0, n - 1))
    dst = data.draw(st.integers(0, n - 1))
    path = topo.path(src, dst)
    assert path[0] == src and path[-1] == dst
    assert len(path) <= 4
    # every intermediate hop is a master
    for hop in path[1:-1]:
        assert topo.is_master(hop)
    # consecutive hops share a communicator (same legion or both masters)
    for a, b in zip(path, path[1:]):
        same_legion = topo.home.get(a) == topo.home.get(b)
        both_master = topo.is_master(a) and topo.is_master(b)
        assert same_legion or both_master


@given(n=st.integers(2, 60), k=st.integers(2, 10))
def test_pov_contents(n, k):
    """POV_i = legion i's members + master of successor (paper Fig. 2)."""
    topo = LegionTopology.build(list(range(n)), k)
    if topo.n_legions < 2:
        return
    for lg in topo.legions:
        pov = topo.pov(lg.index)
        succ = topo.successor(lg.index)
        assert set(lg.members) <= set(pov)
        assert succ.master in pov
        assert len(pov) <= len(lg.members) + 1


@given(n=st.integers(3, 60), k=st.integers(2, 8), data=st.data())
def test_master_is_lowest_rank_and_reelection(n, k, data):
    topo = LegionTopology.build(list(range(n)), k)
    victim = data.draw(st.integers(0, n - 1))
    lg_idx, was_master = topo.remove(victim)
    lg = next(l for l in topo.legions if l.index == lg_idx)
    if lg.members:
        assert lg.master == min(lg.members)      # re-election rule
        if was_master:
            assert lg.master != victim
    topo.compact()
    assert victim not in topo.nodes


@given(n=st.integers(1, 100))
def test_threshold_selects_flat_or_hierarchical(n):
    """Paper: hierarchy is worth it for s > 11 (linear S hypothesis)."""
    topo = make_topology(list(range(n)), LegioPolicy())
    if n > 12:
        assert topo.n_legions > 1
    else:
        assert topo.n_legions == 1


def test_ring_successor_predecessor():
    topo = LegionTopology.build(list(range(12)), 4)
    idx = [lg.index for lg in topo.legions]
    for i in idx:
        assert topo.predecessor(topo.successor(i).index).index == i
    # last legion's successor is the first (ring)
    assert topo.successor(idx[-1]).index == idx[0]


def test_assignment_is_final():
    """Members never migrate legions, even when theirs shrinks to 1."""
    topo = LegionTopology.build(list(range(9)), 3)
    topo.remove(4)
    topo.remove(5)
    topo.compact()
    assert topo.home[3] == 1
    lg = topo.legion_of(3)
    assert lg.index == 1 and lg.members == [3]


def test_empty_legion_leaves_ring():
    topo = LegionTopology.build(list(range(6)), 2)
    topo.remove(2)
    topo.remove(3)
    topo.compact()
    assert [lg.index for lg in topo.legions] == [0, 2]
    assert topo.successor(0).index == 2
    assert topo.successor(2).index == 0
