"""Substitution recovery: spare pool, slot splice, invariants, e2e modes."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (
    FaultInjector,
    LegionCheckpointer,
    LegionTopology,
    LegioExecutor,
    LegioPolicy,
    SparePool,
    SparePoolExhausted,
    SubstituteEngine,
    VirtualCluster,
    initial_assignment,
    reassign,
    restore_rank,
    substitute_assign,
)


def work(node, shard, step):
    return np.ones(4) * (shard + 1)


def sub_policy(**kw):
    kw.setdefault("legion_size", 4)
    kw.setdefault("recovery_mode", "substitute")
    kw.setdefault("spare_fraction", 0.25)
    return LegioPolicy(**kw)


# ---------------------------------------------------------------------------
# SparePool / policy provisioning
# ---------------------------------------------------------------------------

def test_pool_provisioning_fraction_and_absolute():
    assert SparePool.provision(16, sub_policy()).capacity == 4
    assert SparePool.provision(16, LegioPolicy(spare_nodes=2)).capacity == 2
    # the larger knob wins
    p = LegioPolicy(spare_fraction=0.25, spare_nodes=7)
    assert SparePool.provision(16, p).capacity == 7
    # spare ids sit above every initial node id
    pool = SparePool.provision(16, sub_policy())
    assert pool.available == [16, 17, 18, 19]


def test_pool_take_is_fifo_until_exhausted():
    pool = SparePool.provision(8, LegioPolicy(spare_nodes=2))
    assert pool.take() == 8
    assert pool.take() == 9
    assert pool.take() is None
    assert pool.exhausted and pool.consumed == [8, 9]


def test_policy_rejects_unknown_mode():
    with pytest.raises(ValueError):
        LegioPolicy(recovery_mode="resurrect")


# ---------------------------------------------------------------------------
# topology splice invariants (paper §V properties (a)–(c) must survive)
# ---------------------------------------------------------------------------

def assert_invariants(topo: LegionTopology, n_expected: int):
    # (a) #communicators linear in #nodes
    live = [lg for lg in topo.legions if lg.members]
    assert topo.n_communicators() == 2 * len(live) + 2
    assert topo.size == n_expected
    # masters are the lowest surviving rank everywhere
    for lg in live:
        assert lg.master == min(lg.members)
    # (b)/(c): every pair connects via the unique <=4-hop master relay
    nodes = topo.nodes
    probe = nodes[:: max(1, len(nodes) // 6)]
    for src in probe:
        for dst in probe:
            path = topo.path(src, dst)
            assert path[0] == src and path[-1] == dst
            assert len(path) <= 4
            for hop in path[1:-1]:
                assert topo.is_master(hop)
    # POV ring: each live legion's POV = members + successor master
    if len(live) > 1:
        for lg in live:
            pov = topo.pov(lg.index)
            assert set(lg.members) <= set(pov)
            assert topo.successor(lg.index).master in pov


@given(n=st.integers(8, 64), k=st.integers(2, 8), data=st.data())
def test_substitute_preserves_invariants(n, k, data):
    topo = LegionTopology.build(list(range(n)), k)
    n_fail = data.draw(st.integers(1, min(3, n - 1)))
    failed = set(data.draw(st.permutations(list(range(n))))[:n_fail])
    pool = SparePool(capacity=n_fail,
                     available=[n + i for i in range(n_fail)])
    eng = SubstituteEngine(sub_policy(legion_size=k))
    report = eng.repair(topo, failed, pool)
    # full capacity restored: every failed slot filled by a spare
    assert report.mode == "substitute"
    assert len(report.substitutions) == n_fail and not report.unfilled
    assert_invariants(topo, n)
    # the spare landed in the failed node's home legion — assignment final
    for dead, spare in report.substitutions:
        assert topo.home[spare] == topo.home[dead]


@given(n=st.integers(8, 48), k=st.integers(2, 6), pool_size=st.integers(0, 2),
       data=st.data())
def test_then_shrink_falls_back_when_pool_exhausted(n, k, pool_size, data):
    topo = LegionTopology.build(list(range(n)), k)
    n_fail = data.draw(st.integers(pool_size + 1, min(4, n - 1)))
    failed = set(data.draw(st.permutations(list(range(n))))[:n_fail])
    pool = SparePool(capacity=pool_size,
                     available=[n + i for i in range(pool_size)])
    eng = SubstituteEngine(sub_policy(
        legion_size=k, recovery_mode="substitute_then_shrink"))
    report = eng.repair(topo, failed, pool)
    # pool covers what it can; the rest shrinks — never more than requested
    assert len(report.substitutions) == pool_size
    assert len(report.unfilled) == n_fail - pool_size
    assert topo.size == n - len(report.unfilled)
    for lg in topo.legions:
        assert lg.master == min(lg.members)


def test_strict_mode_raises_on_exhaustion():
    topo = LegionTopology.build(list(range(8)), 4)
    eng = SubstituteEngine(sub_policy())
    with pytest.raises(SparePoolExhausted):
        eng.repair(topo, {3}, SparePool(capacity=0))
    # nothing was mutated by the refused repair
    assert topo.size == 8


def test_master_substitution_promotes_survivor_not_spare():
    """Spare ids are above every initial id, so the lowest-rank master rule
    promotes a surviving original member, never the fresh spare."""
    topo = LegionTopology.build(list(range(16)), 4)
    eng = SubstituteEngine(sub_policy())
    pool = SparePool(capacity=1, available=[16])
    report = eng.repair(topo, {4}, pool)          # 4 = master of legion 1
    assert report.master_failed
    lg = topo.legion_of(16)
    assert lg.index == 1 and lg.master == 5
    ops = [s.op for s in report.steps]
    assert "substitute" in ops and "restore" in ops and "promote" in ops


def test_whole_legion_death_keeps_slot_in_ring():
    """Under shrink an emptied legion leaves the ring; under substitution
    the spare keeps the slot alive at its original ring position."""
    topo = LegionTopology.build(list(range(6)), 2)
    eng = SubstituteEngine(sub_policy(legion_size=2))
    pool = SparePool(capacity=2, available=[6, 7])
    eng.repair(topo, {2, 3}, pool)
    assert [lg.index for lg in topo.legions] == [0, 1, 2]
    assert topo.legion_of(6).index == 1 and topo.legion_of(7).index == 1
    assert topo.successor(0).index == 1


def test_expand_recreates_compacted_legion_in_ring_order():
    topo = LegionTopology.build(list(range(6)), 2)
    topo.remove(2)
    topo.remove(3)
    topo.compact()
    assert [lg.index for lg in topo.legions] == [0, 2]
    topo.expand(1, 6)
    assert [lg.index for lg in topo.legions] == [0, 1, 2]
    assert topo.legion_of(6).master == 6
    assert topo.successor(0).index == 1


def test_assignment_finality_enforced():
    topo = LegionTopology.build(list(range(8)), 4)
    topo.substitute(5, 8)
    with pytest.raises(ValueError):
        topo.substitute(6, 8)          # 8 already assigned — final
    with pytest.raises(ValueError):
        topo.expand(0, 8)


# ---------------------------------------------------------------------------
# batch plan: capacity hand-over and dropped-shard return
# ---------------------------------------------------------------------------

def test_substitute_assign_moves_shards_wholesale():
    plan = initial_assignment(list(range(4)), 2)
    out = substitute_assign(plan, {1: 4})
    assert out.shards_of(4) == plan.shards_of(1)
    assert out.shards_of(1) == ()
    assert out.active_shards == 8 and out.dropped_shards == ()


def test_restore_rank_returns_dropped_shards():
    plan = initial_assignment(list(range(4)), 2)
    plan = reassign(plan, {1}, "drop")
    assert plan.dropped_shards == (2, 3)
    out = restore_rank(plan, 4)
    assert out.shards_of(4) == (2, 3)
    assert out.dropped_shards == () and out.active_shards == 8


def test_restore_rank_disjoint_claim_never_erases_dropped_record():
    """A claim that misses the dropped pool must not wipe the record of
    shards dropped for other failures — they stay dropped."""
    plan = initial_assignment(list(range(4)), 2)
    plan = reassign(plan, {1}, "drop")
    out = restore_rank(plan, 4, shards=())
    assert out.dropped_shards == (2, 3)        # other failure's drops intact
    all_shards = sorted(s for a in out.assignments for s in a.shards)
    assert all_shards == [0, 1, 4, 5, 6, 7]    # nothing duplicated or lost


def test_restore_rank_pulls_back_from_rebalance():
    plan = initial_assignment(list(range(4)), 2)
    plan = reassign(plan, {1}, "rebalance")
    assert plan.dropped_shards == ()
    out = restore_rank(plan, 4)
    sizes = [len(a.shards) for a in out.assignments]
    assert sum(sizes) == 8 and max(sizes) - min(sizes) <= 1
    all_shards = sorted(s for a in out.assignments for s in a.shards)
    assert all_shards == list(range(8))          # nothing lost, no dupes


# ---------------------------------------------------------------------------
# end-to-end: substitute restores capacity, shrink stays degraded
# ---------------------------------------------------------------------------

def test_e2e_substitute_restores_capacity_shrink_stays_degraded():
    """The acceptance scenario: same fault, two recovery modes. Substitute
    returns to the pre-fault node count and per-step throughput (full
    reduce); shrink continues with one node fewer."""
    full = sum(range(1, 17))

    def run(mode):
        inj = FaultInjector.at([(2, 5)])
        pol = sub_policy(recovery_mode=mode) if mode != "shrink" \
            else LegioPolicy(legion_size=4)
        cl = VirtualCluster(16, policy=pol, injector=inj)
        ex = LegioExecutor(cl, work)
        return cl, ex.run(5)

    cl_shrink, rep_shrink = run("shrink")
    assert cl_shrink.topo.size == 15
    assert rep_shrink[3].reduced[0] == full - 6          # shard 5 dropped
    assert rep_shrink[3].grad_scale == pytest.approx(16 / 15)

    cl_sub, rep_sub = run("substitute")
    assert rep_sub[2].repair.mode == "substitute"
    assert rep_sub[2].repair.substitutions == ((5, 16),)
    assert cl_sub.topo.size == 16                        # node count restored
    assert cl_sub.plan.active_shards == 16               # throughput restored
    assert rep_sub[3].reduced[0] == full                 # full per-step reduce
    assert rep_sub[3].grad_scale == 1.0
    # transparency held either way: no step raised, reports kept coming
    assert [r.step for r in rep_sub] == list(range(5))


def test_e2e_nonblocking_runs_shrunk_then_reexpands():
    inj = FaultInjector.at([(2, 5)])
    pol = sub_policy(recovery_mode="substitute_then_shrink",
                     nonblocking_substitution=True, spare_warmup_steps=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(6)
    full = sum(range(1, 17))
    # fault step: shrink repair, the spare is still warming up
    assert reports[2].repair.mode == "substitute(nonblocking)"
    assert reports[2].repair.survivors == 15
    # warmup step genuinely runs shrunk — repair overlapped useful work
    assert reports[3].expanded == ()
    assert reports[3].reduced[0] == full - 6             # shard 5 dropped
    assert reports[3].grad_scale == pytest.approx(16 / 15)
    # next boundary: topology re-expanded, spare adopted the dropped shard
    assert reports[4].expanded == ((5, 16),)
    assert cl.pending == []
    assert cl.topo.size == 16
    assert reports[4].reduced[0] == full
    assert cl.plan.active_shards == 16


def test_fault_step_grad_scale_renormalizes_over_computed_shards():
    """At the fault step the spliced spare has not computed yet — the
    gradient renormalizes over the 15 shards that actually contributed,
    even though the post-repair plan already shows 16."""
    inj = FaultInjector.at([(2, 5)])
    cl = VirtualCluster(16, policy=sub_policy(), injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(4)
    assert cl.plan.active_shards == 16
    assert reports[2].grad_scale == pytest.approx(16 / 15)  # fault step
    assert reports[3].grad_scale == 1.0                     # spare computes


def test_nonblocking_strict_exhaustion_lands_shrink_first():
    """Strict mode with an undersized pool raises — but only AFTER the
    shrink has landed, so the error propagates from a *consistent* topology
    (confirmed-dead nodes are out, the committed shrink is on record) rather
    than one still containing corpses. No spare is consumed, no splice is
    scheduled."""
    inj = FaultInjector.at([(0, 1), (0, 2)])
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute",
                      nonblocking_substitution=True, spare_nodes=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    with pytest.raises(SparePoolExhausted):
        ex.run_step()
    # the shrink landed first: dead nodes are gone, topology is consistent
    assert cl.topo.size == 14
    assert not (set(cl.topo.nodes) & cl.failed)
    # the committed shrink is recorded; the pool and splice queue untouched
    assert len(cl.repairs) == 1 and cl.repairs[0].survivors == 14
    assert len(cl.spare_pool) == 1 and cl.pending == []


def test_nonblocking_splice_returns_only_own_shards():
    """Two failures, one spare, DROP: the splice returns the substituted
    node's shard only — the unfilled failure's shard stays dropped, so the
    plan honestly reports the degraded capacity."""
    inj = FaultInjector.at([(1, 1), (1, 2)])
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      nonblocking_substitution=True, spare_nodes=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(5)
    # exhausted at the fault step -> the report says so
    assert reports[1].repair.mode == "substitute_then_shrink"
    assert reports[3].expanded == ((1, 16),)   # after the 1-step warmup
    assert cl.plan.shards_of(16) == (1,)
    assert cl.plan.dropped_shards == (2,)      # node 2's shard stays dropped
    assert cl.plan.active_shards == 15 and cl.topo.size == 15


def test_e2e_strict_substitute_raises_when_exhausted():
    inj = FaultInjector.at([(0, 1), (1, 2)])
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute", spare_nodes=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run_step()
    with pytest.raises(SparePoolExhausted):
        ex.run_step()


def test_e2e_then_shrink_degrades_when_exhausted():
    inj = FaultInjector.at([(0, 1), (1, 2)])
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      spare_nodes=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(3)
    assert reports[0].repair.substitutions == ((1, 16),)
    assert reports[1].repair.mode == "substitute_then_shrink"
    assert reports[1].repair.unfilled == (2,)
    assert cl.topo.size == 15                            # degraded, alive
    assert reports[2].reduced is not None


def test_fault_on_warm_spare_is_not_lost():
    """A configured fault targeting a warm spare must be honored: the dead
    spare leaves the pool and is never spliced in."""
    inj = FaultInjector.at([(1, 16), (2, 5)])  # kill spare 16, then node 5
    cl = VirtualCluster(16, policy=sub_policy(), injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(5)
    assert 16 in cl.failed and 16 not in cl.spare_pool.available
    # the repair used the NEXT spare, not the dead one
    assert reports[2].repair.substitutions == ((5, 17),)
    assert 16 not in cl.topo.nodes and cl.topo.size == 16


def test_fault_on_warming_pending_spare_reschedules_on_next():
    """The warming spare dies: the splice restarts on the next warm spare
    with a fresh warmup; the dead spare is never installed."""
    inj = FaultInjector.at([(1, 5), (2, 16)])  # node 5 dies; its warming
    pol = sub_policy(recovery_mode="substitute_then_shrink",  # spare dies too
                     nonblocking_substitution=True, spare_warmup_steps=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(6)
    assert reports[1].repair.mode == "substitute(nonblocking)"
    assert reports[4].expanded == ((5, 17),)   # replacement, re-warmed
    assert 16 not in cl.topo.nodes and cl.topo.size == 16


def test_fault_on_warming_spare_with_empty_pool_stays_shrunk():
    inj = FaultInjector.at([(1, 5), (2, 16)])
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute_then_shrink",
                      nonblocking_substitution=True, spare_nodes=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    reports = ex.run(5)
    assert cl.pending == [] and all(r.expanded == () for r in reports)
    assert cl.topo.size == 15                  # then_shrink: degrade quietly


def test_fault_on_warming_spare_strict_mode_raises():
    """Strict substitute semantics: losing the last spare mid-warmup is
    exhaustion, not silent degradation."""
    inj = FaultInjector.at([(1, 5), (2, 16)])
    pol = LegioPolicy(legion_size=4, recovery_mode="substitute",
                      nonblocking_substitution=True, spare_nodes=1)
    cl = VirtualCluster(16, policy=pol, injector=inj)
    ex = LegioExecutor(cl, work)
    ex.run_step()
    ex.run_step()
    with pytest.raises(SparePoolExhausted):
        ex.run_step()                          # step 2: the warming spare dies


def test_e2e_checkpoint_backed_restoration(tmp_path):
    """The substituted rank restores the dead member's state shard —
    restart-only-failed via checkpoint/store.py."""
    ck = LegionCheckpointer(str(tmp_path), async_writes=False)
    inj = FaultInjector.at([(3, 5)])
    cl = VirtualCluster(16, policy=sub_policy(), injector=inj, checkpointer=ck)
    ex = LegioExecutor(cl, work)
    ex.run(2)
    state = {n: {"w": np.full((2,), float(n))} for n in cl.topo.nodes}
    ck.save(2, cl.topo, lambda n: state[n], sync=True)
    ex.run(3)
    assert cl.repairs[-1].substitutions == ((5, 16),)
    restored = cl.restored_state[16]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full((2,), 5.0))
    assert ck.restarts and ck.restarts[-1].node == 5


def test_trainer_substitution_keeps_full_batch():
    from repro.configs.base import ModelConfig, TrainConfig
    from repro.core import ResilientTrainer

    tiny = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
        attn_block_q=16, attn_block_k=16, xent_chunk=16, remat="none",
        param_dtype="float32", dtype="float32",
    )
    tc = TrainConfig(learning_rate=3e-2, total_steps=10, warmup_steps=2,
                     grad_clip=1.0)
    inj = FaultInjector.at([(3, 1)])
    cl = VirtualCluster(4, policy=LegioPolicy(
        recovery_mode="substitute", spare_nodes=1), injector=inj)
    tr = ResilientTrainer(tiny, tc, cl, per_shard_batch=2, seq_len=32)
    reports = tr.run(6)
    assert reports[3].repair is not None
    assert reports[3].repair.substitutions == ((1, 4),)
    # capacity preserved: every step keeps the full shard count
    assert all(r.active_shards == 4 for r in reports)
    assert np.isfinite(reports[-1].loss)
