"""Beyond-paper: end-to-end repair cost in the TRAINING runtime.

On a TPU cluster "shrink" is not communicator surgery — it is (a) topology
rebuild, (b) live-state resharding, (c) recompilation. This benchmark
measures our runtime's actual wall-clock for a mid-training repair, and the
effect of the CompileCache on a regrow back to a previously-seen size (the
elastic case where (c) vanishes).
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.base import TrainConfig
from repro.configs.registry import get_smoke_config
from repro.core import FaultInjector, LegioPolicy, ResilientTrainer, VirtualCluster


def run() -> list[dict]:
    rows = []
    for nodes in (8, 16):
        for policy in ("drop", "rebalance"):
            cfg = get_smoke_config("llama3.2-3b")
            tc = TrainConfig(total_steps=12, warmup_steps=2)
            inj = FaultInjector.at([(4, 1)])
            cl = VirtualCluster(nodes, policy=LegioPolicy(batch_policy=policy),
                                injector=inj)
            tr = ResilientTrainer(cfg, tc, cl, per_shard_batch=1, seq_len=32)
            steps = []
            for i in range(8):
                t0 = time.perf_counter()
                rep = tr.run_step()
                steps.append((time.perf_counter() - t0, rep))
            normal = [s for s, r in steps[1:4] if r.repair is None]
            repair_step = next(s for s, r in steps if r.repair is not None)
            repair_rep = next(r for _, r in steps if r.repair is not None)
            post = [s for s, r in steps[5:] if r.repair is None]
            rows.append({
                "nodes": nodes,
                "batch_policy": policy,
                "normal_step_ms": 1e3 * sum(normal) / len(normal),
                "repair_step_ms": 1e3 * repair_step,
                "post_repair_step_ms": 1e3 * sum(post) / len(post),
                "model_repair_cost_s": repair_rep.repair.model_cost,
                "plan_stages": len(repair_rep.repair.steps),
            })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "repair cost inside the training runtime (smoke model)")
    drop = [r for r in rows if r["batch_policy"] == "drop"]
    reb = [r for r in rows if r["batch_policy"] == "rebalance"]
    print("# DROP shrinks the global batch -> the repair step pays a one-time"
          " RE-COMPILE for the new shape (the dominant S(x) term on XLA,"
          " exactly the (c) term in DESIGN.md §2).")
    print("# REBALANCE keeps the global batch shape -> repair avoids the"
          " recompile entirely; steady-state steps match pre-fault times:")
    for d, r in zip(drop, reb):
        print(f"#   nodes={d['nodes']}: repair step drop={d['repair_step_ms']:.0f}ms"
              f" vs rebalance={r['repair_step_ms']:.0f}ms")


if __name__ == "__main__":
    main()
