"""Paper Eq. 3/4 check: the optimal local_comm size k vs cluster size s.

For each s the brute-force argmin_k E[R_H(s,k)] (expectation over uniform
single-node failure, P(master) = 1/k) is compared against the closed-form
Eq. 3 (linear S) and Eq. 4 (quadratic S) predictions.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.policy import (
    LegioPolicy,
    optimal_k_linear,
    optimal_k_quadratic,
)
from repro.core.shrink import ShrinkCostModel, ShrinkEngine

SIZES = [16, 32, 64, 128, 256, 512, 1024, 4096]


def brute_force_k(s: int, p: float) -> int:
    eng = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=p, c=0.0))
    return min(range(2, s + 1), key=lambda k: eng.expected_repair_cost(s, k))


def run() -> list[dict]:
    rows = []
    for s in SIZES:
        k_lin_pred = optimal_k_linear(s)
        k_quad_pred = optimal_k_quadratic(s)
        k_lin_true = brute_force_k(s, p=1.0)
        k_quad_true = brute_force_k(s, p=2.0)
        rows.append({
            "s": s,
            "eq3_k_linear": k_lin_pred,
            "bruteforce_k_linear": k_lin_true,
            "eq4_k_quadratic": k_quad_pred,
            "bruteforce_k_quadratic": k_quad_true,
            "lin_err": abs(k_lin_pred - k_lin_true),
            "quad_err": abs(k_quad_pred - k_quad_true),
        })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "eq3/eq4: closed-form optimal k vs brute force")
    max_lin = max(r["lin_err"] for r in rows)
    max_quad = max(r["quad_err"] for r in rows)
    print(f"# linear: Eq. 3 matches the brute-force argmin exactly "
          f"(max err {max_lin}).")
    print(f"# quadratic: Eq. 4 diverges from our uniform-failure expectation "
          f"argmin (max err {max_quad}, growing with s) — the paper does not "
          f"show Eq. 4's derivation; under E[R_H] with P(master)=1/k the "
          f"optimum is s ~ 2k^4(k+1)/3, i.e. smaller k than Eq. 4 predicts. "
          f"Recorded as a reproduction discrepancy in EXPERIMENTS.md.")
    assert max_lin <= 1                      # Eq. 3 validated


if __name__ == "__main__":
    main()
