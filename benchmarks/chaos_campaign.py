"""Correlated-failure zoo under all recovery modes — the PR-6 pass bar.

Three parts:

  1. **Invariant matrix** (n=64, depth 3): every scenario preset of
     :class:`FaultModel` x every recovery mode x {train, serve}, with the
     :class:`ChaosHarness` invariant checks (exactly-once accounting,
     ledger conservation, topology coherence, per-scenario guarantees)
     as the pass bar — 30 cells, all must pass.
  2. **Two-rack scale proof** (n=4096, depth 3, k=16): a 2-rack disjoint
     outage resolves in ONE pipeline drain as two scoped terminal
     actions, pairwise-disjoint participants, healthy-subtree repair
     participation exactly zero, and the simulated clock charged the max
     (not the sum) of the scope costs — the paper's concurrency claim at
     the acceptance-criteria scale.
  3. **Scope vectorization equality**: the numpy fast paths of
     ``fault_groups`` / ``partition_scopes`` produce byte-identical
     output to the retired O(n)-scan reference implementations on a
     4096-node topology under correlated fault sets.

All asserts are structural (counts, set relations, equality) — never
wall-clock — per the bench-smoke convention.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.chaos import RECOVERIES, ChaosHarness
from repro.core.executor import LegioExecutor, VirtualCluster
from repro.core.faultmodel import FaultModel
from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy

N_MATRIX = 64
N_SCALE = 4096


def invariant_matrix() -> dict:
    """Every (scenario x recovery x workload) cell at n=64 must pass."""
    harness = ChaosHarness(seed=0)
    reports = harness.run_matrix(N_MATRIX)
    rows = [dict(scenario=r.scenario, workload=r.workload,
                 recovery=r.recovery, checks=len(r.checks),
                 passed=r.passed) for r in reports]
    emit(rows, f"invariant matrix (n={N_MATRIX}, "
               f"{len(FaultModel.SCENARIOS)} scenarios x "
               f"{len(RECOVERIES)} recoveries x train/serve)")
    failed = [r for r in reports if not r.passed]
    for r in failed:
        for chk in r.failures:
            print(f"  FAIL {r.scenario}/{r.workload}/{r.recovery} "
                  f"{chk.name}: {chk.detail}")
    assert not failed, f"{len(failed)} matrix cell(s) failed invariants"
    return {"cells": len(reports), "failed": 0}


def rack_scale_proof() -> dict:
    """2 disjoint racks at n=4096 depth 3: one drain, zero healthy-subtree
    participation, clock charged max(scope costs)."""
    pol = LegioPolicy(legion_size=16, hierarchy_depth=3)
    model = FaultModel(policy=pol, seed=0)
    campaign = model.campaign("rack_outage", N_SCALE, racks=2)
    racks = campaign.meta["racks"]
    assert len(racks) == 2
    assert racks[0]["subtree"] != racks[1]["subtree"]
    victims = set(campaign.crashed)
    assert len(victims) == 2 * pol.legion_size
    fault_step = campaign.events[0].step

    cl = VirtualCluster(N_SCALE, policy=pol, injector=campaign.injector())
    assert cl.topo.depth == 3
    rack_members = {r["subtree"]: set(r["members"]) for r in racks}
    ex = LegioExecutor(cl, lambda node, shard, step: 1.0)
    for _ in range(fault_step):
        ex.run_step()
    clock_before = cl.clock.sim_seconds
    report = ex.run_step()                       # the fault step: ONE drain

    assert set(report.failed_now) == victims
    assert len(report.actions) == 2              # one terminal action per rack
    scopes = [a.scope for a in report.actions]
    assert all(s is not None for s in scopes)
    p0, p1 = (set(s.participants) for s in scopes)
    assert p0 and p1 and not (p0 & p1)           # concurrent: disjoint racks
    # every repair participant that existed at campaign time lives in one
    # of the two struck subtrees — healthy subtrees contribute ZERO
    struck = rack_members[racks[0]["subtree"]] | rack_members[racks[1]["subtree"]]
    subtree_all = {st: set(ms)
                   for st, ms in FaultModel._subtree_members(
                       LegionTopology.build(
                           list(range(N_SCALE)), pol.legion_size,
                           depth=pol.hierarchy_depth)).items()}
    struck_subtrees = {racks[0]["subtree"], racks[1]["subtree"]}
    outside = {p for p in (p0 | p1) if p < N_SCALE
               and not any(p in subtree_all[st] for st in struck_subtrees)}
    assert not outside, f"healthy-subtree participants: {sorted(outside)[:8]}"
    # the clock charged max(scope costs), not the sum — concurrent repair
    costs = [a.report.model_cost for a in report.actions]
    charged = cl.clock.sim_seconds - clock_before \
        - pol.step_sim_seconds - report.sim_collective_seconds
    assert abs(charged - max(costs)) < 1e-9
    assert charged < sum(costs)
    assert len(cl.live_nodes) == N_SCALE - len(victims)
    summary = dict(n=N_SCALE, depth=3, racks=2, victims=len(victims),
                   drains=1, actions=len(report.actions),
                   participants=[len(p0), len(p1)],
                   healthy_subtree_participation=0,
                   charged_sim_s=round(charged, 6),
                   sum_costs_sim_s=round(sum(costs), 6))
    emit([summary], "two-rack outage scale proof "
                    f"(n={N_SCALE}, depth 3, k={pol.legion_size})")
    return summary


def scope_vectorization() -> dict:
    """Numpy fast paths == retired reference scans, byte for byte."""
    topo = LegionTopology.build(list(range(N_SCALE)), 16, depth=3)
    rng = np.random.default_rng(6)
    cases = 0
    for _ in range(8):
        # correlated shapes: a whole legion, plus uncorrelated singles
        lg = topo.legions[int(rng.integers(len(topo.legions)))]
        singles = {int(v) for v in
                   rng.choice(topo.nodes, size=5, replace=False)}
        faults = (set(lg.members) | singles) & set(topo.nodes)
        for node in faults:
            assert topo.fault_groups(node) == \
                topo._fault_groups_reference(node)
        assert topo.partition_scopes(faults) == \
            topo._partition_scopes_reference(faults)
        cases += 1
    print(f"[chaos_campaign] vectorized scope scans byte-identical to "
          f"reference on {cases} correlated fault sets @ n={N_SCALE}: OK")
    return {"n": N_SCALE, "cases": cases, "identical": True}


def main() -> dict:
    matrix = invariant_matrix()
    scale = rack_scale_proof()
    vec = scope_vectorization()
    print("[chaos_campaign] all presets pass invariants under all "
          "recovery modes; 2-rack outage resolves in one drain with "
          "healthy-subtree participation = 0: OK")
    return {"matrix": matrix, "rack_scale": scale, "vectorization": vec}


if __name__ == "__main__":
    main()
