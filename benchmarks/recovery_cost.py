"""Peer-replicated restore cost + adaptive recovery dominance (PR 9).

Two claims, both structural (deterministic byte/sim-second accounting; wall
clocks are reported but never asserted — CI machines vary):

(a) **Peer restore is O(shard)**: restoring a substituted rank from its
    POV-ring buddy touches exactly the member's shard bytes and charges one
    link-model cross transfer, independent of how many members (= how much
    total model) the checkpoint covers. The store path re-reads the
    manifest (O(members) entries) plus the member npz, and its simulated
    charge is the flat ``SubstituteCostModel.restore_seconds`` — the peer
    charge sits strictly below it at the default config.

(b) **Adaptive dominance**: over a fault-rate x checkpoint-interval grid,
    the ``adaptive`` mode's realized recovery overhead (simulated makespan
    minus the fault-free ideal for the same fixed work) is <= every static
    preset's in every cell. The presets mirror
    ``repro.serve.engine.recovery_preset`` (each mode in its canonical
    configuration); adaptive runs without overlap windows so every repair
    charge lands on the clock and the comparison is apples-to-apples.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import emit
from repro.checkpoint import store
from repro.checkpoint.replicate import ShardReplicator
from repro.core.collectives import LinkModel
from repro.core.cr import LegionCheckpointer
from repro.core.detector import FaultInjector
from repro.core.executor import LegioExecutor, VirtualCluster
from repro.core.hierarchy import make_topology
from repro.core.policy import LegioPolicy
from repro.core.substitute import SubstituteCostModel

EPS = 1e-6

# -- (a) O(shard) peer restore ------------------------------------------------

SHARD_FLOATS = 4096          # fixed per-member shard: 16 KiB of float32
MEMBER_COUNTS = (8, 32, 128)  # total model grows 16x; the shard does not


def _shards_for(topo) -> dict:
    return {(lg.index, n): {"w": np.full(SHARD_FLOATS, n, dtype=np.float32)}
            for lg in topo.legions for n in lg.members}


def bench_peer_restore() -> list[dict]:
    """Bytes touched + simulated charge per restore path, vs model size."""
    rows = []
    # hierarchical even at the smallest size: the POV ring (and with it the
    # replica buddy map) only exists with more than one legion
    pol = LegioPolicy(legion_size=4, hierarchical_threshold=4)
    cost = SubstituteCostModel()
    for m in MEMBER_COUNTS:
        topo = make_topology(list(range(m)), pol)
        shards = _shards_for(topo)
        tmp = tempfile.mkdtemp(prefix="recovery_cost_")
        try:
            store.save(tmp, 0, shards)
            victim = topo.legions[0].members[-1]
            legion = topo.legions[0].index
            sdir = os.path.join(tmp, "step_000000")
            manifest_bytes = os.path.getsize(
                os.path.join(sdir, "manifest.json"))
            npz_bytes = os.path.getsize(
                os.path.join(sdir, store.member_relpath(legion, victim)))
            t0 = time.perf_counter()
            store.restore_member(tmp, 0, legion, victim)
            store_wall = time.perf_counter() - t0

            repl = ShardReplicator(link=LinkModel())   # no ledger: direct
            repl.push_map(0, topo, shards)
            record = repl.replicas[victim]
            peer_bytes = record.nbytes
            peer_secs = repl.transfer_seconds(peer_bytes)
            t0 = time.perf_counter()
            repl.restore(victim, topo, failed=set())
            peer_wall = time.perf_counter() - t0
            rows.append({
                "members": m,
                "model_mb": round(m * SHARD_FLOATS * 4 / 2 ** 20, 3),
                "store_bytes": manifest_bytes + npz_bytes,
                "peer_bytes": peer_bytes,
                "store_sim_s": cost.restore_seconds,
                "peer_sim_s": peer_secs,
                "store_wall_ms": round(store_wall * 1e3, 3),
                "peer_wall_ms": round(peer_wall * 1e3, 3),
            })
        finally:
            shutil.rmtree(tmp, ignore_errors=True)

    # O(shard): the peer path is flat in model size; the store path grows
    peer = [r["peer_bytes"] for r in rows]
    assert len(set(peer)) == 1, f"peer restore bytes not flat: {peer}"
    stored = [r["store_bytes"] for r in rows]
    assert all(a < b for a, b in zip(stored, stored[1:])), \
        f"store restore bytes did not grow with the model: {stored}"
    assert all(r["peer_sim_s"] < cost.restore_seconds for r in rows), \
        "peer transfer charge not below the store restore charge"
    return rows


# -- (b) adaptive dominance grid ----------------------------------------------

N_NODES = 16
SHARDS_PER_NODE = 1
WORK_STEPS = 40                         # fault-free ideal: WORK_STEPS steps
TOTAL_WORK = N_NODES * SHARDS_PER_NODE * WORK_STEPS
SPARE_FRACTION = 0.25

MODES = {
    "shrink": dict(recovery_mode="shrink"),
    "substitute": dict(recovery_mode="substitute_then_shrink",
                       spare_fraction=SPARE_FRACTION),
    "nonblocking": dict(recovery_mode="substitute_then_shrink",
                        spare_fraction=SPARE_FRACTION,
                        nonblocking_substitution=True),
    "adaptive": dict(recovery_mode="adaptive",
                     spare_fraction=SPARE_FRACTION),
}

FAULT_PERIODS = (0, 12, 5)              # steps between kills; 0 = none
CHECKPOINT_EVERY = (2, 8)


def _injector(period: int) -> FaultInjector:
    if period <= 0:
        return FaultInjector()
    victims = [n for n in range(1, N_NODES) if n % 2 == 1]  # never the root
    pairs = [(period * (i + 1), v) for i, v in enumerate(victims)
             if period * (i + 1) < WORK_STEPS - 4]
    return FaultInjector.at(pairs)


def _run_cell(mode: str, period: int, ck_every: int) -> float:
    """Recovery overhead (sim s) for one (mode, fault-rate, ckpt) config.

    Runs a fixed WORK_STEPS-step campaign and charges two exact terms:
    the sim-clock seconds above the fault-free ideal (repair charges), and
    the work deficit converted at the full-cluster rate — every slot-step
    lost to a shrunk topology costs exactly ``step_sim / n`` seconds, so
    capacity loss is never hidden by end-of-run step quantization."""
    pol = LegioPolicy(legion_size=4, **MODES[mode])
    tmp = tempfile.mkdtemp(prefix=f"recovery_cost_{mode}_")
    try:
        ck = LegionCheckpointer(tmp, async_writes=False)
        cluster = VirtualCluster(N_NODES, policy=pol,
                                 injector=_injector(period),
                                 shards_per_node=SHARDS_PER_NODE,
                                 checkpointer=ck)
        ex = LegioExecutor(cluster, lambda n, s, step: 1.0)
        done = 0
        for step in range(WORK_STEPS):
            if step % ck_every == 0:
                ck.save(step, cluster.topo,
                        lambda n: {"w": np.full(64, n, dtype=np.float32)},
                        sync=True)
            report = ex.run_step(step)
            done += sum(len(cluster.plan.shards_of(n))
                        for n in report.results)
        ideal = WORK_STEPS * pol.step_sim_seconds
        deficit = max(0, TOTAL_WORK - done)
        return (cluster.clock.sim_seconds - ideal
                + deficit * pol.step_sim_seconds / N_NODES)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def bench_dominance() -> list[dict]:
    rows = []
    for period in FAULT_PERIODS:
        for ck_every in CHECKPOINT_EVERY:
            cell = {"fault_period": period, "ckpt_every": ck_every}
            for mode in MODES:
                cell[mode] = round(_run_cell(mode, period, ck_every), 6)
            rows.append(cell)
    for cell in rows:
        for mode in MODES:
            if mode == "adaptive":
                continue
            assert cell["adaptive"] <= cell[mode] + EPS, (
                f"adaptive overhead {cell['adaptive']} exceeds {mode} "
                f"{cell[mode]} in cell {cell}")
    return rows


def main() -> dict:
    peer_rows = bench_peer_restore()
    emit(peer_rows, "(a) restore path bytes + charges vs model size "
                    "(peer flat, store grows)")
    grid_rows = bench_dominance()
    emit(grid_rows, "(b) recovery overhead (sim s above fault-free ideal) "
                    "per mode; adaptive <= every static mode per cell")
    return {"peer_restore": peer_rows, "dominance": grid_rows}


if __name__ == "__main__":
    main()
