"""Paper Fig. 12 analogue: molecular-docking screening skeleton under Legio.

The paper's second application screens a ligand database against a target,
keeping the best-scoring molecules — EP with an all-reduce(max) at the end.
Here each "docking score" is a deterministic surrogate (a seeded optimization
of a rough energy function); the run uses the real model zoo only for sizing
realism, not chemistry. Measured: throughput per configuration and the
result-set integrity under faults (DROP loses the dead node's ligands,
REBALANCE preserves the full screen — both valid per the paper's policies).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_repeated
from repro.core import FaultInjector, LegioExecutor, LegioPolicy, VirtualCluster

LIGANDS_PER_SHARD = 64
POSES_PER_LIGAND = 128
SIZES = [8, 16, 32]


def dock_shard(node: int, shard: int, step: int) -> np.ndarray:
    """Score one ligand shard; returns [best_score, best_ligand_id, count]."""
    rng = np.random.default_rng(shard * 7919 + step)
    # surrogate energy: min over random poses of a quadratic + LJ-ish term
    best, best_id = np.inf, -1
    for lig in range(LIGANDS_PER_SHARD):
        poses = rng.normal(size=(POSES_PER_LIGAND, 3))
        r2 = np.sum(poses ** 2, axis=1) + 0.5
        energy = (r2 - 2.0) ** 2 - 1.0 / r2 ** 3 + 0.01 * lig
        e = energy.min()
        if e < best:
            best, best_id = e, shard * LIGANDS_PER_SHARD + lig
    return np.array([best, float(best_id), LIGANDS_PER_SHARD])


def reduce_best(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    keep = a if a[0] <= b[0] else b
    return np.array([keep[0], keep[1], a[2] + b[2]])


def run_config(n: int, fail: bool, policy: str) -> tuple[float, dict]:
    inj = FaultInjector.at([(1, 2)]) if fail else FaultInjector()
    cl = VirtualCluster(
        n, policy=LegioPolicy(batch_policy=policy, straggler_threshold=0.0),
        injector=inj)
    ex = LegioExecutor(cl, dock_shard, reduce_op=reduce_best)
    secs = time_repeated(lambda: ex.run_step(), repeats=2, warmup=1)
    last = ex.run_step()
    _, _, screened = last.reduced
    return secs, {"screened": int(screened), "survivors": len(cl.live_nodes)}


def run() -> list[dict]:
    rows = []
    for n in SIZES:
        t_plain, s_plain = run_config(n, fail=False, policy="drop")
        t_drop, s_drop = run_config(n, fail=True, policy="drop")
        t_reb, s_reb = run_config(n, fail=True, policy="rebalance")
        rows.append({
            "ranks": n,
            "step_ms": t_plain * 1e3,
            "step_ms_faulted": t_drop * 1e3,
            "ligands_nofault": s_plain["screened"],
            "ligands_drop": s_drop["screened"],
            "ligands_rebalance": s_reb["screened"],
            "survivors": s_drop["survivors"],
        })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "fig12: docking screen under Legio")
    for r in rows:
        full = r["ranks"] * LIGANDS_PER_SHARD
        assert r["ligands_nofault"] == full
        assert r["ligands_drop"] == full - LIGANDS_PER_SHARD  # dead node's slice lost
        assert r["ligands_rebalance"] == full                 # recovered
    print("# DROP loses exactly the dead node's ligands; REBALANCE screens "
          "the full database (counter-based shards are regenerable)")


if __name__ == "__main__":
    main()
