"""Load-curve serving benchmark: continuous batching vs the lock-step
barrier under a fault storm (beyond-paper; repro.serve).

Two parts, all pass/fail asserts structural (simulated-clock seconds, never
wall time, per repo convention):

**Fault storm at scale** — n=4096, depth-3 topology (256 legions of 16
under 16 top-level subtrees), ``rack_outage`` kills two racks mid-campaign
while a seeded open-loop traffic stream (Poisson + diurnal swell + a burst
window, three SLO classes over a two-million-user population) keeps
arriving. The *identical* pre-generated arrival schedule is fed to both
engines — same offered load, no closed-loop mercy:

  * continuous batching: per-legion in-flight windows, slack-ordered
    admission, decode-state migration off the dead racks;
  * lock-step baseline: one batch per node per round, the round's sim
    duration stretches to the slowest in-flight batch, faults restart
    their requests from prefill.

Pass bar: exactly-once ledger conserved in both modes (zero lost, zero
double-completions), zero starved rounds on healthy legions, migrations
actually exercised, and continuous p99 (sim-seconds) strictly better than
lock-step at the same offered load.

**Load curve** — n=64 swept across offered rates with SLO-feasibility
admission control (``serve_admission=shed``): goodput, p99/p999, SLO
attainment, and shed counts per rate. Backpressure must engage before
queues blow past deadline feasibility: zero sheds while the load is
feasible, sheds > 0 once offered load clears capacity.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import LegioPolicy, VirtualCluster
from repro.core.faultmodel import FaultModel
from repro.serve import (
    Burst,
    Request,
    ServeEngine,
    TrafficGenerator,
    recovery_preset,
)

# -- fault storm -------------------------------------------------------------

STORM_NODES = 4096
STORM_SEED = 11
STORM_RATE = 600.0            # arrivals per simulated second
STORM_T_END = 24.0            # arrival window (sim seconds)
STORM_ROUND_CAP = 600


def work(node: int, batch: list[Request], step: int) -> dict[int, int]:
    return {r.rid: r.rid for r in batch}


def arrival_schedule(t_end: float) -> list[tuple[float, object]]:
    """Pre-generate the full open-loop stream on a 1-second grid, so both
    engines see the byte-identical offered load regardless of how their
    round durations slice time."""
    gen = TrafficGenerator(
        STORM_RATE, seed=STORM_SEED, diurnal_amplitude=0.3,
        diurnal_period=48.0, bursts=(Burst(6.0, 10.0, 2.0),))
    sched: list[tuple[float, object]] = []
    t = 0.0
    while t < t_end:
        for a in gen.arrivals(t, t + 1.0):
            sched.append((t + 1.0, a))
        t += 1.0
    return sched


def run_storm(mode: str, sched: list[tuple[float, object]]) -> dict:
    continuous = mode == "continuous"
    policy = LegioPolicy(
        legion_size=16, hierarchy_depth=3, serve_microbatch=2,
        serve_window=2, **recovery_preset("nonblocking", spare_fraction=0.02))
    cluster = VirtualCluster(
        STORM_NODES, policy=policy,
        injector=FaultModel(seed=STORM_SEED).campaign(
            "rack_outage", STORM_NODES, at_step=3, racks=2).injector())
    engine = ServeEngine(cluster, work, continuous=continuous)

    fault_legions = {cluster.topo.home[e.node]
                     for e in cluster.injector.events
                     if e.node in cluster.topo.home}
    i = 0
    rounds = 0
    while rounds < STORM_ROUND_CAP:
        now = cluster.clock.sim_seconds
        while i < len(sched) and sched[i][0] <= now:
            j = i
            while j < len(sched) and sched[j][0] <= now:
                j += 1
            engine.submit([a for _, a in sched[i:j]])
            i = j
        if i >= len(sched) and not engine.pending:
            break
        engine.run_round()
        rounds += 1
    m = engine.metrics.summary(max(rounds, 1))

    healthy = [lg.index for lg in cluster.topo.legions
               if lg.members and lg.index not in fault_legions]
    healthy_starved = sum(engine.metrics.starved_rounds(lg) for lg in healthy)
    submitted = len(sched)
    accounted = (len(engine.completed) + m["parked"] + m["abandoned"]
                 + m["shed"] + engine.pending)
    return {
        "mode": mode,
        "submitted": submitted,
        "completed": m["completed"],
        "lost": submitted - accounted,
        "unserved": engine.pending,
        "requeues": m["requeues"],
        "duplicates_suppressed": m["duplicates_suppressed"],
        "migrations": m["migrations"],
        "decode_ticks_preserved": m["decode_ticks_preserved"],
        "prefill_ticks": m["prefill_ticks"],
        "decode_ticks": m["decode_ticks"],
        "rounds": rounds,
        "sim_seconds": round(cluster.clock.sim_seconds, 3),
        "p50_latency_sim": m["p50_latency_sim"],
        "p99_latency_sim": m["p99_latency_sim"],
        "p999_latency_sim": m["p999_latency_sim"],
        "goodput_rps_sim": round(engine.metrics.goodput_sim(
            cluster.clock.sim_seconds), 2),
        "starved_rounds_healthy": healthy_starved,
        "starved_rounds_total": m["starved_rounds"],
        "completed_ids_unique":
            len(set(engine.completed)) == len(engine.completed)
            and len(engine.metrics.completions) == len(engine.completed),
    }


# -- load curve --------------------------------------------------------------

CURVE_NODES = 64
CURVE_RATES = (2.0, 8.0, 160.0)    # arrivals/sim-second: idle, busy, swamped
CURVE_T_END = 30.0


def run_curve_point(rate: float) -> dict:
    policy = LegioPolicy(
        legion_size=8, serve_microbatch=2, serve_window=2,
        serve_admission="shed", serve_admission_slack=1.0,
        **recovery_preset("shrink"))
    cluster = VirtualCluster(CURVE_NODES, policy=policy)
    engine = ServeEngine(cluster, work)
    gen = TrafficGenerator(rate, seed=STORM_SEED + int(rate))
    t_prev = 0.0
    rounds = 0
    while rounds < 400:
        now = cluster.clock.sim_seconds
        if now < CURVE_T_END:
            engine.submit(gen.arrivals(t_prev, now) if now > t_prev else [])
            t_prev = now
        elif not engine.pending:
            break
        engine.run_round()
        rounds += 1
    m = engine.metrics.summary(max(rounds, 1))
    submitted = gen.generated
    accounted = (m["completed"] + m["parked"] + m["abandoned"] + m["shed"]
                 + engine.pending)
    return {
        "offered_rps": rate,
        "submitted": submitted,
        "completed": m["completed"],
        "shed": m["shed"],
        "lost": submitted - accounted,
        "p99_latency_sim": m["p99_latency_sim"],
        "p999_latency_sim": m["p999_latency_sim"],
        "slo_attainment": m["slo_attainment"],
        "goodput_rps_sim": round(engine.metrics.goodput_sim(
            cluster.clock.sim_seconds), 2),
    }


def main() -> None:
    sched = arrival_schedule(STORM_T_END)
    storm = [run_storm(mode, sched) for mode in ("continuous", "lockstep")]
    curve = [run_curve_point(rate) for rate in CURVE_RATES]
    emit(storm, "serve_latency: continuous batching vs lock-step under a "
                "rack-outage storm (n=4096 depth 3)")
    emit(curve, "serve_latency: admission-controlled load curve (n=64)")
    by = {r["mode"]: r for r in storm}
    cont, lock = by["continuous"], by["lockstep"]

    # -- the acceptance ledger: structural asserts only ----------------------
    for r in storm:
        assert r["lost"] == 0 and r["unserved"] == 0, \
            f"{r['mode']}: exactly-once ledger not conserved"
        assert r["completed_ids_unique"], \
            f"{r['mode']}: a request id completed more than once"
        assert r["requeues"] > 0, \
            f"{r['mode']}: the storm must force redeliveries"
        assert r["starved_rounds_healthy"] == 0, \
            f"{r['mode']}: healthy legions starved during repair"
    assert cont["migrations"] > 0, \
        "continuous mode must migrate decode state off the dead racks"
    assert cont["decode_ticks_preserved"] > 0, \
        "migration must actually preserve decode progress"
    assert cont["p99_latency_sim"] < lock["p99_latency_sim"], \
        "continuous batching must beat the lock-step barrier at p99"
    assert cont["goodput_rps_sim"] >= lock["goodput_rps_sim"], \
        "continuous batching must not lose goodput vs lock-step"
    for r in curve:
        assert r["lost"] == 0, f"rate {r['offered_rps']}: requests lost"
    assert curve[0]["shed"] == 0, \
        "admission must not shed while the load is feasible"
    assert curve[-1]["shed"] > 0, \
        "admission must shed once offered load clears capacity"

    print(f"# storm (n={STORM_NODES}, depth 3, 2 racks out, "
          f"{storm[0]['submitted']} requests): p99 sim-latency continuous "
          f"{cont['p99_latency_sim']:.1f}s vs lockstep "
          f"{lock['p99_latency_sim']:.1f}s; goodput "
          f"{cont['goodput_rps_sim']:.0f} vs {lock['goodput_rps_sim']:.0f} "
          f"req/s; {cont['migrations']} decode migrations preserved "
          f"{cont['decode_ticks_preserved']} ticks")
    print(f"# load curve (n={CURVE_NODES}, shed admission): "
          + "; ".join(
              f"{r['offered_rps']:.0f} rps -> goodput "
              f"{r['goodput_rps_sim']:.1f}, p99 {r['p99_latency_sim']:.1f}s, "
              f"shed {r['shed']}" for r in curve))


if __name__ == "__main__":
    main()
