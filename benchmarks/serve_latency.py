"""Serving under faults: latency percentiles + goodput, shrink vs
substitute vs non-blocking substitute (beyond-paper; repro.serve).

A 16-node cluster serves a streaming campaign (fixed arrivals per round)
while three nodes die mid-flight. Per recovery mode:

  * p50/p99 round-latency (deterministic — latency is measured in rounds,
    not wall seconds, so the numbers are structural, per repo convention);
  * goodput (completed requests per round) and time-to-drain;
  * the at-least-once/exactly-once ledger: redeliveries, duplicates
    suppressed, lost (must be zero);
  * stall accounting on healthy legions during the repair rounds — the
    non-blocking claim measured directly.

Shrink serves the whole campaign on degraded capacity after the faults;
substitution restores capacity and the queue drains faster — the serving
analogue of the post-repair-throughput trade in benchmarks/repair_time.py.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import FaultInjector, LegioPolicy, VirtualCluster
from repro.serve import RECOVERY_PRESETS, Request, ServeEngine, recovery_preset

N_NODES = 16
ARRIVALS_PER_ROUND = 40
ARRIVAL_ROUNDS = 10
FAULTS = [(2, 1), (3, 5), (4, 9)]          # three workers die mid-flight
MICROBATCH = 2


def work(node: int, batch: list[Request], step: int) -> dict[int, float]:
    return {r.rid: float(np.cos(r.rid)) for r in batch}


def run_campaign(mode: str) -> dict:
    policy = LegioPolicy(legion_size=4, serve_microbatch=MICROBATCH,
                         **recovery_preset(mode))
    cluster = VirtualCluster(N_NODES, policy=policy,
                             injector=FaultInjector.at(FAULTS))
    engine = ServeEngine(cluster, work)

    submitted = 0
    rounds = 0
    while submitted < ARRIVALS_PER_ROUND * ARRIVAL_ROUNDS or engine.pending:
        if rounds < ARRIVAL_ROUNDS:
            engine.submit(ARRIVALS_PER_ROUND)
            submitted += ARRIVALS_PER_ROUND
        engine.run_round()
        rounds += 1
        if rounds > 200:
            break
    m = engine.metrics.summary(rounds)

    fault_steps = [s for s, _ in FAULTS]
    fault_legions = {cluster.topo.home[v] for _, v in FAULTS}
    healthy = [lg.index for lg in cluster.topo.legions
               if lg.members and lg.index not in fault_legions]
    healthy_stalls = sum(
        engine.metrics.stalled_rounds(lg, min(fault_steps), max(fault_steps))
        for lg in healthy)
    return {
        "mode": mode,
        "submitted": submitted,
        "completed": len(engine.completed),
        "lost": submitted - len(engine.completed),
        "requeues": m["requeues"],
        "duplicates_suppressed": m["duplicates_suppressed"],
        "rounds_to_drain": rounds,
        "p50_latency_rounds": m["p50_latency_rounds"],
        "p99_latency_rounds": m["p99_latency_rounds"],
        "p99_healthy_legions": engine.metrics.latency_percentile(
            99, set(healthy)),
        "goodput_rps": round(m["goodput_rps"], 2),
        "healthy_stall_rounds": healthy_stalls,
        "survivor_capacity": len(cluster.live_nodes) / N_NODES,
        "completed_ids_unique": len(set(engine.completed)) == submitted,
    }


def main() -> None:
    rows = [run_campaign(mode) for mode in RECOVERY_PRESETS]
    emit(rows, "serve_latency: fault campaign, shrink vs substitute vs "
               "nonblocking")
    by = {r["mode"]: r for r in rows}

    # -- the acceptance ledger: structural asserts only ----------------------
    for r in rows:
        assert r["lost"] == 0, f"{r['mode']}: requests lost"
        assert r["completed_ids_unique"], \
            f"{r['mode']}: a request id completed more than once"
        assert r["requeues"] > 0, \
            f"{r['mode']}: the fault campaign must force redeliveries"
        assert r["healthy_stall_rounds"] == 0, \
            f"{r['mode']}: healthy legions stalled during repair"
    assert by["substitute"]["survivor_capacity"] > \
        by["shrink"]["survivor_capacity"], \
        "substitution must preserve capacity shrink discards"
    assert by["substitute"]["rounds_to_drain"] <= \
        by["shrink"]["rounds_to_drain"], \
        "restored capacity must not drain slower than shrink"
    assert by["nonblocking"]["p99_latency_rounds"] <= \
        by["shrink"]["p99_latency_rounds"], \
        "non-blocking substitution must bound tail latency vs shrink"

    print(f"# fault campaign ({len(FAULTS)} deaths mid-flight, "
          f"{ARRIVALS_PER_ROUND * ARRIVAL_ROUNDS} requests): zero lost, "
          f"zero duplicates in every mode")
    print(f"# p99 latency (rounds): shrink "
          f"{by['shrink']['p99_latency_rounds']:.0f}, substitute "
          f"{by['substitute']['p99_latency_rounds']:.0f}, nonblocking "
          f"{by['nonblocking']['p99_latency_rounds']:.0f}; goodput "
          f"shrink {by['shrink']['goodput_rps']:.1f} vs nonblocking "
          f"{by['nonblocking']['goodput_rps']:.1f} req/round")


if __name__ == "__main__":
    main()
