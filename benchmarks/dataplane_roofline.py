"""Data-plane roofline: scheduled (alpha-beta) vs measured collective time.

For each (op x payload x participants) cell, runs the same scheduled
collective on two clusters that differ only in ``LegioPolicy.data_plane``
("sim" vs "auto") and reports the control plane's alpha-beta estimate next
to the measured wall time of each backend. On a single-device host the
"auto" cluster resolves to the sim plane (the graceful skip — the CI step
that forces 8 host devices is what exercises the jax column for real).

Asserts are structural, pinning the seam's parity contract:
  - byte-identical result dicts between backends (integer-exact payloads);
  - identical stage lists (schedules and their clock charges never depend
    on the backend);
  - the compression hop moves fewer wire bytes than raw on BOTH paths, with
    the accounting identical by construction (it lives in the control
    plane) — and still byte-identical results (host-computed scale, see
    kernels/quantize.py).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.executor import VirtualCluster
from repro.core.policy import LegioPolicy
from repro.optim import compression as C

PAYLOAD_ELEMS = (256, 16_384, 262_144)          # 1 KiB / 64 KiB / 1 MiB f32
PARTICIPANTS = (4, 8, 16)
REPEATS = 2


def _contributions(nodes, n_elems: int) -> dict[int, np.ndarray]:
    """Integer-exact f32 payloads: summation order cannot matter, so both
    backends must agree bit-for-bit."""
    base = (np.arange(n_elems, dtype=np.float32) % 13.0) - 6.0
    return {node: base * np.float32(i + 1)
            for i, node in enumerate(sorted(nodes))}


def _pair(n_nodes: int, compression: str = "none"
          ) -> tuple[VirtualCluster, VirtualCluster]:
    def mk(plane):
        return VirtualCluster(n_nodes, policy=LegioPolicy(
            data_plane=plane, grad_compression=compression))
    return mk("sim"), mk("auto")


def _run(cluster: VirtualCluster, op: str, n_elems: int):
    coll = cluster.collectives()
    nodes = cluster.topo.nodes
    contrib = _contributions(nodes, n_elems)
    root = sorted(nodes)[0]
    def fn():
        if op == "allreduce":
            return coll.allreduce(contrib, np.add)
        if op == "reduce":
            return coll.reduce(root, contrib, np.add)
        return coll.bcast(root, contrib[root])
    res = fn()                      # asserted-on result (also the warmup)
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        fn()
    wall = (time.perf_counter() - t0) / REPEATS
    return res, wall


def _assert_parity(res_sim, res_jax, cell: str) -> None:
    assert res_sim.stages == res_jax.stages, \
        f"{cell}: stage lists diverged between backends"
    assert res_sim.sim_seconds == res_jax.sim_seconds, \
        f"{cell}: clock charges diverged between backends"
    assert set(res_sim.data) == set(res_jax.data), \
        f"{cell}: result membership diverged"
    for node in res_sim.data:
        a, b = np.asarray(res_sim.data[node]), np.asarray(res_jax.data[node])
        assert a.dtype == b.dtype and a.tobytes() == b.tobytes(), \
            f"{cell}: node {node} payload not byte-identical"


def main() -> dict:
    rows: list[dict] = []

    # -- uncompressed sweep: op x payload x participants ---------------------
    for n_nodes in PARTICIPANTS:
        sim_cl, jax_cl = _pair(n_nodes)
        backend = jax_cl.dataplane.name
        for op in ("allreduce", "bcast", "reduce"):
            for n_elems in PAYLOAD_ELEMS:
                cell = f"{op}/{n_elems}el/{n_nodes}n"
                res_s, wall_s = _run(sim_cl, op, n_elems)
                res_j, wall_j = _run(jax_cl, op, n_elems)
                _assert_parity(res_s, res_j, cell)
                rows.append({
                    "op": op, "elems": n_elems,
                    "payload_bytes": n_elems * 4,
                    "participants": n_nodes,
                    "backend": backend,
                    "alpha_beta_ms": res_s.sim_seconds * 1e3,
                    "sim_wall_ms": wall_s * 1e3,
                    "measured_wall_ms": wall_j * 1e3,
                    "stages": len(res_s.stages),
                })

    # -- compression hop: hierarchical topology so a cross hop exists --------
    comp_rows: list[dict] = []
    n_nodes = max(PARTICIPANTS)
    raw_res = None
    for scheme in ("none", "int8", "topk"):
        for n_elems in PAYLOAD_ELEMS:
            # fresh pair per payload: error-feedback residuals are
            # shape-bound per master
            sim_cl, jax_cl = _pair(n_nodes, compression=scheme)
            assert sim_cl.topo.depth >= 2, \
                "compression sweep needs a cross-legion hop"
            cell = f"allreduce+{scheme}/{n_elems}el/{n_nodes}n"
            res_s, wall_s = _run(sim_cl, "allreduce", n_elems)
            res_j, wall_j = _run(jax_cl, "allreduce", n_elems)
            _assert_parity(res_s, res_j, cell)
            g = np.zeros(n_elems, np.float32)
            wire = C.compressed_bytes(g, scheme,
                                      sim_cl.policy.topk_fraction)
            comp_rows.append({
                "op": f"allreduce+{scheme}", "elems": n_elems,
                "raw_bytes": n_elems * 4, "wire_bytes": wire,
                "participants": n_nodes,
                "backend": jax_cl.dataplane.name,
                "alpha_beta_ms": res_s.sim_seconds * 1e3,
                "sim_wall_ms": wall_s * 1e3,
                "measured_wall_ms": wall_j * 1e3,
            })
            if scheme == "none":
                raw_res = raw_res or {}
                raw_res[n_elems] = res_s.sim_seconds
            else:
                assert wire < n_elems * 4, \
                    f"{cell}: compression did not shrink the wire"
                assert res_s.sim_seconds < raw_res[n_elems], \
                    f"{cell}: cheaper wire must show in the clock charge"

    emit(rows, "scheduled vs measured collective time per op x payload x "
               "participants")
    emit(comp_rows, "compression hop: wire bytes + clock charge, both "
                    "backends (identical accounting by construction)")
    backend = comp_rows[-1]["backend"]
    if backend == "sim":
        print("# single-device host: auto resolved to the sim plane "
              "(jax column == second sim run); force devices via "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "for a real jax column")
    print(f"# parity: {len(rows) + len(comp_rows)} cells byte-identical "
          f"across backends (backend={backend})")
    return {"cells": rows, "compression": comp_rows, "backend": backend}


if __name__ == "__main__":
    main()
