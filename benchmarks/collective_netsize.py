"""Paper Fig. 7/8/9 analogue: Bcast/Reduce/Barrier overhead vs network size.

The ad-hoc paper benchmark times each call with and without Legio while the
rank count grows. Reported per op and size: the baseline tree time, Legio
flat, Legio hierarchical (k from Eq. 3), each accumulated over 100
repetitions as in the paper.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.collectives import (
    HierarchicalCollectives,
    LinkModel,
    agreement_time,
    flat_collective_time,
)
from repro.core.hierarchy import LegionTopology
from repro.core.policy import optimal_k_linear

REPS = 100
PAYLOAD = 4096          # bytes, mid-size message
NET_SIZES = [8, 16, 32, 64, 128, 256, 512]


def run() -> list[dict]:
    link = LinkModel()
    rows = []
    for n in NET_SIZES:
        nodes = list(range(n))
        k = optimal_k_linear(n)
        hier = HierarchicalCollectives(LegionTopology.build(nodes, k), link)
        flat = HierarchicalCollectives(LegionTopology.flat(nodes), link)
        payload = np.zeros(PAYLOAD // 8, np.float64)
        contributions = {i: payload for i in nodes}

        for op in ("bcast", "reduce", "barrier"):
            if op == "bcast":
                t_f = flat.bcast(0, payload).sim_seconds
                t_h = hier.bcast(0, payload).sim_seconds
                base = flat_collective_time(link, "one_to_all", n, PAYLOAD)
            elif op == "reduce":
                t_f = flat.reduce(0, contributions).sim_seconds
                t_h = hier.reduce(0, contributions).sim_seconds
                base = flat_collective_time(link, "all_to_one", n, PAYLOAD)
            else:
                t_f = flat.barrier().sim_seconds
                t_h = hier.barrier().sim_seconds
                base = flat_collective_time(link, "all_to_all", n, 8)
            t_f += agreement_time(link, n)
            t_h += agreement_time(link, k)
            rows.append({
                "op": op, "ranks": n, "k_eq3": k,
                "ulfm_100x_ms": base * REPS * 1e3,
                "legio_flat_100x_ms": t_f * REPS * 1e3,
                "legio_hier_100x_ms": t_h * REPS * 1e3,
            })
    return rows


def main() -> None:
    rows = run()
    emit(rows, f"fig7/8/9: per-op overhead vs network size ({REPS} reps)")
    # the hierarchical curve must grow no faster than the baseline
    for op in ("bcast", "reduce", "barrier"):
        sel = [r for r in rows if r["op"] == op]
        growth_h = sel[-1]["legio_hier_100x_ms"] / sel[0]["legio_hier_100x_ms"]
        growth_b = sel[-1]["ulfm_100x_ms"] / sel[0]["ulfm_100x_ms"]
        verdict = "OK" if growth_h <= growth_b * 1.5 else "REGRESSION"
        print(f"# {op}: growth 8->512 ranks: baseline {growth_b:.2f}x, "
              f"hierarchical {growth_h:.2f}x [{verdict}]")


if __name__ == "__main__":
    main()
