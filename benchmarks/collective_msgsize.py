"""Paper Fig. 5/6 analogue: Bcast/Reduce time vs message size, three stacks.

The paper ran mpiBench on 32 ranks and compared (a) plain ULFM, (b) Legio
flat, (c) Legio hierarchical. Here the three stacks are (a) the raw
alpha-beta tree over the flat communicator, (b) flat + the per-call BNP
agreement (Legio's per-op overhead), (c) the hierarchical schedule + the
agreement bounded to the local_comm. The claim under test: the Legio curves
track the baseline's growth — the overhead does not damage message-size
scalability.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.collectives import (
    HierarchicalCollectives,
    LinkModel,
    agreement_time,
    flat_collective_time,
)
from repro.core.hierarchy import LegionTopology
from repro.core.policy import optimal_k_linear

N_RANKS = 32
SIZES = [2 ** p for p in range(4, 23, 2)]       # 16 B .. 4 MiB


def run() -> list[dict]:
    link = LinkModel()
    nodes = list(range(N_RANKS))
    k = optimal_k_linear(N_RANKS)
    topo = LegionTopology.build(nodes, k)
    hier = HierarchicalCollectives(topo, link)
    flat_topo = LegionTopology.flat(nodes)
    flat = HierarchicalCollectives(flat_topo, link)

    rows = []
    for op in ("bcast", "reduce"):
        for nbytes in SIZES:
            payload = np.zeros(max(nbytes // 8, 1), np.float64)
            contributions = {n: payload for n in nodes}
            base = flat_collective_time(link, "one_to_all", N_RANKS, nbytes)
            if op == "bcast":
                t_flat = flat.bcast(0, payload).sim_seconds
                t_hier = hier.bcast(0, payload).sim_seconds
            else:
                t_flat = flat.reduce(0, contributions).sim_seconds
                t_hier = hier.reduce(0, contributions).sim_seconds
            # Legio adds the BNP agreement per call (paper §IV)
            t_flat += agreement_time(link, N_RANKS)
            t_hier += agreement_time(link, k)
            rows.append({
                "op": op, "bytes": nbytes,
                "ulfm_us": base * 1e6,
                "legio_flat_us": t_flat * 1e6,
                "legio_hier_us": t_hier * 1e6,
                "flat_overhead_pct": 100 * (t_flat - base) / base,
                "hier_overhead_pct": 100 * (t_hier - base) / base,
            })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "fig5/6: collective time vs message size (32 ranks)")
    # scalability check: overheads flatten as message size grows
    big = [r for r in rows if r["bytes"] >= 2 ** 20]
    worst = max(abs(r["hier_overhead_pct"]) for r in big)
    print(f"# max |hierarchical overhead| at >=1MiB: {worst:.1f}% "
          f"({'OK: scalability preserved' if worst < 60 else 'REGRESSION'})")


if __name__ == "__main__":
    main()
