"""Scoped repair vs topology depth — the paper's §V scalability claim,
measured (and the point of the N-level generalization).

Three tables:

  1. **Repair participants vs n** (the headline): for depth 1/2/3 at fixed
     k, how many surviving nodes must enter the repair path for a worker
     fault and for a legion-master fault. Flat regresses to O(n); depth 2
     confines a worker fault to its legion but a master fault still drags
     every master into the global shrink (O(n/k)); depth 3 bounds the
     master case by the super-legion — O(k·d), independent of n.
  2. **Repair model cost vs n**: the S(x) sum of the scoped plan per case.
  3. **Concurrent scoped drain** (e2e): two faults injected the same step
     in disjoint subtrees of a depth-3 cluster repair as two terminal
     actions in ONE pipeline drain, with pairwise-disjoint participants,
     healthy subtrees reporting zero repair participation, and the
     simulated clock charged the max (not the sum) of the scope costs.

All asserts are structural (counts, set relations, plan shapes) — never
wall-clock — per the bench-smoke convention.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.detector import FaultInjector
from repro.core.executor import LegioExecutor, VirtualCluster
from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy
from repro.core.shrink import ShrinkEngine

SIZES = [64, 256, 1024, 4096]
K = 4


def _topo(n: int, depth: int) -> LegionTopology:
    if depth == 1:
        return LegionTopology.flat(list(range(n)))
    return LegionTopology.build(list(range(n)), K, depth=depth)


def _participants(topo: LegionTopology, victim: int) -> int:
    scopes = topo.partition_scopes({victim})
    assert len(scopes) == 1
    return scopes[0].n_participants


def _master_victim(topo: LegionTopology, depth: int) -> int:
    """A legion master that holds no higher mastership (the common case):
    master of the LAST legion — never the min of its super-group."""
    return topo.legions[-1].master if depth > 1 else topo.nodes[-1]


def participants_table() -> list[dict]:
    rows = []
    for n in SIZES:
        for depth in (1, 2, 3):
            topo = _topo(n, depth)
            worker = _participants(topo, topo.legions[-1].members[-1])
            master = _participants(topo, _master_victim(topo, depth))
            rows.append(dict(n=n, depth=depth, k=(n if depth == 1 else K),
                             worker_participants=worker,
                             master_participants=master))
    emit(rows, "repair participants per single fault (scoped)")

    by = {(r["n"], r["depth"]): r for r in rows}
    for n in SIZES:
        # flat: everyone repairs, O(n)
        assert by[(n, 1)]["worker_participants"] == n - 1
    for a, b in zip(SIZES, SIZES[1:]):
        # depth >= 2: worker-fault participants independent of n (= k - 1 +
        # nothing else: only the legion shrinks)
        for depth in (2, 3):
            assert by[(a, depth)]["worker_participants"] \
                == by[(b, depth)]["worker_participants"] == K - 1
        # depth 2: a master fault still involves every master -> grows with n
        assert by[(b, 2)]["master_participants"] \
            > by[(a, 2)]["master_participants"]
        # depth 3 (the tentpole claim): master-fault participants are
        # O(k·d) — a constant, independent of total n
        assert by[(a, 3)]["master_participants"] \
            == by[(b, 3)]["master_participants"]
    assert by[(SIZES[-1], 3)]["master_participants"] <= 3 * K + 2
    return rows


def cost_table() -> list[dict]:
    rows = []
    eng = ShrinkEngine(LegioPolicy())
    for n in SIZES:
        for depth in (1, 2, 3):
            topo = _topo(n, depth)
            worker_cost = sum(s.cost_units for s in eng.plan(
                topo, {topo.legions[-1].members[-1]}))
            master_cost = sum(s.cost_units for s in eng.plan(
                topo, {_master_victim(topo, depth)}))
            rows.append(dict(n=n, depth=depth,
                             worker_cost_s=worker_cost,
                             master_cost_s=master_cost))
    emit(rows, "scoped repair model cost S(x) sums (sim seconds)")
    by = {(r["n"], r["depth"]): r for r in rows}
    for a, b in zip(SIZES, SIZES[1:]):
        # flat repair cost grows with n; depth-3 master repair cost does not
        assert by[(b, 1)]["worker_cost_s"] > by[(a, 1)]["worker_cost_s"]
        assert by[(b, 3)]["master_cost_s"] == by[(a, 3)]["master_cost_s"]
    # and at the largest size the scoped hierarchical repair is far cheaper
    assert by[(SIZES[-1], 3)]["master_cost_s"] \
        < by[(SIZES[-1], 1)]["worker_cost_s"]
    return rows


def concurrent_drain() -> dict:
    """Two faults in disjoint subtrees of a 64-node depth-3 cluster, same
    step: one drain, two scoped terminal actions, healthy subtrees never
    enter the repair path."""
    n, fault_step = 64, 2
    victims = (5, 37)       # legion 1 (subtree 0) and legion 9 (subtree 2)
    pol = LegioPolicy(legion_size=K, hierarchy_depth=3)
    cl = VirtualCluster(n, policy=pol,
                        injector=FaultInjector.at([(fault_step, v)
                                                   for v in victims]))
    assert cl.topo.depth == 3
    subtree = {v: cl.topo.subtree_of(cl.topo.legion_of(v).index)
               for v in victims}
    assert subtree[victims[0]] != subtree[victims[1]]

    ex = LegioExecutor(cl, lambda node, shard, step: np.ones(2))
    for _ in range(fault_step):
        ex.run_step()
    clock_before = cl.clock.sim_seconds
    report = ex.run_step()                       # the fault step: ONE drain

    assert report.failed_now == victims
    assert len(report.actions) == 2              # one terminal action per scope
    scopes = [a.scope for a in report.actions]
    assert all(s is not None for s in scopes)
    p0, p1 = (set(s.participants) for s in scopes)
    assert p0 and p1 and not (p0 & p1)           # concurrent: disjoint
    # healthy subtrees report ZERO repair participation
    touched_legions = {li for s in scopes for li in s.legions}
    for lg in cl.topo.legions:
        if lg.index not in touched_legions:
            assert not (set(lg.members) & (p0 | p1))
    # the clock charged max(scope costs), not the sum — concurrent repair
    costs = [a.report.model_cost for a in report.actions]
    charged = cl.clock.sim_seconds - clock_before \
        - pol.step_sim_seconds - report.sim_collective_seconds
    assert abs(charged - max(costs)) < 1e-9
    assert charged < sum(costs)
    summary = dict(actions=len(report.actions),
                   participants=[len(p0), len(p1)],
                   charged_sim_s=charged, sum_costs_sim_s=sum(costs))
    emit([summary], "concurrent scoped drain (64 nodes, depth 3, 2 faults)")
    return summary


def main() -> dict:
    parts = participants_table()
    costs = cost_table()
    conc = concurrent_drain()
    print("[hierarchy_scaling] scoped repair participants O(k*d), "
          "independent of n; disjoint subtrees repaired concurrently: OK")
    return {"participants": parts, "costs": costs, "concurrent": conc}


if __name__ == "__main__":
    main()
