"""Shared benchmark plumbing: CSV emit + timing helpers."""
from __future__ import annotations

import csv
import io
import sys
import time
from contextlib import contextmanager


def emit(rows: list[dict], header: str = "") -> str:
    """Print rows as CSV to stdout; returns the CSV text."""
    if not rows:
        print("(no rows)")
        return ""
    buf = io.StringIO()
    writer = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
    writer.writeheader()
    for r in rows:
        writer.writerow({k: _fmt(v) for k, v in r.items()})
    text = buf.getvalue()
    if header:
        print(f"# {header}")
    sys.stdout.write(text)
    sys.stdout.flush()
    return text


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return v


def time_repeated(fn, repeats: int, *, warmup: int = 1) -> float:
    """Mean wall seconds per call over ``repeats`` (after warmup)."""
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


@contextmanager
def section(name: str):
    print(f"\n=== {name} ===")
    t0 = time.perf_counter()
    yield
    print(f"=== {name} done in {time.perf_counter() - t0:.1f}s ===")
