"""Transparency overhead: the MPI facade vs direct collective calls.

The paper's "negligible overhead" claim (§VI, Figs. 5-9): interposing every
MPI call must cost next to nothing on the fault-free path. Its deterministic
analogue here is *structural*, per the bench-smoke convention — wall-clock
asserts are banned, so the claim is measured in what the facade *does*:

  * **zero extra collective stages** — a bcast/reduce/allreduce issued on a
    :class:`repro.mpi.Comm` runs byte-identical payloads through exactly
    the schedule stages the direct :class:`HierarchicalCollectives` call
    runs, at every cluster size;
  * **O(1) bookkeeping per call** — the interposition adds exactly one
    pipeline drain per call (the PROC_FAILED trap + heartbeat check) and
    zero repair rounds, independent of cluster size: drains/call stays 1 at
    n=8 and at n=512;
  * **identical alpha-beta time** — the facade charges the same simulated
    collective seconds as the direct schedule (the overhead is bookkeeping,
    never traffic).

The emitted table carries wall-microsecond columns for dashboards; the
asserts never read them.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_repeated
from repro.core import HierarchicalCollectives, LegioPolicy
from repro.mpi import Session

SIZES = (8, 32, 128, 512)
PAYLOAD = 1024          # float64 elements per rank
CALLS = 5               # interposed calls measured per (size, op)


def run_size(n: int) -> dict:
    sess = Session(n, policy=LegioPolicy())
    comm = sess.world
    direct = HierarchicalCollectives(sess.cluster.topo, sess.cluster.link)
    payload = np.arange(PAYLOAD, dtype=np.float64)
    contributions = {m: payload * (m + 1) for m in comm.members}

    facade_stages = direct_stages = 0
    facade_sim = direct_sim = 0.0
    for _ in range(CALLS):
        fac = comm.allreduce(contributions)
        ref = direct.allreduce(dict(contributions))
        assert all(fac.data[m].tobytes() == ref.data[m].tobytes()
                   for m in ref.data), "facade payload diverged"
        assert fac.stages == ref.stages, "facade added collective stages"
        facade_stages += len(fac.stages)
        direct_stages += len(ref.stages)
        facade_sim += fac.sim_seconds
        direct_sim += ref.sim_seconds

    # structural claims
    assert facade_stages == direct_stages                     # zero extra
    assert abs(facade_sim - direct_sim) < 1e-12               # same traffic
    assert comm.stats.calls == CALLS
    assert comm.stats.drains == CALLS                         # 1 drain/call
    assert comm.stats.repair_rounds == 0                      # fault-free

    # dashboard-only wall numbers (never asserted)
    t_facade = time_repeated(lambda: comm.allreduce(contributions), 3)
    t_direct = time_repeated(
        lambda: direct.allreduce(dict(contributions)), 3)
    return {
        "n": n,
        "stages_per_call": facade_stages // CALLS,
        "extra_stages": facade_stages - direct_stages,
        "drains_per_call": comm.stats.drains / comm.stats.calls,
        "repair_rounds": comm.stats.repair_rounds,
        "sim_seconds_delta": facade_sim - direct_sim,
        "facade_us": t_facade * 1e6,
        "direct_us": t_direct * 1e6,
    }


def main() -> dict:
    rows = [run_size(n) for n in SIZES]
    emit(rows, header="MPI facade vs direct collectives, fault-free path "
                      "(structural: extra_stages == 0, drains/call == 1)")
    # the O(1) claim across sizes: bookkeeping does not grow with n
    drains = {r["drains_per_call"] for r in rows}
    assert drains == {1.0}, f"bookkeeping grew with cluster size: {drains}"
    assert all(r["extra_stages"] == 0 for r in rows)
    return {
        "sizes": list(SIZES),
        "drains_per_call": sorted(drains),
        "extra_stages": 0,
        "facade_us": {r["n"]: round(r["facade_us"], 1) for r in rows},
        "direct_us": {r["n"]: round(r["direct_us"], 1) for r in rows},
    }


if __name__ == "__main__":
    main()
