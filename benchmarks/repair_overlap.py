"""Background repair overlap — the revoke-then-repair proof artifact.

At n=4096, depth-3 (k=16), a legion-master fault tears a 61-participant
subtree. The blocking baseline charges the full hierarchical shrink —
S(x) summed over the scope's levels — to the fault step: every healthy
subtree waits. With ``repair_overlap`` the structural repair still lands
in the drain, but the *charge* goes to a :class:`BackgroundRepair`
window; healthy subtrees keep collecting on their pinned epoch with the
torn scope excluded from the schedule.

The headline assertion is exact, not approximate:

  * **overlap fault-step sim-seconds == fault-free step sim-seconds** —
    repair is *fully* hidden. Exactness holds structurally: per-level
    collective time is the max over parallel groups, tree rounds are
    ``ceil(log2 x)`` (flat across 9..16 members), and at n=4096 the 255
    untouched legions dominate every level's max, so excluding the torn
    scope moves no critical path.
  * **blocking fault-step == fault-free + repair model cost** — the
    retained baseline really pays S(x) in line.
  * **accounting closes** — once the window merges, ``hidden_seconds``
    equals the repair's model cost and ``residual_seconds`` is 0: the
    repair cost capacity, never time.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.executor import FaultInjector, LegioExecutor, VirtualCluster
from repro.core.policy import LegioPolicy

N = 4096
K = 16
DEPTH = 3
FAULT_STEP = 2
EPS = 1e-9


def _run(overlap: bool) -> dict:
    pol = LegioPolicy(legion_size=K, hierarchy_depth=DEPTH,
                      recovery_mode="shrink", repair_overlap=overlap)
    probe = VirtualCluster(N, policy=pol, injector=FaultInjector.at([]))
    victim = probe.topo.legions[-1].master        # interior master, not root
    cl = VirtualCluster(N, policy=pol,
                        injector=FaultInjector.at([(FAULT_STEP, victim)]))
    ex = LegioExecutor(cl, work_fn=lambda node, shard, step: 1.0)
    deltas = []
    for step in range(FAULT_STEP + 3):
        before = cl.clock.sim_seconds
        ex.run_step(step)
        deltas.append(cl.clock.sim_seconds - before)
    while cl.background:                          # let any tail window merge
        step += 1
        ex.run_step(step)
    assert len(cl.repairs) == 1
    return {
        "mode": "overlap" if overlap else "blocking",
        "victim": victim,
        "fault_free_step": deltas[FAULT_STEP - 1],
        "fault_step": deltas[FAULT_STEP],
        "repair_cost": cl.repairs[0].model_cost,
        "hidden": cl.clock.hidden_seconds,
        "residual": cl.clock.residual_seconds,
        "survivors": len(cl.live_nodes),
    }


def main() -> dict:
    blocking = _run(overlap=False)
    overlap = _run(overlap=True)

    # same fault, same structural outcome, same model cost either way
    assert overlap["victim"] == blocking["victim"]
    assert overlap["survivors"] == blocking["survivors"] == N - 1
    assert abs(overlap["repair_cost"] - blocking["repair_cost"]) < EPS

    # headline: the overlap fault step costs exactly a fault-free step
    assert abs(overlap["fault_step"] - overlap["fault_free_step"]) < EPS, \
        (overlap["fault_step"], overlap["fault_free_step"])
    # the retained baseline pays the repair in line
    assert abs(blocking["fault_step"]
               - (blocking["fault_free_step"] + blocking["repair_cost"])) \
        < EPS
    # accounting: the whole cost was absorbed behind compute, none waited
    assert abs(overlap["hidden"] - overlap["repair_cost"]) < EPS
    assert overlap["residual"] == 0.0
    assert blocking["hidden"] == blocking["residual"] == 0.0

    rows = [blocking, overlap]
    emit(rows, header=f"master-fault repair overlap, n={N} k={K} "
                      f"depth={DEPTH} (sim-seconds per step)")
    saved = blocking["fault_step"] - overlap["fault_step"]
    print(f"# overlap hides {overlap['hidden']:.4f}s of repair "
          f"({saved:.4f}s off the fault step) — fully hidden: "
          f"{abs(overlap['fault_step'] - overlap['fault_free_step']) < EPS}")
    return {
        "n": N, "k": K, "depth": DEPTH,
        "fault_free_step": overlap["fault_free_step"],
        "blocking_fault_step": blocking["fault_step"],
        "overlap_fault_step": overlap["fault_step"],
        "repair_cost": overlap["repair_cost"],
        "hidden_seconds": overlap["hidden"],
        "residual_seconds": overlap["residual"],
        "fully_hidden": bool(
            abs(overlap["fault_step"] - overlap["fault_free_step"]) < EPS),
    }


if __name__ == "__main__":
    raise SystemExit(0 if main() else 0)
