"""Paper Fig. 10 analogue: communicator repair time vs #processes — plus
the shrink-vs-substitute trade (Ashraf et al.).

Per cluster size:
  * model cost — the calibrated S(x) sum for flat vs hierarchical repair
    (worker- and master-failure cases, plus the 1/k-weighted expectation);
  * measured wall — our runtime's actual repair path (topology surgery +
    plan construction) on the virtual cluster, averaged over every node as
    the victim;
  * substitution — the same expectation for the substitute engine
    (teardown + splice + blocking restore, and the non-blocking splice
    where the restore overlaps useful work), and the *post-repair
    throughput*: the fraction of pre-fault capacity each mode keeps.

The paper's observation that the average hierarchical repair is cheaper on
256 ranks "since the probability for a master node to fail is contained
(1/8)" is exactly the expectation row here. Substitution pays more at
repair time but runs at 100% capacity afterwards — shrink's throughput is
(n-1)/n forever, so substitution amortizes within a handful of steps.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy, optimal_k_linear
from repro.core.shrink import ShrinkCostModel, ShrinkEngine
from repro.core.substitute import SparePool, SubstituteCostModel, SubstituteEngine

SIZES = [16, 32, 64, 128, 256, 512]


def _sub_policy(k: int) -> LegioPolicy:
    return LegioPolicy(legion_size=k, recovery_mode="substitute_then_shrink")


def measure_wall(n: int, k: int | None) -> float:
    """Mean wall seconds of the shrink repair path over all single victims."""
    eng = ShrinkEngine(LegioPolicy())
    total = 0.0
    victims = list(range(n))
    for victim in victims:
        topo = (LegionTopology.build(list(range(n)), k) if k
                else LegionTopology.flat(list(range(n))))
        t0 = time.perf_counter()
        eng.repair(topo, {victim})
        total += time.perf_counter() - t0
    return total / len(victims)


def measure_substitute_wall(n: int, k: int) -> float:
    """Mean wall seconds of the substitution repair path (splice included)."""
    total = 0.0
    victims = list(range(n))
    for victim in victims:
        topo = LegionTopology.build(list(range(n)), k)
        eng = SubstituteEngine(_sub_policy(k))
        pool = SparePool(capacity=1, available=[n])
        t0 = time.perf_counter()
        eng.repair(topo, {victim}, pool)
        total += time.perf_counter() - t0
    return total / len(victims)


def run() -> list[dict]:
    eng = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=1.0))
    rows = []
    for n in SIZES:
        k = optimal_k_linear(n)
        sub = SubstituteEngine(_sub_policy(k),
                               SubstituteCostModel(shrink=eng.cost))
        rows.append({
            "ranks": n,
            "k_eq3": k,
            "flat_model_s": eng.cost_flat(n),
            "hier_worker_model_s": eng.cost_hierarchical(n, k, False),
            "hier_master_model_s": eng.cost_hierarchical(n, k, True),
            "hier_expected_model_s": eng.expected_repair_cost(n, k),
            "sub_expected_model_s": sub.expected_repair_cost(n, k),
            "sub_nonblocking_model_s": sub.expected_repair_cost(
                n, k, blocking=False),
            "flat_wall_us": measure_wall(n, None) * 1e6,
            "hier_wall_us": measure_wall(n, k) * 1e6,
            "sub_wall_us": measure_substitute_wall(n, k) * 1e6,
            "shrink_post_repair_capacity": (n - 1) / n,
            "sub_post_repair_capacity": 1.0,
        })
    return rows


def measure_pipeline_stages(n: int = 64, n_faults: int = 8) -> dict:
    """Per-stage latency breakdown of the fault pipeline
    (detect / notice / agree / plan / apply) over a fault campaign — the
    event-driven analogue of Fig. 10's single repair-time number: apply
    (the strategy's topology surgery) dominates, agreement and noticing are
    noise, which is exactly why the non-blocking flavor overlaps apply with
    useful work."""
    import numpy as np

    from repro.core.detector import FaultInjector
    from repro.core.executor import LegioExecutor, VirtualCluster

    k = optimal_k_linear(n)
    victims = [(2 + i, 2 * i + 1) for i in range(n_faults)]
    pol = LegioPolicy(legion_size=k, recovery_mode="substitute_then_shrink",
                      spare_fraction=0.25)
    cl = VirtualCluster(n, policy=pol, injector=FaultInjector.at(victims))
    ex = LegioExecutor(cl, lambda node, s, t: np.ones(1))
    ex.run(n_faults + 4)
    stages = ("detect", "notice", "agree", "plan", "apply")
    out = {f"{st}_us": 0.0 for st in stages}
    traces = cl.pipeline.traces
    for tr in traces:
        for st in stages:
            out[f"{st}_us"] += tr.stage_seconds.get(st, 0.0) * 1e6
    n_drains = max(len(traces), 1)
    out = {k_: v / n_drains for k_, v in out.items()}
    out["drains"] = len(traces)
    out["total_us"] = sum(out[f"{st}_us"] for st in stages)
    return out


def measure_exhaustion_campaign(n: int = 16, spares: int = 2,
                                faults: int = 4, steps: int = 14) -> dict:
    """Spare-exhaustion campaign: more faults than provisioned spares under
    substitute_then_shrink, with the elastic SpareProvisioner on vs off.
    Without it the run stays degraded forever (the PR-1 gap); with it the
    backlog heals once re-spawned spares come up and throughput returns to
    100% of pre-fault capacity."""
    import numpy as np

    from repro.core.detector import FaultInjector
    from repro.core.executor import LegioExecutor, VirtualCluster

    out = {}
    for label, watermark in (("provisioner_off", 0), ("provisioner_on", spares)):
        pol = LegioPolicy(
            legion_size=optimal_k_linear(n),
            recovery_mode="substitute_then_shrink",
            spare_nodes=spares,
            spare_refill_watermark=watermark,
            spare_provision_delay_steps=2,
            spare_churn_cap=2 * faults,
        )
        cl = VirtualCluster(n, policy=pol, injector=FaultInjector.at(
            [(2, 2 * i + 1) for i in range(faults)]))
        ex = LegioExecutor(cl, lambda node, s, t: np.ones(1))
        reports = ex.run(steps)
        nodes_per_step = [len(rep.results) for rep in reports]
        recovered = next((r.step for r in reports
                          if r.step > 2 and len(r.results) == n), None)
        out[label] = {
            "final_nodes": cl.topo.size,
            "final_shards_per_step": cl.plan.active_shards,
            "capacity_fraction": cl.plan.active_shards / n,
            "respawned_spares": cl.provisioner.spawned,
            "recovered_at_step": recovered,
            "min_computing_nodes": min(nodes_per_step),
        }
    return out


def measure_post_repair_throughput(n: int = 16, steps: int = 6) -> dict:
    """End-to-end per-step throughput (shards computed per step) after one
    injected fault, shrink vs substitute — the capacity-preservation claim
    measured on the actual executor."""
    import numpy as np

    from repro.core.detector import FaultInjector
    from repro.core.executor import LegioExecutor, VirtualCluster

    out = {}
    for mode in ("shrink", "substitute"):
        pol = LegioPolicy(legion_size=optimal_k_linear(n), recovery_mode=mode,
                          spare_fraction=0.25 if mode != "shrink" else 0.0)
        cl = VirtualCluster(n, policy=pol,
                            injector=FaultInjector.at([(1, n // 2)]))
        ex = LegioExecutor(cl, lambda node, s, t: np.ones(1))
        ex.run(steps)
        out[mode] = {
            "post_fault_shards_per_step": cl.plan.active_shards,
            "final_nodes": cl.topo.size,
            "repair_model_s": sum(r.model_cost for r in cl.repairs),
        }
    return out


def main() -> None:
    rows = run()
    emit(rows, "fig10: repair time vs #processes (+ substitution)")
    r256 = next(r for r in rows if r["ranks"] == 256)
    assert r256["hier_expected_model_s"] < r256["flat_model_s"], \
        "hierarchical expected repair must beat flat at 256 ranks (paper)"
    assert r256["sub_expected_model_s"] > r256["hier_expected_model_s"], \
        "substitution must cost more at repair time (splice + restore)"
    assert r256["sub_nonblocking_model_s"] < r256["sub_expected_model_s"], \
        "non-blocking substitution must hide the restore term"
    print(f"# 256 ranks: expected hierarchical repair "
          f"{r256['hier_expected_model_s']:.3f}s vs flat "
          f"{r256['flat_model_s']:.3f}s "
          f"(paper: hierarchical wins on average, master prob 1/k)")
    print(f"# 256 ranks: substitution repair "
          f"{r256['sub_expected_model_s']:.3f}s (non-blocking "
          f"{r256['sub_nonblocking_model_s']:.3f}s) buys back "
          f"{(1.0 - r256['shrink_post_repair_capacity']) * 100:.2f}% capacity")
    tp = measure_post_repair_throughput()
    assert tp["substitute"]["post_fault_shards_per_step"] > \
        tp["shrink"]["post_fault_shards_per_step"], \
        "substitute must out-throughput shrink after the fault"
    print(f"# e2e post-fault throughput (16 nodes, 1 fault): "
          f"shrink {tp['shrink']['post_fault_shards_per_step']} shards/step, "
          f"substitute {tp['substitute']['post_fault_shards_per_step']} "
          f"shards/step at +"
          f"{tp['substitute']['repair_model_s'] - tp['shrink']['repair_model_s']:.3f}s "
          f"one-time repair cost")

    stages = measure_pipeline_stages()
    emit([stages], "fault-pipeline stage latency (mean us per drain)")
    # structural assertions only — relative stage timings are microseconds
    # and would flake on loaded CI runners
    assert stages["drains"] == 8, "every injected fault must drain once"
    assert all(stages[f"{st}_us"] >= 0.0 for st in
               ("detect", "notice", "agree", "plan", "apply")), \
        "every pipeline stage must be timed"
    assert stages["total_us"] > 0.0
    print(f"# pipeline drain breakdown (64 ranks, 8 faults): "
          f"detect {stages['detect_us']:.1f}us  notice {stages['notice_us']:.1f}us  "
          f"agree {stages['agree_us']:.1f}us  plan {stages['plan_us']:.1f}us  "
          f"apply {stages['apply_us']:.1f}us")

    camp = measure_exhaustion_campaign()
    emit([{"provisioner": k_, **v} for k_, v in camp.items()],
         "spare-exhaustion campaign: elastic re-spawn on vs off")
    assert camp["provisioner_on"]["capacity_fraction"] == 1.0, \
        "elastic re-spawn must return the campaign to full capacity"
    assert camp["provisioner_off"]["capacity_fraction"] < 1.0, \
        "without the provisioner an exhausted campaign stays degraded"
    print(f"# exhaustion campaign (16 nodes, 4 faults, 2 spares): "
          f"off -> {camp['provisioner_off']['capacity_fraction'] * 100:.0f}% "
          f"capacity forever; on -> "
          f"{camp['provisioner_on']['capacity_fraction'] * 100:.0f}% after "
          f"{camp['provisioner_on']['respawned_spares']} re-spawns "
          f"(recovered at step {camp['provisioner_on']['recovered_at_step']})")


if __name__ == "__main__":
    main()
