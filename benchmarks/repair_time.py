"""Paper Fig. 10 analogue: communicator repair time vs #processes.

Two quantities per cluster size:
  * model cost — the calibrated S(x) sum for flat vs hierarchical repair
    (worker- and master-failure cases, plus the 1/k-weighted expectation);
  * measured wall — our runtime's actual repair path (topology surgery +
    plan construction) on the virtual cluster, averaged over every node as
    the victim.

The paper's observation that the average hierarchical repair is cheaper on
256 ranks "since the probability for a master node to fail is contained
(1/8)" is exactly the expectation row here.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy, optimal_k_linear
from repro.core.shrink import ShrinkCostModel, ShrinkEngine

SIZES = [16, 32, 64, 128, 256, 512]


def measure_wall(n: int, k: int | None) -> float:
    """Mean wall seconds of the repair path over all single-node victims."""
    eng = ShrinkEngine(LegioPolicy())
    total = 0.0
    victims = list(range(n))
    for victim in victims:
        topo = (LegionTopology.build(list(range(n)), k) if k
                else LegionTopology.flat(list(range(n))))
        t0 = time.perf_counter()
        eng.repair(topo, {victim})
        total += time.perf_counter() - t0
    return total / len(victims)


def run() -> list[dict]:
    eng = ShrinkEngine(LegioPolicy(), ShrinkCostModel(p=1.0))
    rows = []
    for n in SIZES:
        k = optimal_k_linear(n)
        rows.append({
            "ranks": n,
            "k_eq3": k,
            "flat_model_s": eng.cost_flat(n),
            "hier_worker_model_s": eng.cost_hierarchical(n, k, False),
            "hier_master_model_s": eng.cost_hierarchical(n, k, True),
            "hier_expected_model_s": eng.expected_repair_cost(n, k),
            "flat_wall_us": measure_wall(n, None) * 1e6,
            "hier_wall_us": measure_wall(n, k) * 1e6,
        })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "fig10: repair time vs #processes")
    r256 = next(r for r in rows if r["ranks"] == 256)
    assert r256["hier_expected_model_s"] < r256["flat_model_s"], \
        "hierarchical expected repair must beat flat at 256 ranks (paper)"
    print(f"# 256 ranks: expected hierarchical repair "
          f"{r256['hier_expected_model_s']:.3f}s vs flat "
          f"{r256['flat_model_s']:.3f}s "
          f"(paper: hierarchical wins on average, master prob 1/k)")


if __name__ == "__main__":
    main()
