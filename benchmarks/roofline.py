"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and prints
per (arch × shape × mesh): the three roofline terms, the dominant bound,
MODEL_FLOPS/HLO_FLOPs, and bytes-per-device vs the v5e HBM.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.common import emit

DEFAULT_DIR = Path("experiments/dryrun")


def load(dirpath: Path) -> list[dict]:
    rows = []
    for path in sorted(dirpath.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("skipped"):
            continue
        terms = rec["roofline"]
        mem = rec["memory_analysis"]
        rows.append({
            "arch": rec["arch"],
            "shape": rec["shape"],
            "mesh": "pod2" if rec["multi_pod"] else "pod1",
            "compute_ms": terms["compute_s"] * 1e3,
            "memory_ms": terms["memory_s"] * 1e3,
            "collective_ms": terms["collective_s"] * 1e3,
            "dominant": terms["dominant"].replace("_s", ""),
            "roofline_frac": terms["roofline_fraction"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
            "peak_GiB_per_dev": mem["peak_bytes_per_device"] / 2 ** 30,
            "fits_v5e_16G": mem["peak_bytes_per_device"] < 16e9,
            "tag": rec.get("overrides", {}) and "tuned" or "base",
        })
    return rows


def main(dirpath: "str | Path | None" = None) -> dict:
    """Emit the roofline table from ``dirpath`` (default
    experiments/dryrun). Called by benchmarks.run with its ``--dryrun-dir``
    value; skips with a message — not an error — when no artifacts exist."""
    dirpath = Path(dirpath) if dirpath else DEFAULT_DIR
    rows = load(dirpath) if dirpath.is_dir() else []
    if not rows:
        msg = (f"no dry-run artifacts in {dirpath} — run "
               f"`python -m repro.launch.dryrun --all --both-meshes` first")
        print(f"# {msg}")
        return {"skipped": msg}
    emit(rows, f"roofline terms per (arch x shape x mesh) from {dirpath}")
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    fits = sum(r["fits_v5e_16G"] for r in rows)
    print(f"# dominant-term census: {doms}; {fits}/{len(rows)} cells fit 16G HBM")
    return {"rows": len(rows), "dominant": doms, "fits_16G": fits}


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
