"""Benchmark harness entry: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig10 ep   # substring filter
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import section

SUITES = [
    # (key, module, paper anchor)
    ("fig5_6_msgsize", "benchmarks.collective_msgsize", "Fig. 5/6"),
    ("fig7_8_9_netsize", "benchmarks.collective_netsize", "Fig. 7/8/9"),
    ("fig10_repair", "benchmarks.repair_time", "Fig. 10"),
    ("fig11_nas_ep", "benchmarks.app_ep", "Fig. 11"),
    ("fig12_docking", "benchmarks.app_docking", "Fig. 12"),
    ("eq3_4_optimal_k", "benchmarks.optimal_k", "Eq. 3/4"),
    ("repair_recompile", "benchmarks.repair_recompile", "beyond-paper"),
    ("serve_latency", "benchmarks.serve_latency", "beyond-paper"),
    ("roofline", "benchmarks.roofline", "EXPERIMENTS §Roofline"),
]


def main() -> int:
    filters = [a.lower() for a in sys.argv[1:]]
    failures = []
    for key, module, anchor in SUITES:
        if filters and not any(f in key for f in filters):
            continue
        with section(f"{key} ({anchor})"):
            try:
                mod = __import__(module, fromlist=["main"])
                mod.main()
            except Exception:
                traceback.print_exc()
                failures.append(key)
    print(f"\n[benchmarks] {'ALL OK' if not failures else 'FAILED: ' + ', '.join(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
