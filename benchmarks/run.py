"""Benchmark harness entry: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig10 ep   # substring filter
  PYTHONPATH=src python -m benchmarks.run --json fig10 optimal_k hierarchy
                                                     # + machine-readable
                                                     #   BENCH_PR10.json

``--json`` records per-suite status/wall-seconds (and whatever dict a
suite's ``main()`` returns) to ``BENCH_PR10.json`` — the CI artifact. The
asserts inside the suites stay structural (the bench-smoke convention);
the JSON is for dashboards, not pass/fail. ``--dryrun-dir PATH`` points the
roofline suite at a directory of ``repro.launch.dryrun`` artifacts (it
skips with a message when none exist).
"""
from __future__ import annotations

import json
import sys
import time
import traceback

from benchmarks.common import section

SUITES = [
    # (key, module, paper anchor)
    ("fig5_6_msgsize", "benchmarks.collective_msgsize", "Fig. 5/6"),
    ("fig7_8_9_netsize", "benchmarks.collective_netsize", "Fig. 7/8/9"),
    ("fig10_repair", "benchmarks.repair_time", "Fig. 10"),
    ("repair_overlap", "benchmarks.repair_overlap",
     "beyond-paper background repair"),
    ("fig11_nas_ep", "benchmarks.app_ep", "Fig. 11"),
    ("fig12_docking", "benchmarks.app_docking", "Fig. 12"),
    ("eq3_4_optimal_k", "benchmarks.optimal_k", "Eq. 3/4"),
    ("hierarchy_scaling", "benchmarks.hierarchy_scaling", "§V scalability"),
    ("repair_recompile", "benchmarks.repair_recompile", "beyond-paper"),
    ("serve_latency", "benchmarks.serve_latency",
     "beyond-paper load curve"),
    ("interposition_overhead", "benchmarks.interposition_overhead",
     "§VI transparency overhead"),
    ("roofline", "benchmarks.roofline", "EXPERIMENTS §Roofline"),
    ("dataplane_roofline", "benchmarks.dataplane_roofline",
     "beyond-paper data-plane seam"),
    ("chaos_campaign", "benchmarks.chaos_campaign",
     "§III-V fault-model zoo"),
    ("recovery_cost", "benchmarks.recovery_cost",
     "beyond-paper peer restore + adaptive recovery"),
]

JSON_PATH = "BENCH_PR10.json"


def main() -> int:
    args = sys.argv[1:]
    write_json = "--json" in args
    dryrun_dir = None
    for i, a in enumerate(list(args)):
        if a.startswith("--dryrun-dir="):
            dryrun_dir = a.split("=", 1)[1]
            args.remove(a)
        elif a == "--dryrun-dir" and i + 1 < len(args):
            dryrun_dir = args[i + 1]
            args.remove(dryrun_dir)
            args.remove(a)
    filters = [a.lower() for a in args if not a.startswith("--")]
    failures = []
    results: list[dict] = []
    for key, module, anchor in SUITES:
        if filters and not any(f in key for f in filters):
            continue
        with section(f"{key} ({anchor})"):
            t0 = time.perf_counter()
            entry = {"suite": key, "anchor": anchor, "status": "ok"}
            try:
                mod = __import__(module, fromlist=["main"])
                # the roofline suite reads dry-run artifacts — thread the
                # directory through instead of leaking run.py's own argv
                data = (mod.main(dryrun_dir) if key == "roofline"
                        else mod.main())
                if isinstance(data, dict):
                    entry["data"] = data
            except Exception:
                traceback.print_exc()
                failures.append(key)
                entry["status"] = "failed"
            entry["wall_seconds"] = round(time.perf_counter() - t0, 3)
            results.append(entry)
    if write_json:
        payload = {
            "suites": results,
            "failed": failures,
            "ok": not failures,
        }
        with open(JSON_PATH, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"\n[benchmarks] wrote {JSON_PATH} "
              f"({len(results)} suite(s), {len(failures)} failure(s))")
    print(f"\n[benchmarks] {'ALL OK' if not failures else 'FAILED: ' + ', '.join(failures)}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
