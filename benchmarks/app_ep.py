"""Paper Fig. 11 analogue: the NAS EP benchmark under Legio.

NAS EP generates independent Gaussian pairs with the Marsaglia polar method
and reduces per-rank counts — the canonical embarrassingly parallel MPI
program. We run it on the virtual cluster in three configurations (baseline
/ Legio flat / Legio hierarchical) across cluster sizes, and additionally
with an injected fault, verifying the statistical result degrades gracefully
(the paper's "approximate result" trade-off).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_repeated
from repro.core import FaultInjector, LegioExecutor, LegioPolicy, VirtualCluster

PAIRS_PER_SHARD = 20_000
SIZES = [8, 16, 32, 64]


def marsaglia_counts(node: int, shard: int, step: int) -> np.ndarray:
    """One shard's Marsaglia polar sweep -> [accepted, sum_x, sum_y]."""
    rng = np.random.default_rng(shard * 1_000_003 + step)
    u = rng.uniform(-1, 1, (PAIRS_PER_SHARD, 2))
    s = np.sum(u * u, axis=1)
    ok = (s > 0) & (s < 1)
    factor = np.sqrt(-2 * np.log(s[ok]) / s[ok])
    g = u[ok] * factor[:, None]
    return np.array([ok.sum(), g[:, 0].sum(), g[:, 1].sum()])


def run_config(n_nodes: int, hierarchical: bool, fail: bool) -> tuple[float, dict]:
    inj = FaultInjector.at([(1, 1)]) if fail else FaultInjector()
    policy = LegioPolicy(
        hierarchical_threshold=0 if hierarchical else 10 ** 9,
        straggler_threshold=0.0)
    cl = VirtualCluster(n_nodes, policy=policy, injector=inj)
    ex = LegioExecutor(cl, marsaglia_counts)

    def step():
        return ex.run_step()

    secs = time_repeated(step, repeats=3, warmup=1)
    last = ex.run_step()
    accepted, sx, sy = last.reduced
    shards = cl.plan.active_shards
    stats = {
        "acceptance": accepted / (shards * PAIRS_PER_SHARD),
        "mean_x": sx / max(accepted, 1),
        "survivors": len(cl.live_nodes),
    }
    return secs, stats


def run() -> list[dict]:
    rows = []
    for n in SIZES:
        base_s, base_stats = run_config(n, hierarchical=False, fail=False)
        hier_s, _ = run_config(n, hierarchical=True, fail=False)
        fail_s, fail_stats = run_config(n, hierarchical=True, fail=True)
        rows.append({
            "ranks": n,
            "flat_step_ms": base_s * 1e3,
            "hier_step_ms": hier_s * 1e3,
            "hier_overhead_pct": 100 * (hier_s - base_s) / base_s,
            "faulted_step_ms": fail_s * 1e3,
            "acceptance_nofault": base_stats["acceptance"],
            "acceptance_faulted": fail_stats["acceptance"],
            "survivors_after_fault": fail_stats["survivors"],
        })
    return rows


def main() -> None:
    rows = run()
    emit(rows, "fig11: NAS EP (Marsaglia polar) under Legio")
    # statistical validity: acceptance rate stays pi/4 despite the fault
    for r in rows:
        for col in ("acceptance_nofault", "acceptance_faulted"):
            assert abs(r[col] - np.pi / 4) < 0.01, (r["ranks"], col, r[col])
    worst = max(abs(r["hier_overhead_pct"]) for r in rows)
    print(f"# acceptance == pi/4 +- 1% in ALL configs (result stays valid "
          f"after discard); max hier overhead {worst:.1f}%")


if __name__ == "__main__":
    main()
