"""Encoder-decoder transformer (whisper-style backbone).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, D) — the conv mel frontend is not
modeled. Positions are sinusoidal (computed on the fly, so decode length is
not baked into parameters).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import gather_fsdp, shard_activations
from repro.models.attention import attention, decode_attention
from repro.models.common import (
    activation_fn,
    cross_entropy_chunked,
    dense_init,
    embed_init,
    rms_norm,
    sinusoidal_positions,
    softcap,
)

Params = dict[str, Any]


def _init_attn(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    return {
        "wq": dense_init(ks[0], (D, cfg.q_dim), dtype),
        "wk": dense_init(ks[1], (D, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (D, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, D), dtype),
    }


def _init_mlp(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 2)
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_in": dense_init(ks[0], (D, F), dtype),
        "w_out": dense_init(ks[1], (F, D), dtype),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    D = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": jnp.zeros((D,), dtype), "attn": _init_attn(cfg, k1, dtype),
            "mlp_norm": jnp.zeros((D,), dtype), "mlp": _init_mlp(cfg, k2, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "attn_norm": jnp.zeros((D,), dtype), "attn": _init_attn(cfg, k1, dtype),
            "cross_norm": jnp.zeros((D,), dtype), "cross": _init_attn(cfg, k2, dtype),
            "mlp_norm": jnp.zeros((D,), dtype), "mlp": _init_mlp(cfg, k3, dtype),
        }

    enc_keys = jax.random.split(keys[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    return {
        "embed": embed_init(keys[2], (cfg.vocab_size, D), dtype),
        "enc_layers": jax.tree.map(lambda *x: jnp.stack(x, 0), *[enc_layer(k) for k in enc_keys]),
        "dec_layers": jax.tree.map(lambda *x: jnp.stack(x, 0), *[dec_layer(k) for k in dec_keys]),
        "enc_norm": jnp.zeros((D,), dtype),
        "final_norm": jnp.zeros((D,), dtype),
    }


def _self_attn(cfg, lp, h, *, causal):
    B, S, _ = h.shape
    q = (h @ lp["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    o = attention(q, k, v, cfg, causal=causal, window=0)
    return o.reshape(B, S, cfg.q_dim) @ lp["wo"], k, v


def _cross_attn(cfg, lp, h, enc_k, enc_v):
    B, S, _ = h.shape
    q = (h @ lp["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    o = attention(q, enc_k, enc_v, cfg, causal=False, window=0)
    return o.reshape(B, S, cfg.q_dim) @ lp["wo"]


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) precomputed embeddings (stub frontend)."""
    dtype = jnp.dtype(cfg.dtype)
    S = frames.shape[1]
    x = frames.astype(dtype) + sinusoidal_positions(S, cfg.d_model).astype(dtype)[None]

    def body(carry, lp):
        lp = gather_fsdp(lp, cfg.act_shard)
        h = rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
        o, _, _ = _self_attn(cfg, lp["attn"], h, causal=False)
        x2 = carry + o
        h2 = rms_norm(x2, lp["mlp_norm"], cfg.norm_eps)
        act = activation_fn(cfg.activation)
        out = x2 + act(h2 @ lp["mlp"]["w_in"]) @ lp["mlp"]["w_out"]
        return shard_activations(out, cfg.act_shard), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _enc_kv(cfg, dec_layers, enc_out):
    """Precompute per-decoder-layer cross K/V from encoder output."""
    B, Se, _ = enc_out.shape

    def per_layer(_, lp):
        k = (enc_out @ lp["cross"]["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        v = (enc_out @ lp["cross"]["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        return None, (k, v)

    _, (ek, ev) = jax.lax.scan(per_layer, None, dec_layers)
    return ek, ev  # (L, B, Se, K, hd)


def decode_train(cfg: ModelConfig, params: Params, tokens: jax.Array,
                 enc_out: jax.Array, collect_kv: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = x + sinusoidal_positions(S, cfg.d_model).astype(dtype)[None]

    def body(carry, lp):
        lp = gather_fsdp(lp, cfg.act_shard)
        h = rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
        o, k, v = _self_attn(cfg, lp["attn"], h, causal=True)
        x2 = carry + o
        hc = rms_norm(x2, lp["cross_norm"], cfg.norm_eps)
        Bq, Se, _ = enc_out.shape
        ek = (enc_out @ lp["cross"]["wk"]).reshape(Bq, Se, cfg.n_kv_heads, cfg.head_dim)
        ev = (enc_out @ lp["cross"]["wv"]).reshape(Bq, Se, cfg.n_kv_heads, cfg.head_dim)
        x3 = x2 + _cross_attn(cfg, lp["cross"], hc, ek, ev)
        h2 = rms_norm(x3, lp["mlp_norm"], cfg.norm_eps)
        act = activation_fn(cfg.activation)
        out = x3 + act(h2 @ lp["mlp"]["w_in"]) @ lp["mlp"]["w_out"]
        return shard_activations(out, cfg.act_shard), (k, v) if collect_kv else None

    fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, kv = jax.lax.scan(fn, x, params["dec_layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), kv


def train_loss(cfg: ModelConfig, params: Params, batch: dict) -> tuple[jax.Array, dict]:
    """batch: embeds (B,S_enc,D) stub audio frames, tokens/labels (B,S)."""
    enc_out = encode(cfg, params, batch["embeds"])
    hidden, _ = decode_train(cfg, params, batch["tokens"], enc_out)
    loss, metrics = cross_entropy_chunked(
        hidden, params["embed"], batch["labels"], chunk=cfg.xent_chunk,
        z_loss_weight=cfg.z_loss_weight,
    )
    metrics["loss"] = loss
    return loss, metrics


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    L = cfg.n_layers
    dtype = jnp.dtype(cfg.dtype)
    Se = cfg.encoder_seq_len
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "cross_k": jnp.zeros((L, batch, Se, cfg.n_kv_heads, cfg.head_dim), dtype),
        "cross_v": jnp.zeros((L, batch, Se, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, max_len: int,
            *, embeds: jax.Array) -> tuple[jax.Array, dict]:
    B, S = tokens.shape
    enc_out = encode(cfg, params, embeds)
    hidden, kv = decode_train(cfg, params, tokens, enc_out, collect_kv=True)
    k_all, v_all = kv
    pad = max_len - S
    padk = jnp.zeros((cfg.n_layers, B, pad, cfg.n_kv_heads, cfg.head_dim), k_all.dtype)
    ck, cv = _enc_kv(cfg, params["dec_layers"], enc_out)
    cache = {
        "pos": jnp.asarray(S, jnp.int32),
        "k": jnp.concatenate([k_all, padk], axis=2),
        "v": jnp.concatenate([v_all, padk], axis=2),
        "cross_k": ck, "cross_v": cv,
    }
    logits = hidden[:, -1:, :].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    # sinusoidal position embedding at (dynamic) position `pos`
    half = cfg.d_model // 2
    inv = jnp.exp(-math.log(10000.0) / max(half - 1, 1) * jnp.arange(half, dtype=jnp.float32))
    ang = pos.astype(jnp.float32) * inv
    x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :].astype(dtype)

    def body(carry, xs):
        lp, lc = xs
        C = lc["k"].shape[1]
        h = rms_norm(carry, lp["attn_norm"], cfg.norm_eps)
        q = (h @ lp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        k_cache = jax.lax.dynamic_update_slice_in_dim(lc["k"], k, pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(lc["v"], v, pos, axis=1)
        valid = jnp.broadcast_to((jnp.arange(C) <= pos)[None, :], (B, C))
        o = decode_attention(q, k_cache, v_cache, valid,
                             head_shard=cfg.act_shard)
        x2 = carry + o.reshape(B, 1, cfg.q_dim) @ lp["attn"]["wo"]
        hc = rms_norm(x2, lp["cross_norm"], cfg.norm_eps)
        qc = (hc @ lp["cross"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        Se = lc["cross_k"].shape[1]
        validc = jnp.ones((B, Se), bool)
        oc = decode_attention(qc, lc["cross_k"], lc["cross_v"], validc,
                              head_shard=cfg.act_shard)
        x3 = x2 + oc.reshape(B, 1, cfg.q_dim) @ lp["cross"]["wo"]
        h2 = rms_norm(x3, lp["mlp_norm"], cfg.norm_eps)
        act = activation_fn(cfg.activation)
        out = x3 + act(h2 @ lp["mlp"]["w_in"]) @ lp["mlp"]["w_out"]
        return out, {"k": k_cache, "v": v_cache}

    layer_caches = {k: cache[k] for k in ("k", "v", "cross_k", "cross_v")}
    x, new_kv = jax.lax.scan(body, x, (params["dec_layers"], layer_caches))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    new_cache = dict(cache)
    new_cache["k"] = new_kv["k"]
    new_cache["v"] = new_kv["v"]
    new_cache["pos"] = pos + 1
    return logits, new_cache
