"""Decoder-only transformer LM covering the dense / moe / vlm / hybrid families.

Layers are scan-stacked: every parameter leaf under ``params["layers"]`` has a
leading ``(L, ...)`` dimension and the forward pass is a single
``jax.lax.scan`` — HLO size is depth-independent (deepseek-67b's 95 layers
compile as fast as 2) and remat policy applies per layer.

Decode uses a ring-buffer KV cache when ``cfg.sliding_window > 0`` (slot =
pos % window) so `long_500k` SWA decoding holds a bounded cache; hybrid layers
additionally carry the SSD recurrent state.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import gather_fsdp, shard_activations, shard_heads
from repro.models import ssd as ssd_mod
from repro.models.attention import attention, decode_attention
from repro.models.common import (
    activation_fn,
    apply_rope,
    cross_entropy_chunked,
    dense_init,
    embed_init,
    rms_norm,
    softcap,
)
from repro.models.moe import init_moe_params, moe_ffn

Params = dict[str, Any]


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def _init_attn(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    return {
        "wq": dense_init(ks[0], (D, cfg.q_dim), dtype),
        "wk": dense_init(ks[1], (D, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (D, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, D), dtype, scale=1.0 / (cfg.q_dim ** 0.5 * cfg.n_layers ** 0.5)),
    }


def _init_mlp(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 3)
    D, F = cfg.d_model, cfg.d_ff
    p = {
        "w_in": dense_init(ks[0], (D, F), dtype),
        "w_out": dense_init(ks[1], (F, D), dtype, scale=1.0 / (F ** 0.5 * cfg.n_layers ** 0.5)),
    }
    if cfg.gated_mlp():
        p["w_gate"] = dense_init(ks[2], (D, F), dtype)
    return p


def _init_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p: Params = {
        "attn_norm": jnp.zeros((D,), dtype),
        "mlp_norm": jnp.zeros((D,), dtype),
        "attn": _init_attn(cfg, ks[0], dtype),
    }
    if cfg.is_moe:
        p["moe"] = init_moe_params(cfg, ks[1], dtype)
    else:
        p["mlp"] = _init_mlp(cfg, ks[1], dtype)
    if cfg.family == "hybrid":
        p["ssm"] = ssd_mod.init_ssm_params(cfg, ks[2], dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_unembed, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    per_layer = [_init_layer(cfg, k, dtype) for k in layer_keys]
    layers = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *per_layer)
    params: Params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(k_unembed, (cfg.vocab_size, cfg.d_model), dtype)
    return params


def unembed_matrix(cfg: ModelConfig, params: Params) -> jax.Array:
    return params["embed"] if cfg.tie_embeddings else params["unembed"]


# ----------------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------------

def _attn_branch(cfg: ModelConfig, lp: Params, h: jax.Array, positions: jax.Array,
                 window: int | None = None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (attn_out (B,S,D), k (B,S,K,hd), v (B,S,K,hd))."""
    B, S, D = h.shape
    q = (h @ lp["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = attention(q, k, v, cfg, causal=True, window=window)
    return o.reshape(B, S, cfg.q_dim) @ lp["wo"], k, v


def _mlp_branch(cfg: ModelConfig, lp: Params, h: jax.Array) -> jax.Array:
    act = activation_fn(cfg.activation)
    if cfg.gated_mlp():
        mid = act(h @ lp["w_gate"]) * (h @ lp["w_in"])
    else:
        mid = act(h @ lp["w_in"])
    # (B, S, F) intermediate: F stays tensor-parallel (w_in col-parallel,
    # w_out row-parallel — the Megatron pattern, one all-reduce per layer)
    mid = shard_heads(mid, cfg.act_shard)
    return mid @ lp["w_out"]


def _layer_fwd(cfg: ModelConfig, lp: Params, x: jax.Array, positions: jax.Array,
               collect_kv: bool):
    """One transformer block. Returns (x, (aux_losses, kv))."""
    if cfg.fsdp_gather == "layer":
        lp = gather_fsdp(lp, cfg.act_shard)
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    window = cfg.hybrid_attn_window if cfg.family == "hybrid" else None
    attn_out, k, v = _attn_branch(cfg, lp["attn"], h, positions, window=window)
    if cfg.family == "hybrid":
        ssm_out, ssm_cache = ssd_mod.mamba_block(cfg, lp["ssm"], h)
        x = x + 0.5 * (attn_out + ssm_out)
    else:
        ssm_cache = None
        x = x + attn_out
    h2 = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        B, S, D = h2.shape
        y, m = moe_ffn(cfg, lp["moe"], h2.reshape(B * S, D))
        y = y.reshape(B, S, D)
        aux = (m.aux_loss, m.router_z_loss, m.dropped_fraction)
    else:
        y = _mlp_branch(cfg, lp["mlp"], h2)
        aux = (jnp.zeros((), jnp.float32),) * 3
    x = x + y
    kv = (k, v, ssm_cache) if collect_kv else None
    return x, (aux, kv)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   *, embeds: jax.Array | None = None, collect_kv: bool = False):
    """tokens: (B,S) int32 (or ``embeds`` (B,S,D) for stub frontends).

    Returns (hidden (B,S,D), aux dict, stacked kv or None).
    """
    dtype = jnp.dtype(cfg.dtype)
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    else:
        x = embeds.astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    x = shard_activations(x, cfg.act_shard)

    body = functools.partial(_layer_fwd, cfg, positions=positions, collect_kv=collect_kv)

    layers = params["layers"]
    if cfg.fsdp_gather == "step":
        # ZeRO-2: gather the whole stacked weight set once per step; remat
        # recomputes then reuse the live gathered copy instead of re-gathering
        # per layer per pass (trades ~params/TP bytes of HBM for ~pass-count x
        # fewer all-gathers — the §Perf collective-term lever)
        layers = gather_fsdp(layers, cfg.act_shard)

    def scan_body(carry, lp):
        out, y = body(lp, carry)
        return shard_activations(out, cfg.act_shard), y

    scan_fn = _remat(cfg, scan_body)
    L, G = cfg.n_layers, cfg.scan_block
    if G and 0 < G < L and L % G == 0 and not collect_kv:
        # Two-level layer scan: the outer scan saves one carry per block of G
        # layers; the (rematted) inner layers are recomputed per block in the
        # backward pass. Peak residual memory ~ (L/G + G) carries vs L.
        blocked = jax.tree.map(
            lambda a: a.reshape((L // G, G) + a.shape[1:]), layers)

        def block_body(carry, blk):
            return jax.lax.scan(lambda c, lp: scan_fn(c, lp), carry, blk)

        outer = block_body if cfg.remat == "none" else jax.checkpoint(block_body)
        x, (aux, kv) = jax.lax.scan(outer, x, blocked)
        aux = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), aux)
    else:
        x, (aux, kv) = jax.lax.scan(lambda c, lp: scan_fn(c, lp), x, layers)
    aux_losses = {
        "moe_aux": jnp.mean(aux[0]),
        "router_z": jnp.mean(aux[1]),
        "dropped": jnp.mean(aux[2]),
    }
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_losses, kv


def train_loss(cfg: ModelConfig, params: Params, batch: dict) -> tuple[jax.Array, dict]:
    """batch: tokens (B,S), labels (B,S). Returns (scalar loss, metrics)."""
    hidden, aux, _ = forward_hidden(cfg, params, batch.get("tokens"),
                                    embeds=batch.get("embeds"))
    loss, metrics = cross_entropy_chunked(
        hidden, unembed_matrix(cfg, params), batch["labels"],
        chunk=cfg.xent_chunk, z_loss_weight=cfg.z_loss_weight,
        logits_softcap=cfg.logits_softcap,
    )
    if cfg.is_moe:
        loss = loss + cfg.moe_aux_loss_weight * aux["moe_aux"] \
                    + cfg.router_z_loss_weight * aux["router_z"]
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


# ----------------------------------------------------------------------------
# KV cache / decode
# ----------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, max_len: int) -> int:
    window = cfg.hybrid_attn_window if cfg.family == "hybrid" else cfg.sliding_window
    return min(window, max_len) if window and window > 0 else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    L = cfg.n_layers
    C = cache_len(cfg, max_len)
    dtype = jnp.dtype(cfg.dtype)
    cache: dict = {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((L, batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, C, cfg.n_kv_heads, cfg.head_dim), dtype),
    }
    if cfg.family == "hybrid":
        di, H, P, N, G = ssd_mod.ssm_dims(cfg)
        cache["conv"] = jnp.zeros((L, batch, cfg.conv_kernel - 1, di + 2 * G * N), dtype)
        cache["state"] = jnp.zeros((L, batch, H, P, N), jnp.float32)
    return cache


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, max_len: int,
            *, embeds: jax.Array | None = None) -> tuple[jax.Array, dict]:
    """Run the full prompt, build the decode cache. Returns (last-token logits, cache)."""
    B, S = tokens.shape[0], tokens.shape[1]
    hidden, _, kv = forward_hidden(cfg, params, tokens, embeds=embeds, collect_kv=True)
    k_all, v_all, ssm_caches = kv                         # (L,B,S,K,hd)
    C = cache_len(cfg, max_len)
    if S >= C:
        k_cache = k_all[:, :, S - C:, :, :]
        v_cache = v_all[:, :, S - C:, :, :]
        # ring layout: slot = pos % C. Roll so absolute position p sits at p % C.
        shift = S % C
        k_cache = jnp.roll(k_cache, shift, axis=2)
        v_cache = jnp.roll(v_cache, shift, axis=2)
    else:
        padk = jnp.zeros((cfg.n_layers, B, C - S, cfg.n_kv_heads, cfg.head_dim), k_all.dtype)
        k_cache = jnp.concatenate([k_all, padk], axis=2)
        v_cache = jnp.concatenate([v_all, padk], axis=2)
    cache: dict = {"pos": jnp.asarray(S, jnp.int32), "k": k_cache, "v": v_cache}
    if cfg.family == "hybrid":
        cache["conv"] = ssm_caches.conv
        cache["state"] = ssm_caches.state
    logits = hidden[:, -1:, :].astype(jnp.float32) @ unembed_matrix(cfg, params).T.astype(jnp.float32)
    return softcap(logits, cfg.logits_softcap), cache


def _decode_layer(cfg: ModelConfig, lp: Params, x: jax.Array, layer_cache: dict,
                  pos: jax.Array) -> tuple[jax.Array, dict]:
    B = x.shape[0]
    C = layer_cache["k"].shape[1]
    h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = (h @ lp["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    pos_b = jnp.full((B,), pos)[:, None]
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k = apply_rope(k, pos_b, cfg.rope_theta)
    slot = pos % C
    k_cache = jax.lax.dynamic_update_slice_in_dim(layer_cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(layer_cache["v"], v, slot, axis=1)
    valid = (jnp.arange(C)[None, :] <= pos) | jnp.full((1, C), pos >= C)
    valid = jnp.broadcast_to(valid, (B, C))
    o = decode_attention(q, k_cache, v_cache, valid,
                         logit_softcap=cfg.attn_logit_softcap,
                         head_shard=cfg.act_shard)
    attn_out = o.reshape(B, 1, cfg.q_dim) @ lp["attn"]["wo"]
    new_cache = {"k": k_cache, "v": v_cache}
    if cfg.family == "hybrid":
        ssm_in = ssd_mod.SSMCache(conv=layer_cache["conv"], state=layer_cache["state"])
        ssm_out, ssm_new = ssd_mod.mamba_decode_step(cfg, lp["ssm"], h, ssm_in)
        x = x + 0.5 * (attn_out + ssm_out)
        new_cache["conv"] = ssm_new.conv
        new_cache["state"] = ssm_new.state
    else:
        x = x + attn_out
    h2 = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe_ffn(cfg, lp["moe"], h2.reshape(B, cfg.d_model))
        y = y.reshape(B, 1, cfg.d_model)
    else:
        y = _mlp_branch(cfg, lp["mlp"], h2)
    return x + y, new_cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    """tokens: (B, 1). Returns (logits (B,1,V) fp32, updated cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    pos = cache["pos"]

    layer_cache_keys = [k for k in ("k", "v", "conv", "state") if k in cache]

    def scan_body(carry, xs):
        lp, lcache = xs
        x_new, new_lcache = _decode_layer(cfg, lp, carry, lcache, pos)
        return x_new, new_lcache

    xs = (params["layers"], {k: cache[k] for k in layer_cache_keys})
    x, new_layer_caches = jax.lax.scan(scan_body, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x.astype(jnp.float32) @ unembed_matrix(cfg, params).T.astype(jnp.float32)
    new_cache = dict(cache)
    new_cache.update(new_layer_caches)
    new_cache["pos"] = pos + 1
    return softcap(logits, cfg.logits_softcap), new_cache
