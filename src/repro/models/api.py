"""Family-dispatched model API.

Every launcher / test / benchmark goes through these five functions:

  init_params(cfg, key)                      -> params pytree
  train_loss(cfg, params, batch)             -> (loss, metrics)
  init_cache(cfg, batch, max_len)            -> decode cache pytree
  prefill(cfg, params, tokens, max_len, ...) -> (last logits, cache)
  decode_step(cfg, params, cache, tokens)    -> (logits, cache)
"""
from __future__ import annotations

from typing import Any

import jax

from repro.configs.base import ModelConfig
from repro.models import encdec, mamba, transformer

Params = dict[str, Any]

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm", "hybrid")


def _mod(cfg: ModelConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer
    if cfg.family == "ssm":
        return mamba
    if cfg.family == "encdec":
        return encdec
    raise ValueError(f"unknown family {cfg.family!r}")


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    return _mod(cfg).init_params(cfg, key)


def train_loss(cfg: ModelConfig, params: Params, batch: dict):
    return _mod(cfg).train_loss(cfg, params, batch)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return _mod(cfg).init_cache(cfg, batch, max_len)


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int, **kw):
    return _mod(cfg).prefill(cfg, params, tokens, max_len, **kw)


def decode_step(cfg: ModelConfig, params: Params, cache: dict, tokens):
    return _mod(cfg).decode_step(cfg, params, cache, tokens)


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
