"""Mixture-of-Experts FFN: batched grouped dispatch/combine (scatter/gather).

Tokens are split into groups of ``cfg.moe_group_size``; capacity per expert
within a group is ``C = g * top_k * capacity_factor / E``; overflow tokens
are dropped (their combine weight is zero — residual carries them, standard
GShard/Switch semantics).

Two measured pathologies shaped this implementation (§Perf log):

* **No scan over groups.** A ``lax.scan`` over token groups is replicated
  control flow under SPMD — every device executes every global group, so the
  MoE block silently loses data parallelism (measured ~16x redundant expert
  FLOPs on mixtral/grok). Groups are a *batched* leading dim instead,
  sharded over the data axes (``shard_activations``), and all per-group ops
  are ``vmap``-broadcast — GSPMD keeps each group's dispatch local to its
  data shard.
* **No one-hot dispatch einsums.** ``einsum("gec,gd->ecd", onehot, x)``
  costs O(g·E·C·D) MXU flops ≈ 10-80x the expert matmuls. Dispatch is a
  per-group scatter-set (slot indices are unique by construction; dropped
  choices scatter out of bounds), combine is a gather + gate-weighted sum —
  O(g·k·D) data movement, zero matmul flops, exact same capacity semantics.

Expert weights stay (E, D, F) with D fsdp- and F tensor-sharded; inside the
layer they are FSDP-gathered once (dist.sharding.gather_fsdp) so every group
computes its expert slice against the full D.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import shard_activations, shard_heads
from repro.models.common import activation_fn


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array       # load-balance loss (scalar)
    router_z_loss: jax.Array  # scalar
    dropped_fraction: jax.Array


def _capacity(cfg: ModelConfig, group: int) -> int:
    c = int(group * cfg.experts_per_token * cfg.moe_capacity_factor / cfg.n_experts)
    return max(c, cfg.experts_per_token)


def _route_group(cfg: ModelConfig, router_logits: jax.Array, capacity: int):
    """router_logits: (g, E) fp32.

    Returns (expert_idx (g,k), slot (g,k), keep (g,k), gates (g,k),
    aux, z, dropped) — everything the scatter/gather dispatch needs.
    """
    g, E = router_logits.shape
    k = cfg.experts_per_token
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (g, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)     # (g, k, E)
    # Position-in-expert: choice-major priority (first choices fill first).
    flat = onehot.transpose(1, 0, 2).reshape(k * g, E)            # choice-major rows
    pos = jnp.cumsum(flat, axis=0) - flat                         # (k*g, E)
    pos = pos.reshape(k, g, E).transpose(1, 0, 2)                 # (g, k, E)
    slot = jnp.take_along_axis(
        pos, expert_idx[..., None], axis=2)[..., 0].astype(jnp.int32)
    keep = slot < capacity

    # Load-balance aux loss (Switch): E * sum_e f_e * p_e
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)               # fraction routed to e
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e) / k
    z = jnp.mean(jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))
    dropped = 1.0 - jnp.sum(keep) / (g * k)
    return expert_idx, slot, keep, gate_vals, aux, z, dropped


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, MoEMetrics]:
    """x: (T, D) -> (T, D). p: router (D,E), we_in/we_gate (E,D,F), we_out (E,F,D)."""
    T, D = x.shape
    E = cfg.n_experts
    g = min(cfg.moe_group_size, T)
    n_groups = (T + g - 1) // g
    pad = n_groups * g - T
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, D), x.dtype)], axis=0)
    # batched (NOT scanned) groups; the group dim shards over the data axes
    xg = shard_activations(x.reshape(n_groups, g, D), cfg.act_shard)
    capacity = _capacity(cfg, g)
    act = activation_fn(cfg.activation)
    gated = cfg.gated_mlp()
    k = cfg.experts_per_token

    def dispatch_one(xb, logits):
        """(g, D), (g, E) -> (xe (E,C,D), gather_idx (g,k), w (g,k), stats)."""
        expert_idx, slot, keep, gates, aux, z, dropped = \
            _route_group(cfg, logits, capacity)
        flat_idx = expert_idx * capacity + slot                   # (g, k)
        scatter_idx = jnp.where(keep, flat_idx, E * capacity + 1) # OOB = drop
        src = jnp.broadcast_to(xb[:, None, :], (g, k, D)).reshape(g * k, D)
        xe = jnp.zeros((E * capacity + 1, D), xb.dtype) \
            .at[scatter_idx.reshape(-1)].set(src, mode="drop",
                                             unique_indices=True) \
            [:E * capacity].reshape(E, capacity, D)
        gather_idx = jnp.where(keep, flat_idx, E * capacity)      # zero sink
        w = (gates * keep.astype(gates.dtype)).astype(xb.dtype)
        return xe, gather_idx, w, (aux, z, dropped)

    def combine_one(ye, gather_idx, w):
        ye_flat = jnp.concatenate(
            [ye.reshape(E * capacity, D), jnp.zeros((1, D), ye.dtype)], axis=0)
        y_tk = ye_flat[gather_idx.reshape(-1)].reshape(g, k, D)
        return jnp.einsum("gk,gkd->gd", w, y_tk)

    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    xe, gather_idx, w, (aux, z, dropped) = jax.vmap(dispatch_one)(xg, logits)
    # Shardy drops the group sharding at the (data-dependent) scatter and
    # all-gathers xe to a full (n, E·C, D) buffer — re-pin the group dim
    # here and around every expert tensor (measured: 22x redundant expert
    # FLOPs and 64 GB fp32 gathers without these constraints)
    xe = shard_activations(xe, cfg.act_shard)

    # ---- expert FFNs (the only matmuls), batched over groups ----
    h = jnp.einsum("necd,edf->necf", xe, p["we_in"])
    if gated:
        h = act(jnp.einsum("necd,edf->necf", xe, p["we_gate"])) * h
    else:
        h = act(h)
    h = shard_heads(h, cfg.act_shard, head_axis=3)                # F tensor-parallel
    ye = shard_activations(
        jnp.einsum("necf,efd->necd", h, p["we_out"]), cfg.act_shard)

    y = jax.vmap(combine_one)(ye, gather_idx, w)                  # (n, g, D)
    y = y.reshape(n_groups * g, D)[:T]
    return y, MoEMetrics(jnp.mean(aux), jnp.mean(z), jnp.mean(dropped))


def init_moe_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    from repro.models.common import dense_init
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "we_in": dense_init(ks[1], (E, D, F), dtype),
        "we_out": dense_init(ks[2], (E, F, D), dtype),
    }
    if cfg.gated_mlp():
        p["we_gate"] = dense_init(ks[3], (E, D, F), dtype)
    return p
