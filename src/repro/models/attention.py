"""Attention implementations.

``blocked_attention`` is the production XLA path: a flash-attention-style
online-softmax computed block-by-block (never materializes the full
(Sq, Sk) score matrix). The Pallas TPU kernel in
``repro.kernels.flash_attention`` implements the same contract with the
score blocks held in VMEM and is validated against ``mha_reference``;
the blocked-jnp path is what the dry-run lowers (Pallas lowering is
TPU-only; see DESIGN.md §6).

Distribution: GQA is computed H-major — K/V are repeated to the full query
head count and every (B, S, H, hd) tensor is constrained to head (='model')
parallelism via ``shard_heads``. The repeat costs rep× KV HBM traffic but
keeps every einsum batch-parallel over heads under GSPMD; without it the
(D -> H*hd) reshape loses the sharding and the partitioner emits an
all-reduce of the score blocks (measured: ~10x the collective bytes of the
whole rest of the step). The Pallas kernel does NOT pay the repeat — its
BlockSpec index map reuses one KV block per query-head group in VMEM.

Causal block skipping: query blocks are unrolled (static python loop) so
each gets a statically-bounded KV range — halves causal compute vs. the
naive full sweep (``cfg.causal_block_skip``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard_heads
from repro.models.common import softcap as _softcap

NEG_INF = -1e30


def _mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool, window: int) -> jax.Array:
    """(Q, K) boolean mask: True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def mha_reference(
    q: jax.Array,             # (B, Sq, H, hd)
    k: jax.Array,             # (B, Sk, K, hd)
    v: jax.Array,             # (B, Sk, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    q_offset: int = 0,
) -> jax.Array:
    """Naive O(S^2)-memory oracle. Only for tests/small shapes."""
    B, Sq, H, hd = q.shape
    Kh = k.shape[2]
    rep = H // Kh
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(hd))
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Sq, Kh, rep, hd)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qf, kf)
    scores = _softcap(scores, logit_softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, k_pos, causal=causal, window=window)
    scores = jnp.where(m[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", p, vf)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _kv_block_range(
    q_start: int, q_len: int, k_len: int, block_k: int,
    *, causal: bool, window: int, q_offset: int, skip: bool,
) -> tuple[int, int]:
    """Static [lo, hi) KV-block range a query block can attend to."""
    n_blocks = (k_len + block_k - 1) // block_k
    if not skip:
        return 0, n_blocks
    q_first = q_offset + q_start
    q_last = q_offset + q_start + q_len - 1
    hi = n_blocks if not causal else min(n_blocks, (q_last // block_k) + 1)
    lo = 0
    if window > 0:
        lo = max(0, (q_first - window + 1) // block_k)
    return lo, max(hi, lo + 1)


def blocked_attention(
    q: jax.Array,             # (B, Sq, H, hd)
    k: jax.Array,             # (B, Sk, K, hd)
    v: jax.Array,             # (B, Sk, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 1024,
    block_skip: bool = True,
    head_shard: str = "none",
) -> jax.Array:
    """Flash-attention (online softmax) in XLA ops; O(Sq·block_k) memory."""
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    rep = H // Kh
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # Pad to block multiples; padded keys are masked via ``k_pos < Sk``.
    Sq_real, Sk_real = Sq, Sk
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        Sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        Sk += pad_k

    # H-major GQA: repeat KV to the query head count (see module docstring).
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    q = shard_heads(q, head_shard)
    k = shard_heads(k, head_shard)
    v = shard_heads(v, head_shard)

    scale = 1.0 / math.sqrt(hd)
    qf = q.astype(jnp.float32) * scale                       # (B, Sq, H, hd)
    k_pos_all = jnp.arange(Sk)

    out_blocks = []
    for qi in range(Sq // block_q):
        q_start = qi * block_q
        qb = jax.lax.dynamic_slice_in_dim(qf, q_start, block_q, axis=1)
        q_pos = q_offset + q_start + jnp.arange(block_q)
        lo, hi = _kv_block_range(
            q_start, block_q, Sk, block_k,
            causal=causal, window=window, q_offset=q_offset, skip=block_skip,
        )

        def kv_step(carry, j, qb=qb, q_pos=q_pos):
            acc, m_prev, l_prev = carry
            k_start = j * block_k
            kb = jax.lax.dynamic_slice_in_dim(k, k_start, block_k, axis=1).astype(jnp.float32)
            vb = jax.lax.dynamic_slice_in_dim(v, k_start, block_k, axis=1).astype(jnp.float32)
            k_pos = jax.lax.dynamic_slice_in_dim(k_pos_all, k_start, block_k, axis=0)
            s = jnp.einsum("bqhd,bshd->bhqs", qb, kb)        # (B, H, bq, bk)
            s = _softcap(s, logit_softcap)
            mask = (k_pos[None, :] <= q_pos[:, None]) if causal else jnp.ones((block_q, block_k), bool)
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            if pad_k:
                mask &= (k_pos < Sk_real)[None, :]
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)                      # (B, H, bq)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqs,bshd->bhqd", p, vb)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, H, block_q, hd), jnp.float32)
        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        (acc, m_fin, l_fin), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(lo, hi)
        )
        ob = acc / jnp.maximum(l_fin[..., None], 1e-37)      # (B, H, bq, hd)
        out_blocks.append(jnp.transpose(ob, (0, 2, 1, 3)))   # (B, bq, H, hd)

    out = jnp.concatenate(out_blocks, axis=1)
    if pad_q:
        out = out[:, :Sq_real]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,             # (B, 1, H, hd) — one new token
    k_cache: jax.Array,       # (B, C, K, hd)
    v_cache: jax.Array,       # (B, C, K, hd)
    valid_mask: jax.Array,    # (B, C) bool — which cache slots hold real keys
    *,
    logit_softcap: float = 0.0,
    head_shard: str = "none",
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffer) KV cache.

    Unlike the training path this does NOT repeat KV to the query head count:
    the cache is the dominant decode buffer (GiB-scale at 32k context) and a
    rep-fold repeat would multiply it. Instead the grouped einsum keeps the
    cache's (K, hd) layout and the cache is sharded over its *sequence* dim
    ('model' axis, see dist.sharding.cache_specs) — scores come out C-sharded
    and the softmax/value reductions contract over C, so the only collectives
    are a tiny (B,K,rep) logsumexp combine and the (B,H,hd) output partial —
    ring-attention decoding, chosen by GSPMD from the shardings.
    """
    B, _, H, hd = q.shape
    Kh = k_cache.shape[2]
    rep = H // Kh
    qf = (q.astype(jnp.float32) * (1.0 / math.sqrt(hd))).reshape(B, Kh, rep, hd)
    s = jnp.einsum("bkrd,bckd->bkrc", qf, k_cache.astype(jnp.float32))
    s = _softcap(s, logit_softcap)
    s = jnp.where(valid_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrc,bckd->bkrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attention(q, k, v, cfg, *, causal=True, window=None, q_offset=0):
    """Config-dispatched attention entry point used by the models."""
    window = cfg.sliding_window if window is None else window
    kwargs = dict(
        causal=causal,
        window=window,
        logit_softcap=cfg.attn_logit_softcap,
        q_offset=q_offset,
    )
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, block_q=cfg.attn_block_q,
                                    block_k=cfg.attn_block_k, **kwargs)
    return blocked_attention(
        q, k, v, block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        block_skip=cfg.causal_block_skip, head_shard=cfg.act_shard, **kwargs,
    )
