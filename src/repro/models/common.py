"""Shared model building blocks: norms, RoPE, activations, init helpers."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

PyTree = Any


def dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def param_dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with fp32 statistics (weight is a (d,) gain, gemma-style 1+w)."""
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + weight.astype(jnp.float32))).astype(orig_dtype)


def activation_fn(name: str):
    if name in ("silu", "swiglu"):
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name!r}")


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                        # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal position embeddings, (S, D) fp32."""
    half = d_model // 2
    log_timescale = math.log(10000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


# ----------------------------------------------------------------------------
# Init helpers
# ----------------------------------------------------------------------------

def dense_init(key: jax.Array, shape: tuple[int, ...], dtype, scale: float | None = None) -> jax.Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key: jax.Array, n: int) -> list[jax.Array]:
    return list(jax.random.split(key, n))


def stack_layer_params(per_layer: list[PyTree]) -> PyTree:
    """[{...}, {...}] -> {...: stacked (L, ...)} for scan-over-layers."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_layer)


def cross_entropy_chunked(
    hidden: jax.Array,        # (B, S, D)
    unembed: jax.Array,       # (V, D)
    labels: jax.Array,        # (B, S) int32
    *,
    chunk: int,
    z_loss_weight: float = 0.0,
    logits_softcap: float = 0.0,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Mean NLL over all tokens without materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk computes logits in fp32, its
    logsumexp, and the target logit. Bounds peak logits memory to
    (B, chunk, V) — required for vocab=256k archs.
    """
    B, S, D = hidden.shape
    n_chunks = max(S // chunk, 1)
    chunk = S // n_chunks
    assert S % chunk == 0, f"seq {S} not divisible by xent chunk {chunk}"

    hidden_c = hidden.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)   # (n, B, c, D)
    labels_c = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)      # (n, B, c)

    def body(carry, xs):
        nll_sum, z_sum, correct = carry
        h, y = xs
        # bf16 operands, fp32 accumulation: an explicit .astype(f32) here gets
        # hoisted out of the scan by XLA and materializes the whole (n,B,c,D)
        # hidden stack in fp32 (measured +3 GiB/device at llama-3B scale)
        logits = jnp.einsum("bcd,vd->bcv", h, unembed,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, logits_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)                       # (B, c)
        tgt = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum(lse - tgt)
        z_sum = z_sum + jnp.sum(jnp.square(lse))
        correct = correct + jnp.sum(jnp.argmax(logits, axis=-1) == y)
        return (nll_sum, z_sum, correct), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32))
    # remat: never keep a chunk's (B, c, V) fp32 logits for the backward —
    # recomputing them costs one extra matmul per chunk and saves ~4 GB per
    # chunk at llama-3B scale (the single biggest temp buffer in train_step)
    (nll_sum, z_sum, correct), _ = jax.lax.scan(
        jax.checkpoint(body), init, (hidden_c, labels_c))
    n_tok = B * S
    loss = nll_sum / n_tok
    z_loss = z_loss_weight * z_sum / n_tok
    metrics = {
        "nll": loss,
        "z_loss": z_loss,
        "accuracy": correct.astype(jnp.float32) / n_tok,
    }
    return loss + z_loss, metrics
