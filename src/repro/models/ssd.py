"""Mamba-2 SSD (state-space duality) layer — chunked parallel form.

The sequence is split into chunks of length Q. Within a chunk the SSD is
computed in its "attention-like" quadratic form; across chunks a linear
recurrence carries the (H, P, N) state. This is the exact algorithm of
arXiv:2405.21060 §6 and is what the Pallas kernel
(``repro.kernels.ssd_scan``) implements per (batch, head) block; this module
is the XLA-native version used by the models and the dry-run.

Shapes: x (B,S,H,P) inputs, dt (B,S,H) timesteps (post-softplus), A (H,)
negative decay rates, B/C (B,S,G,N) input/output projections (G groups
broadcast over heads).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import shard_heads
from repro.models.common import dense_init, rms_norm


def segsum(la: jax.Array) -> jax.Array:
    """la: (..., Q) log-decays -> (..., Q, Q) lower-triangular cumulative sums.

    out[..., i, j] = sum_{m=j+1..i} la[..., m]   (for j <= i; -inf above diag)
    """
    Q = la.shape[-1]
    cum = jnp.cumsum(la, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked_reference(
    x: jax.Array,   # (B,S,H,P)
    dt: jax.Array,  # (B,S,H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B,S,G,N)
    Cm: jax.Array,  # (B,S,G,N)
    *,
    chunk: int,
    initial_state: jax.Array | None = None,  # (B,H,P,N)
    head_shard: str = "none",
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)). fp32 math."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    n_chunks = S // Q

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    # chunked views: (B, n, Q, ...)
    xc = xf.reshape(B_, n_chunks, Q, H, P)
    dtc = dtf.reshape(B_, n_chunks, Q, H)
    Bc = Bf.reshape(B_, n_chunks, Q, G, N)
    Cc = Cf.reshape(B_, n_chunks, Q, G, N)
    lac = dtc * Af[None, None, None, :]          # (B,n,Q,H) log decays
    head_group = jnp.arange(H) // rep            # map head -> group

    Bh = Bc[:, :, :, head_group, :]              # (B,n,Q,H,N)
    Ch = Cc[:, :, :, head_group, :]
    # every intra-chunk einsum batches over (B, n, H): pin H to the model
    # axis (GSPMD pads 50 -> 64 on hymba) — without this Shardy partial-sums
    # the (B,n,H,Q,Q) score tensor and all-reduces ~200 GB/step (§Perf it.5)
    xc = shard_heads(xc, head_shard, head_axis=3)
    dtc = shard_heads(dtc, head_shard, head_axis=3)
    lac = shard_heads(lac, head_shard, head_axis=3)
    Bh = shard_heads(Bh, head_shard, head_axis=3)
    Ch = shard_heads(Ch, head_shard, head_axis=3)

    # --- intra-chunk (quadratic within chunk) ---
    ss = segsum(lac.transpose(0, 1, 3, 2))       # (B,n,H,Q,Q)
    L = jnp.exp(ss)
    scores = jnp.einsum("bnihd,bnjhd->bnhij", Ch, Bh)            # (B,n,H,Q,Q)
    y_intra = jnp.einsum("bnhij,bnhij,bnjh,bnjhp->bnihp",
                         scores, L, dtc, xc)

    # --- chunk summary states ---
    cum = jnp.cumsum(lac, axis=2)                                # (B,n,Q,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)              # (B,n,Q,H)
    states = jnp.einsum("bnjh,bnjh,bnjhs,bnjhp->bnhps",
                        decay_to_end, dtc, Bh, xc)               # (B,n,H,P,N)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,n,H)
    if initial_state is None:
        h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    else:
        h0 = initial_state.astype(jnp.float32)

    def scan_body(h_prev, inp):
        s_c, dec = inp                                           # (B,H,P,N), (B,H)
        h_new = h_prev * dec[:, :, None, None] + s_c
        return h_new, h_prev

    states_t = states.transpose(1, 0, 2, 3, 4)                   # (n,B,H,P,N)
    decay_t = chunk_decay.transpose(1, 0, 2)                     # (n,B,H)
    h_final, h_prevs = jax.lax.scan(scan_body, h0, (states_t, decay_t))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                   # (B,n,H,P,N)

    # inter-chunk contribution: C_i · h_prev, decayed to position i
    in_decay = jnp.exp(cum)                                      # (B,n,Q,H)
    y_inter = jnp.einsum("bnihs,bnhps,bnih->bnihp", Ch, h_prevs, in_decay)

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    return y.astype(x.dtype), h_final


def ssd_decode_step(
    x: jax.Array,    # (B,H,P)
    dt: jax.Array,   # (B,H)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B,G,N)
    Cm: jax.Array,   # (B,G,N)
    state: jax.Array,  # (B,H,P,N) fp32
) -> tuple[jax.Array, jax.Array]:
    """One recurrent step: h = h*exp(dt*A) + dt*B⊗x ; y = C·h."""
    H = x.shape[1]
    G = Bm.shape[1]
    rep = H // G
    head_group = jnp.arange(H) // rep
    Bh = Bm[:, head_group, :].astype(jnp.float32)   # (B,H,N)
    Ch = Cm[:, head_group, :].astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dec = jnp.exp(dtf * A.astype(jnp.float32))      # (B,H)
    xf = x.astype(jnp.float32)
    new_state = state * dec[..., None, None] + \
        dtf[..., None, None] * xf[..., :, None] * Bh[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# ----------------------------------------------------------------------------
# Full Mamba-2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ----------------------------------------------------------------------------

class SSMCache(NamedTuple):
    conv: jax.Array    # (B, K-1, conv_ch) rolling conv inputs
    state: jax.Array   # (B, H, P, N) fp32 SSD state


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int, int, int]:
    di = cfg.d_inner
    H = cfg.ssm_nheads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_ngroups
    return di, H, P, N, G


def init_ssm_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    di, H, P, N, G = ssm_dims(cfg)
    D = cfg.d_model
    conv_ch = di + 2 * G * N
    ks = jax.random.split(key, 5)
    proj_out = 2 * di + 2 * G * N + H   # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (D, proj_out), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_kernel, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "ssd_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], (di, D), dtype),
    }


def _split_zxbcdt(cfg: ModelConfig, zxbcdt: jax.Array):
    di, H, P, N, G = ssm_dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 history: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d. xbc: (B,S,C), w: (K,C). history: (B,K-1,C)."""
    K = w.shape[0]
    if history is None:
        history = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    xp = jnp.concatenate([history, xbc], axis=1)              # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def mamba_block(cfg: ModelConfig, p: dict, x: jax.Array,
                initial: SSMCache | None = None) -> tuple[jax.Array, SSMCache]:
    """x: (B,S,D) -> (B,S,D). Returns output + final cache (for decode handoff)."""
    B_, S, D = x.shape
    di, H, P, N, G = ssm_dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    hist = initial.conv if initial is not None else None
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"], hist)
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(B_, S, H, P)
    Bm = Bm.reshape(B_, S, G, N)
    Cm = Cm.reshape(B_, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])

    init_state = initial.state if initial is not None else None
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        y, h_final = kops.ssd_scan(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                                   initial_state=init_state)
    else:
        y, h_final = ssd_chunked_reference(xs, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                                           initial_state=init_state,
                                           head_shard=cfg.act_shard)
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["ssd_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    K = cfg.conv_kernel
    if S >= K - 1:
        conv_tail = xbc_raw[:, S - (K - 1):, :]
    else:
        prev = hist if hist is not None else jnp.zeros((B_, K - 1, xbc_raw.shape[-1]), x.dtype)
        conv_tail = jnp.concatenate([prev, xbc_raw], axis=1)[:, -(K - 1):, :]
    return out, SSMCache(conv=conv_tail, state=h_final)


def mamba_decode_step(cfg: ModelConfig, p: dict, x: jax.Array,
                      cache: SSMCache) -> tuple[jax.Array, SSMCache]:
    """x: (B,1,D) one token. Returns (out (B,1,D), new cache)."""
    B_, _, D = x.shape
    di, H, P, N, G = ssm_dims(cfg)
    zxbcdt = x[:, 0, :] @ p["in_proj"]                       # (B, proj)
    z, xbc_new, dt_raw = _split_zxbcdt(cfg, zxbcdt)
    K = cfg.conv_kernel
    window = jnp.concatenate([cache.conv, xbc_new[:, None, :]], axis=1)  # (B,K,C)
    xbc = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(xbc)
    xs, Bm, Cm = jnp.split(xbc, [di, di + G * N], axis=-1)
    xs = xs.reshape(B_, H, P)
    Bm = Bm.reshape(B_, G, N)
    Cm = Cm.reshape(B_, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])      # (B,H)
    A = -jnp.exp(p["A_log"])
    y, new_state = ssd_decode_step(xs, dt, A, Bm, Cm, cache.state)
    y = y + xs * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B_, di)
    y = rms_norm(y * jax.nn.silu(z), p["ssd_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    new_cache = SSMCache(conv=window[:, 1:, :], state=new_state)
    return out, new_cache
