from repro.models.api import (
    count_params,
    decode_step,
    init_cache,
    init_params,
    prefill,
    train_loss,
)

__all__ = [
    "count_params",
    "decode_step",
    "init_cache",
    "init_params",
    "prefill",
    "train_loss",
]
