"""Mamba-2 language model (attention-free, family='ssm')."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import gather_fsdp, shard_activations
from repro.models import ssd as ssd_mod
from repro.models.common import cross_entropy_chunked, embed_init, rms_norm

Params = dict[str, Any]


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)

    def layer(k):
        return {
            "norm": jnp.zeros((cfg.d_model,), dtype),
            "ssm": ssd_mod.init_ssm_params(cfg, k, dtype),
        }

    layers = jax.tree.map(lambda *x: jnp.stack(x, 0), *[layer(k) for k in layer_keys])
    return {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": layers,
    }


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array,
                   collect_state: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = shard_activations(x, cfg.act_shard)

    def body(carry, lp):
        lp = gather_fsdp(lp, cfg.act_shard)
        h = rms_norm(carry, lp["norm"], cfg.norm_eps)
        out, cache = ssd_mod.mamba_block(cfg, lp["ssm"], h)
        return shard_activations(carry + out, cfg.act_shard), \
            cache if collect_state else None

    fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, caches = jax.lax.scan(fn, x, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), caches


def train_loss(cfg: ModelConfig, params: Params, batch: dict) -> tuple[jax.Array, dict]:
    hidden, _ = forward_hidden(cfg, params, batch["tokens"])
    loss, metrics = cross_entropy_chunked(
        hidden, params["embed"], batch["labels"], chunk=cfg.xent_chunk,
        z_loss_weight=cfg.z_loss_weight,
    )
    metrics["loss"] = loss
    return loss, metrics


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    del max_len  # SSM state is O(1) in sequence length
    L = cfg.n_layers
    di, H, P, N, G = ssd_mod.ssm_dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "conv": jnp.zeros((L, batch, cfg.conv_kernel - 1, di + 2 * G * N), dtype),
        "state": jnp.zeros((L, batch, H, P, N), jnp.float32),
    }


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, max_len: int,
            **_) -> tuple[jax.Array, dict]:
    hidden, caches = forward_hidden(cfg, params, tokens, collect_state=True)
    cache = {
        "pos": jnp.asarray(tokens.shape[1], jnp.int32),
        "conv": caches.conv,
        "state": caches.state,
    }
    logits = hidden[:, -1:, :].astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, cache


def decode_step(cfg: ModelConfig, params: Params, cache: dict,
                tokens: jax.Array) -> tuple[jax.Array, dict]:
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)

    def body(carry, xs):
        lp, conv, state = xs
        h = rms_norm(carry, lp["norm"], cfg.norm_eps)
        out, new_cache = ssd_mod.mamba_decode_step(
            cfg, lp["ssm"], h, ssd_mod.SSMCache(conv=conv, state=state))
        return carry + out, (new_cache.conv, new_cache.state)

    x, (new_conv, new_state) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["state"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x.astype(jnp.float32) @ params["embed"].T.astype(jnp.float32)
    return logits, {"pos": cache["pos"] + 1, "conv": new_conv, "state": new_state}
