"""Counter-based deterministic data pipeline.

Every batch shard is a pure function of ``(seed, step, shard_index)`` — there
is no consumed-iterator state. This is the property that makes Legio's
policies exact in this framework:

  * DROP       — survivors keep their own shards; nothing to recover.
  * REBALANCE  — a survivor can regenerate *any* failed node's shard
                 bit-exactly, so redistributing work costs one fold_in.
  * restart-only-failed (§VII / MANA analogue) — a replacement node resumes
    mid-run and generates exactly the shards the dead node would have seen.

The synthetic "language" is an order-2 Markov stream with deterministic
structure (token[t] depends on token[t-1], token[t-2] and a per-sequence
offset) so a ~few-M-param model shows a cleanly decreasing loss in the
examples — while staying a pure counter-based generator.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ShardAssignment:
    """Which global shard indices a node computes this step."""
    node: int
    shards: tuple[int, ...]


def _fold(seed: int, *counters: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    for c in counters:
        key = jax.random.fold_in(key, c)
    return key


def make_batch(
    seed: int,
    step: int,
    shard: int,
    *,
    batch: int,
    seq_len: int,
    vocab_size: int,
) -> dict:
    """Generate one shard's (tokens, labels) deterministically.

    labels[t] = tokens[t+1] (next-token prediction); the stream mixes a
    learnable Markov structure with noise tokens.
    """
    key = _fold(seed, step, shard)
    k_start, k_noise, k_mask = jax.random.split(key, 3)
    V = vocab_size
    # structured stream: x[t+1] = (a * x[t] + b) % V with per-sequence (a, b);
    # a ∈ {1, 3} keeps the map inferable from two consecutive tokens, so a
    # small model's loss drops within tens of steps (examples/tests)
    a = 2 * jax.random.randint(k_start, (batch, 1), 0, 2) + 1      # 1 or 3
    b = jax.random.randint(k_start, (batch, 1), 0, V)
    x0 = jax.random.randint(k_start, (batch, 1), 0, V)
    t = jnp.arange(seq_len + 1)[None, :]
    # closed form of the affine recurrence keeps generation O(S) and pure
    tokens = (x0 * jnp.power(a, t) + b * (jnp.power(a, t) - 1) // jnp.maximum(a - 1, 1)) % V
    noise = jax.random.randint(k_noise, tokens.shape, 0, V)
    keep = jax.random.uniform(k_mask, tokens.shape) < 0.9
    stream = jnp.where(keep, tokens, noise).astype(jnp.int32)
    return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}


def global_batch_for_step(
    seed: int,
    step: int,
    *,
    global_batch: int,
    seq_len: int,
    vocab_size: int,
    n_shards: int,
) -> dict:
    """Assemble the full global batch from its shards (host-side, tests)."""
    per = global_batch // n_shards
    parts = [
        make_batch(seed, step, s, batch=per, seq_len=seq_len, vocab_size=vocab_size)
        for s in range(n_shards)
    ]
    return {
        k: jnp.concatenate([p[k] for p in parts], axis=0)
        for k in parts[0]
    }


def shard_batch(
    assignments: list[ShardAssignment],
    seed: int,
    step: int,
    *,
    per_shard_batch: int,
    seq_len: int,
    vocab_size: int,
) -> dict[int, dict]:
    """Materialize each node's batch per its (possibly rebalanced) shards."""
    out: dict[int, dict] = {}
    for asg in assignments:
        if not asg.shards:
            continue
        parts = [
            make_batch(seed, step, s, batch=per_shard_batch,
                       seq_len=seq_len, vocab_size=vocab_size)
            for s in asg.shards
        ]
        out[asg.node] = {
            k: jnp.concatenate([p[k] for p in parts], axis=0) for k in parts[0]
        }
    return out


def host_batch_numpy(seed: int, step: int, shard: int, *, batch: int,
                     seq_len: int, vocab_size: int) -> dict[str, np.ndarray]:
    b = make_batch(seed, step, shard, batch=batch, seq_len=seq_len, vocab_size=vocab_size)
    return {k: np.asarray(v) for k, v in b.items()}
