from repro.data.pipeline import (
    ShardAssignment,
    global_batch_for_step,
    make_batch,
    shard_batch,
)

__all__ = [
    "ShardAssignment",
    "global_batch_for_step",
    "make_batch",
    "shard_batch",
]
