"""Pallas TPU kernel for the Mamba-2 SSD chunked scan (arXiv:2405.21060 §6).

TPU-native formulation: the grid is ``(batch, heads, n_chunks)`` with the
chunk dimension innermost — TPU cores execute the grid sequentially, so the
inter-chunk recurrent state lives in a VMEM scratch buffer ``(P, N)`` that
persists across the chunk sweep (initialized from ``h0`` at chunk 0, written
to the ``final_state`` output at the last chunk). Within a chunk the SSD is
evaluated in its quadratic "attention-like" form, which maps onto the MXU as
three matmuls per chunk:

    scores  = C  @ B^T                       (Q, Q)
    y_intra = (scores ⊙ L ⊙ dt) @ x          (Q, P)
    y_inter = (C ⊙ exp(cum)) @ h^T           (Q, P)
    h_new   = exp(cum[-1]) · h  +  x^T @ (B ⊙ dt·decay_end)     (P, N)

with L the exponentiated segment-sum mask. All math fp32.

GQA-style B/C groups are handled in the BlockSpec index maps (head ``h``
reads group ``h // (H // G)``) — no replication in HBM.

VMEM per grid step (defaults Q=256, P=64, N=128):
  x (Q,P) + B,C (Q,N) + dt,la (Q,) + masks (Q,Q) f32 + state (P,N) f32
  ≈ 0.26 + 0.26 + 0.52 MB « 16 MB. Q is a multiple of 128 to align the
  (Q,Q) and (Q,P) matmuls with the 128x128 MXU systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    # refs (post-BlockSpec)
    x_ref,      # (1, 1, Q, P)
    la_ref,     # (1, 1, Q)  log-decays dt*A
    dt_ref,     # (1, 1, Q)
    b_ref,      # (1, 1, Q, N)
    c_ref,      # (1, 1, Q, N)
    h0_ref,     # (1, 1, P, N)
    y_ref,      # out (1, 1, Q, P)
    hout_ref,   # out (1, 1, P, N)
    # scratch
    state_ref,  # VMEM (P, N) f32
    *,
    n_chunks: int,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    la = la_ref[0, 0].astype(jnp.float32)        # (Q,)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    h = state_ref[...]                           # (P, N)

    cum = jnp.cumsum(la)                         # (Q,)
    # L[i, j] = exp(cum[i] - cum[j]) for j <= i else 0
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    qj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = cum[:, None] - cum[None, :]
    L = jnp.where(qj <= qi, jnp.exp(seg), 0.0)   # (Q, Q)

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (Q, Q)
    w = scores * L * dt[None, :]
    y_intra = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (Q, P)

    in_decay = jnp.exp(cum)                      # (Q,)
    y_inter = jax.lax.dot_general(
        Cm * in_decay[:, None], h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (Q, P)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state recurrence ----
    total = cum[chunk - 1]
    decay_end = jnp.exp(total - cum)             # (Q,)
    upd = jax.lax.dot_general(
        x, Bm * (decay_end * dt)[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (P, N)
    state_ref[...] = h * jnp.exp(total) + upd

    @pl.when(ci == n_chunks - 1)
    def _finalize():
        hout_ref[0, 0] = state_ref[...]


def ssd_scan_pallas(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)  post-softplus timesteps
    A: jax.Array,      # (H,)       negative decay rates
    Bm: jax.Array,     # (B, S, G, N)
    Cm: jax.Array,     # (B, S, G, N)
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,   # (B, H, P, N) f32
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P) in x.dtype, final_state (B,H,P,N) f32)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert H % G == 0, (H, G)
    rep = H // G
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    n_chunks = Sp // Q

    la = dt * A[None, None, :]                            # (B, Sp, H)
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    # kernel layout: time-major per (batch, head)
    xt = x.transpose(0, 2, 1, 3)                          # (B, H, Sp, P)
    lat = la.transpose(0, 2, 1)                           # (B, H, Sp)
    dtt = dt.transpose(0, 2, 1)
    Bt = Bm.transpose(0, 2, 1, 3)                         # (B, G, Sp, N)
    Ct = Cm.transpose(0, 2, 1, 3)

    grid = (B, H, n_chunks)
    kernel = functools.partial(_ssd_kernel, n_chunks=n_chunks, chunk=Q)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, Q, N),
                         lambda b, h, c, rep=rep: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, Q, N),
                         lambda b, h, c, rep=rep: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, lat, dtt, Bt, Ct, initial_state)

    y = y.transpose(0, 2, 1, 3)                           # (B, Sp, H, P)
    if pad:
        y = y[:, :S]
    return y, h_fin
