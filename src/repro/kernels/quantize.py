"""Pallas TPU kernel for int8 absmax quantization (the compression hop).

The cross-legion hop of a compressed all-reduce quantizes one master's
error-fed partial to int8 before it rides the slow links
(optim/compression.py). On device that is two passes over the flattened
tensor, both expressed as a Pallas grid over ``(block_rows, 128)`` tiles:

  1. ``absmax`` — a running max of |x| accumulated across grid steps into a
     (1, 1) output block. TPU cores execute the grid sequentially, so the
     same output block is a legal cross-step accumulator (the SSD scan's
     VMEM-state idiom applied to a reduction).
  2. ``quantize`` — elementwise ``clip(round(x / scale), -127, 127)`` into
     an int8 tile, with the (1, 1) scale block broadcast to every step.

The two passes are exposed separately (:func:`absmax_pallas`,
:func:`quantize_int8_with_scale`) because the data plane computes the scale
``max(absmax, 1e-12) / 127`` on the host: under jit XLA rewrites division
by the constant 127 into multiplication by its reciprocal (1 ulp off true
division), so an in-graph scale cannot be bitwise-reproduced by the numpy
sim backend. With the scale as runtime data, every remaining op
(max / divide / round-half-even / clip) is IEEE-exact and the jax and sim
data planes produce byte-identical compression — a pinned test invariant.
:func:`quantize_int8_pallas` composes both passes in one jit for callers
that do not need cross-backend bit parity.

Tiles: f32 inputs want (8, 128) multiples, int8 outputs (32, 128) — the
default ``block_rows=256`` satisfies both; inputs are zero-padded up to a
whole grid (zeros never raise an absmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.optim.compression import Int8Grad

_LANES = 128


def _absmax_kernel(x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = 0.0

    out_ref[0, 0] = jnp.maximum(out_ref[0, 0], jnp.max(jnp.abs(x_ref[...])))


def _quantize_kernel(x_ref, scale_ref, q_ref):
    s = scale_ref[0, 0]
    q_ref[...] = jnp.clip(jnp.round(x_ref[...] / s), -127, 127
                          ).astype(jnp.int8)


def _padded(g: jax.Array, block_rows: int) -> jax.Array:
    """Flatten to a zero-padded (rows, 128) f32 grid, rows a multiple of
    ``block_rows``."""
    gf = g.astype(jnp.float32)
    n = gf.size
    rows = -(-max(n, 1) // _LANES)
    rows_p = -(-rows // block_rows) * block_rows
    flat = jnp.zeros((rows_p * _LANES,), jnp.float32).at[:n].set(
        gf.reshape(-1))
    return flat.reshape(rows_p, _LANES)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def absmax_pallas(g: jax.Array, *, block_rows: int = 256,
                  interpret: bool = False) -> jax.Array:
    """``max(|g|)`` as a () f32 — pass 1 of the quantization."""
    x = _padded(g, block_rows)
    out = pl.pallas_call(
        _absmax_kernel,
        grid=(x.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return out[0, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8_with_scale(g: jax.Array, scale: jax.Array, *,
                             block_rows: int = 256,
                             interpret: bool = False) -> jax.Array:
    """``clip(round(g / scale), -127, 127)`` as int8, shaped like ``g`` —
    pass 2, with the scale as runtime data (see module docstring)."""
    x = _padded(g, block_rows)
    q = pl.pallas_call(
        _quantize_kernel,
        grid=(x.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], _LANES), jnp.int8),
        interpret=interpret,
    )(x, scale.astype(jnp.float32).reshape(1, 1))
    return q.reshape(-1)[:g.size].reshape(g.shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8_pallas(g: jax.Array, *, block_rows: int = 256,
                         interpret: bool = False) -> Int8Grad:
    """Absmax-quantize ``g`` to int8: returns ``Int8Grad(q, scale)`` with
    ``q`` shaped like ``g`` and ``scale = max(absmax, 1e-12) / 127``."""
    if g.size == 0:
        return Int8Grad(q=g.astype(jnp.int8), scale=jnp.float32(1e-12) / 127.0)
    am = absmax_pallas(g, block_rows=block_rows, interpret=interpret)
    scale = jnp.maximum(am, 1e-12) / 127.0
    q = quantize_int8_with_scale(g, scale, block_rows=block_rows,
                                 interpret=interpret)
    return Int8Grad(q=q, scale=scale)
