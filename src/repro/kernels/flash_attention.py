"""Pallas TPU flash-attention kernel (causal / GQA / sliding-window / softcap).

TPU-native formulation (not a CUDA port): the grid is
``(batch, q_heads, q_blocks, kv_blocks)`` with the KV dimension innermost —
TPU cores execute the grid sequentially, so the online-softmax accumulators
live in VMEM scratch and persist across the KV sweep (re-initialized at
``kv_index == 0``, written back at the last KV block). The MXU sees two
``(block_q, head_dim) x (head_dim, block_k)``-shaped matmuls per step;
block sizes default to (512, 1024) and must be multiples of 128 to align
with the MXU systolic array. Softmax stats are kept as (block_q, 128)
lane-replicated tiles — VMEM wants >=2D, (8,128)-aligned allocations.

GQA is handled in the BlockSpec index maps: the KV block for query head
``h`` is loaded from KV head ``h // (Hq // Hkv)`` — no KV replication in
HBM, the re-use happens in VMEM.

Fully-masked KV blocks (causal skip / outside the sliding window) are
skipped with ``pl.when`` around the matmul body, so the causal wall-clock
is ~half of the full sweep, matching the blocked-XLA path's static skip.

VMEM budget per grid step (bf16 in, fp32 acc):
  q (bq, hd) + k/v (bk, hd) + acc (bq, hd) f32 + m/l (bq, 128) f32
  = 512*128*2 + 2*1024*128*2 + 512*128*4 + 2*512*128*4  ≈ 1.4 MB « 16 MB VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _attn_kernel(
    # refs (post-BlockSpec): q (1,1,bq,hd); k,v (1,1,bk,hd); o (1,1,bq,hd)
    q_ref, k_ref, v_ref, o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *,
    causal: bool,
    window: int,
    softcap: float,
    scale: float,
    block_q: int,
    block_k: int,
    n_kv_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    q_start = qi * block_q + q_offset          # absolute first query position
    k_start = kj * block_k

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---- dead-block test: fully causal-masked or left of the window --------
    q_last = q_start + block_q - 1
    k_last = k_start + block_k - 1
    masked_out_causal = causal and (k_start > q_last)
    masked_out_window = window > 0 and (k_last <= q_start - window)
    live = jnp.logical_not(
        jnp.logical_or(masked_out_causal, masked_out_window))

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bq, bk)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                              # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)         # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                             # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)            # (bq, hd)
        acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-37)               # (bq, 1)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,              # (B, Sq, H, hd)
    k: jax.Array,              # (B, Sk, K, hd)
    v: jax.Array,              # (B, Sk, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    logit_softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, Sq, H, hd) attention output, dtype of q."""
    B, Sq, H, hd = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    assert H % Kh == 0, (H, Kh)
    rep = H // Kh
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    n_q_blocks = Sq // block_q
    n_kv_blocks = Sk // block_k

    # kernel-internal layout: (B, H, S, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, n_q_blocks, n_kv_blocks)
    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        softcap=logit_softcap,
        scale=1.0 / math.sqrt(hd),
        block_q=block_q,
        block_k=block_k,
        n_kv_blocks=n_kv_blocks,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
