"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are the single source of truth for kernel semantics:

  * ``flash_attention_ref``  — naive O(S²) softmax attention with GQA,
    causal/sliding-window masking, logit softcap and query offset;
  * ``ssd_scan_ref``         — the chunked SSD recurrence in plain jnp.

The model code uses the same implementations (``repro.models.attention`` /
``repro.models.ssd``), so a kernel that matches its oracle also matches the
XLA path the dry-run lowers.
"""
from __future__ import annotations

import jax

from repro.models.attention import mha_reference
from repro.models.ssd import ssd_chunked_reference


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0, logit_softcap: float = 0.0,
    q_offset: int = 0,
) -> jax.Array:
    return mha_reference(q, k, v, causal=causal, window=window,
                         logit_softcap=logit_softcap, q_offset=q_offset)


def ssd_scan_ref(
    x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array, Cm: jax.Array,
    *, chunk: int = 256, initial_state: jax.Array | None = None,
):
    return ssd_chunked_reference(x, dt, A, Bm, Cm, chunk=chunk,
                                 initial_state=initial_state)
