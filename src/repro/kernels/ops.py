"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs in Python op-by-op, which validates indexing, masking
and the online-softmax/recurrence algebra exactly as the TPU grid would
sequence them. On TPU backends the same call sites lower to Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import quantize_int8_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "q_offset",
                     "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0, q_offset: int = 0,
                    block_q: int = 512, block_k: int = 1024):
    """(B,Sq,H,hd) x (B,Sk,K,hd)² -> (B,Sq,H,hd); GQA via BlockSpec reuse."""
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        q_offset=q_offset, block_q=min(block_q, q.shape[1]),
        block_k=min(block_k, k.shape[1]), interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256, initial_state=None):
    """Chunked SSD scan; returns (y (B,S,H,P), final_state (B,H,P,N) f32)."""
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                           initial_state=initial_state,
                           interpret=_interpret())


@jax.jit
def quantize_int8(g):
    """Int8 absmax quantization (the compression hop); returns Int8Grad."""
    return quantize_int8_pallas(g, interpret=_interpret())
