"""Pallas TPU kernels for the framework's compute hot-spots.

Legio itself contributes no kernels (its contribution is the communicator/
repair layer), but the models it schedules do: flash attention dominates the
transformer cells and the SSD scan dominates mamba2/hymba. Each kernel ships
as <name>.py (pl.pallas_call + BlockSpec), with ``ops.py`` as the jit'd
public wrapper and ``ref.py`` as the pure-jnp oracle used by the tests.
"""
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import quantize_int8_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

__all__ = ["flash_attention_pallas", "quantize_int8_pallas",
           "ssd_scan_pallas"]
