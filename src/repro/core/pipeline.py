"""FaultPipeline — every fault signal flows through explicit stages.

The paper's recovery is transparent because every action hangs off one seam
(the PMPI interposition layer); Bouteiller & Bosilca (2212.08755) argue the
recovery behind that seam should be a pipeline of implicit actions rather
than a blocking in-line procedure. This module is that pipeline for the
step-boundary seam:

    detect  — gather signals from every channel: collective PROC_FAILED
              observations fed by the executor, HeartbeatDetector.sweep
              timeouts (previously dead code — now a first-class channel),
              straggler soft-fails, and injected ground truth (trainer sims);
    notice  — apply the paper's P.2/P.3 noticing semantics per event: which
              survivors actually hold a verdict (bcast notices partially —
              the BNP; heartbeat suspicion is coordinator-state every live
              node can read);
    agree   — unify the observers' suspicion sets into one verdict
              (agreement.agree_fault — the BNP fix);
    plan    — select the registered RecoveryStrategy, partition the verdict
              into crash vs straggle soft-fails, and fold it into disjoint
              :class:`RepairScope` subtrees (the minimal communicator sets
              that contain each fault — Rocco & Palermo's scoped reparation);
    apply   — soft-fail stragglers, run the strategy once per scope via
              ``VirtualCluster.repair_scoped`` (which owns
              confirm/charge/record; disjoint scopes are charged as
              concurrent — max cost, not sum).

Each drain emits one terminal :class:`RecoveryAction` per disjoint scope —
the scopes partition the agreed verdict, so every failed node still appears
in exactly one terminal action. Per-stage wall latencies are recorded on
every action and in ``traces`` (benchmarks/repair_time.py reads the
breakdown, and :class:`~repro.core.strategy.CostModelStrategy` fits its
per-stage EWMA estimates from the same records — the pipeline is the
adaptive scorer's only latency oracle).

Invariants (asserted by tests/test_pipeline.py and tests/test_serve.py):

  * **one terminal action per fault** — every agreed-failed node appears in
    the verdict of exactly one terminal RecoveryAction over the campaign
    (a drain never re-repairs a node a previous drain already repaired);
  * **frozen epochs under pin** — the apply stage mutates the topology only
    through ``VirtualCluster.repair``, which is never called while a
    ``TopologyView`` is pinned: a drain either completes before a
    collective snapshots the structure or raises ``TopologyTornError``;
  * **listeners see every terminal action** — subscribers registered with
    :meth:`FaultPipeline.add_listener` are invoked once per terminal
    action, *after* the repair has been applied. The serve subsystem
    (repro.serve) relies on this to re-enqueue a failed node's in-flight
    requests at-least-once: the listener fires for every verdict the node
    appears in, and the engine's dedup guard collapses redeliveries back
    to exactly-once from the client's view.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, Callable, Iterable

from repro.core.agreement import agree_fault
from repro.core.detector import notice_fault
from repro.core.types import (
    FailureKind,
    FaultEvent,
    FaultSource,
    PipelineTrace,
    RecoveryAction,
    RepairScope,
)

if TYPE_CHECKING:
    from repro.core.executor import VirtualCluster

ALL_SOURCES = (FaultSource.COLLECTIVE, FaultSource.HEARTBEAT,
               FaultSource.STRAGGLER, FaultSource.INJECTED)


class FaultPipeline:
    """Event-driven fault pipeline drained at step boundaries."""

    def __init__(self, cluster: "VirtualCluster"):
        self.cluster = cluster
        self.inbox: list[FaultEvent] = []
        self.actions: list[RecoveryAction] = []
        self.traces: list[PipelineTrace] = []
        self._listeners: list[Callable[[RecoveryAction], None]] = []

    def add_listener(self, fn: Callable[[RecoveryAction], None]) -> None:
        """Subscribe to terminal actions. Called once per action, after the
        repair has been applied — the topology the listener reads is the
        repaired one. Registration order is invocation order."""
        self._listeners.append(fn)

    # -- signal ingestion (detect-stage feeds) --------------------------------

    def observe(self, event: FaultEvent) -> None:
        """Queue an observed fault signal for the next drain."""
        self.inbox.append(event)

    def observe_collective(self, op: str, participants: list[int],
                           failed: set[int], root: int | None = None) -> None:
        """A collective surfaced PROC_FAILED for ``failed`` participants."""
        if failed:
            self.observe(FaultEvent(
                nodes=tuple(sorted(failed)), step=self.cluster._step,
                source=FaultSource.COLLECTIVE, op=op, root=root,
                participants=tuple(participants)))

    def observe_suspicion(self, observers: Iterable[int],
                          suspects: Iterable[int],
                          step: int | None = None) -> None:
        """One *side's* suspicion of the other — the correlated-failure
        injection channel (network partitions, gray switches). Unlike the
        coordinator heartbeat (every live node reads it), this suspicion is
        held only by ``observers``: the notice stage credits exactly them,
        and agreement takes the union over LIVE observers — so a fenced
        side's accusation of the survivors never enters the verdict. If
        both sides stay alive (unfenced split), the agree stage's majority
        quorum condemns exactly the minority — see :meth:`_agree`."""
        observers = tuple(sorted(set(observers)))
        suspects = tuple(sorted(set(suspects)))
        if observers and suspects:
            self.observe(FaultEvent(
                nodes=suspects,
                step=self.cluster._step if step is None else step,
                source=FaultSource.HEARTBEAT, observers=observers))

    # -- stages ---------------------------------------------------------------

    def _detect(self, step: int,
                sources: frozenset[FaultSource]) -> list[FaultEvent]:
        cl = self.cluster
        events = [e for e in self.inbox if e.source in sources]
        self.inbox = [e for e in self.inbox if e.source not in sources]
        if FaultSource.HEARTBEAT in sources:
            suspects = cl.detector.suspicions(cl.clock.sim_seconds,
                                              cl.topo.nodes)
            if suspects:
                events.append(FaultEvent(nodes=suspects, step=step,
                                         source=FaultSource.HEARTBEAT))
        if FaultSource.STRAGGLER in sources:
            lagging = tuple(n for n in cl.straggler.stragglers()
                            if n in cl.topo.nodes)
            if lagging:
                events.append(FaultEvent(nodes=lagging, step=step,
                                         source=FaultSource.STRAGGLER,
                                         kind=FailureKind.STRAGGLE))
        return events

    def _notice(self, events: list[FaultEvent]
                ) -> tuple[dict[int, set[int]], set[int]]:
        """Per-observer suspicion sets. Collective events notice per the
        op's semantics (bcast partially — the BNP); heartbeat/straggler/
        injected suspicion is coordinator state every live node reads —
        unless the event carries explicit ``observers`` (the partition
        channel: each side's suspicion is its own side's knowledge only).

        Also returns the suspects accused *only* through observer-carrying
        events — those hold no ground truth, so the agree stage demands a
        majority quorum before condemning them."""
        cl = self.cluster
        live = set(cl.live_nodes)
        observations: dict[int, set[int]] = {}
        suspicion_only: set[int] = set()
        grounded: set[int] = set()
        for e in events:
            failed = set(e.nodes)
            if e.source is FaultSource.COLLECTIVE:
                members = (list(e.participants) if e.participants is not None
                           else cl.topo.nodes)
                noticers = notice_fault(e.op or "allreduce", members,
                                        failed, root=e.root)
                grounded |= failed
            elif e.observers is not None:
                # partition-style one-sided suspicion: only the event's own
                # observers hold it (and only while they live — a fenced
                # side's accusations die with it at the agree stage)
                noticers = set(e.observers) & live
                suspicion_only |= failed
            else:
                noticers = live
                grounded |= failed
            for obs in noticers:
                observations.setdefault(obs, set()).update(failed)
        return observations, suspicion_only - grounded

    def _agree(self, observations: dict[int, set[int]],
               suspicion_only: set[int]) -> set[int]:
        """``agree_fault`` union, then the split-brain guard: a suspect
        backed by no ground-truth channel needs accusers from a strict
        majority of live nodes. Under an unfenced two-sided partition both
        sides accuse each other while alive — the plain union would condemn
        everyone; the quorum condemns exactly the minority (the same
        resolution a real quorum-based membership service applies). Ground
        -truth channels (collective PROC_FAILED, heartbeat timeout,
        injected) are untouched, so BNP partial noticing still condemns a
        genuinely dead node on a single live observation."""
        live = self.cluster.live_nodes
        verdict = agree_fault(observations, live)
        if not suspicion_only:
            return verdict
        live_set = set(live)
        quorum = len(live) // 2 + 1
        for s in suspicion_only & verdict:
            accusers = sum(1 for obs, seen in observations.items()
                           if s in seen and obs in live_set)
            if accusers < quorum:
                verdict.discard(s)
        return verdict

    def _plan(self, verdict: set[int], events: list[FaultEvent]
              ) -> tuple[str, set[int], list[RepairScope]]:
        """Select the strategy, mark which verdict nodes are performance
        faults that must be soft-failed before repair, and partition the
        verdict into disjoint :class:`RepairScope`\\ s — the minimal
        subtrees whose members must participate. Faults in unrelated
        subtrees land in separate scopes and repair concurrently."""
        straggle = set()
        for e in events:
            if e.kind is FailureKind.STRAGGLE:
                straggle |= set(e.nodes) & verdict
        scopes = self.cluster.topo.partition_scopes(verdict)
        return self.cluster.strategy.name, straggle, scopes

    def _apply(self, verdict: set[int], straggle: set[int],
               scopes: list[RepairScope]):
        cl = self.cluster
        for n in straggle:
            cl.failed.add(n)                     # soft-fail (discard policy)
        return cl.repair_scoped(scopes)

    # -- orchestration --------------------------------------------------------

    def drain(self, step: int,
              sources: Iterable[FaultSource] = ALL_SOURCES,
              gate: Callable[[set[int]], None] | None = None,
              ) -> list[RecoveryAction]:
        """Run detect → notice → agree → plan → apply for the given channels.

        ``gate`` runs between agree and plan with the verdict — the
        executor's root-failure policy hook (STOP raises there, before any
        repair mutates state; IGNORE flags the op skipped).
        """
        srcs = frozenset(sources)
        timings: dict[str, float] = {}

        t0 = time.perf_counter()
        events = self._detect(step, srcs)
        timings["detect"] = time.perf_counter() - t0
        if not events:
            return []

        t0 = time.perf_counter()
        observations, suspicion_only = self._notice(events)
        timings["notice"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        verdict = self._agree(observations, suspicion_only)
        timings["agree"] = time.perf_counter() - t0
        if not verdict:
            return []
        if gate is not None:
            gate(verdict)

        t0 = time.perf_counter()
        strategy_name, straggle, scopes = self._plan(verdict, events)
        timings["plan"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        repaired = self._apply(verdict, straggle, scopes)
        timings["apply"] = time.perf_counter() - t0

        sources = tuple(sorted({e.source for e in events},
                               key=lambda s: s.value))
        windows = {id(br.report) for br in self.cluster.background}
        actions = [
            RecoveryAction(
                step=step,
                verdict=scope.verdict,
                strategy=strategy_name,
                sources=sources,
                report=report,
                terminal=True,
                stage_seconds=dict(timings),
                scope=scope,
                # the repair's charge went to a background window instead
                # of the clock — still open when the action is emitted
                overlapped=id(report) in windows,
            )
            for scope, report in repaired
        ]
        self.actions.extend(actions)
        self.traces.append(PipelineTrace(
            step=step, n_events=len(events),
            verdict=tuple(sorted(verdict)), stage_seconds=dict(timings)))
        for action in actions:
            for listener in self._listeners:
                listener(action)
        return actions
