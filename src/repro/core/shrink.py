"""Shrink engine: repair plans and the S(x) cost model (paper §V, Fig. 3).

ULFM's ``MPIX_Comm_shrink`` requires *all* processes of the shrunk
communicator to participate; its empirical cost ``S(x)`` grows between
linearly and quadratically with the participant count x (Fenix/LFLR
measurements cited by the paper). The hierarchical topology bounds the
participant set:

    R_H(s, k) = S(k) + 2·S(k+1) + S(s/k)   if a master failed      (Eq. 1)
              = S(k)                        otherwise
    vs. flat:  R_F(s) = S(s)

Repair plan for a failed master (paper Fig. 3):
  1. shrink the failed master's local_comm (its members noticed);
  2. the predecessor's master *notifies* its POV (they could not notice
     directly), then that POV shrinks;
  3. shrink the successor POV (contains the failed master directly);
  4. shrink the global_comm;
  5. *promote* the new lowest rank of the orphaned legion to master and
     *include* it into the global_comm (via the successor POV link);
  6. update the predecessor POV with the new master.

In this framework "shrink" = rebuild the participant set's collective
topology + reshard + (possibly) recompile — see mesh_manager. The engine
returns a :class:`RepairReport` carrying both the *model* cost (S(x) sum,
simulated seconds) and the measured wall-clock of our repair path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy
from repro.core.types import RepairReport, RepairStep


@dataclass(frozen=True)
class ShrinkCostModel:
    """S(x) — calibrated between the linear and quadratic empirical bounds.

    ``s_of_x(x) = a·x^p + c``: with p=1 the paper's linear hypothesis, p=2
    the quadratic one. Defaults follow the paper's Fig. 10 scale (~seconds
    for hundreds of ranks; a is per-rank cost, c the constant agreement+
    revoke overhead).
    """

    a: float = 2.5e-3      # per-rank^p seconds
    p: float = 1.0         # 1 = linear (paper's configured hypothesis)
    c: float = 0.12        # constant term: revoke + agree + comm-create

    def s_of_x(self, x: int) -> float:
        if x <= 0:
            return 0.0
        return self.a * (float(x) ** self.p) + self.c

    def flat_cost(self, s: int) -> float:
        return self.s_of_x(s)

    def hierarchical_cost(self, s: int, k: int, master_failed: bool) -> float:
        """Eq. 1. ``s/k`` is the global_comm size (#legions)."""
        if not master_failed:
            return self.s_of_x(k)
        n_masters = max(1, round(s / max(k, 1)))
        return self.s_of_x(k) + 2.0 * self.s_of_x(k + 1) + self.s_of_x(n_masters)


def master_failed_in(topo: LegionTopology, failed: set[int],
                     steps: list[RepairStep]) -> bool:
    """Did this repair lose a master? Hierarchical plans carry an explicit
    promote step; flat topologies need the direct check (shared by the
    shrink and substitute engines — must be evaluated BEFORE mutation)."""
    return any(st.op == "promote" for st in steps) or (
        topo.n_legions == 1
        and any(topo.is_master(n) for n in failed if n in topo.home))


def failures_by_legion(topo: LegionTopology, failed: set[int]) -> dict[int, list[int]]:
    """Group the failed nodes still present in the topology by legion index
    (simultaneous failures fold legion-by-legion — shared by the shrink and
    substitute engines)."""
    by_legion: dict[int, list[int]] = {}
    for node in sorted(failed):
        if node in topo.home and any(node in lg.members for lg in topo.legions):
            by_legion.setdefault(topo.legion_of(node).index, []).append(node)
    return by_legion


class ShrinkEngine:
    """Builds and applies repair plans against a LegionTopology."""

    def __init__(self, policy: LegioPolicy, cost: ShrinkCostModel | None = None):
        self.policy = policy
        self.cost = cost or ShrinkCostModel()

    # ---- plan construction -------------------------------------------------

    def plan(self, topo: LegionTopology, failed: set[int]) -> list[RepairStep]:
        """Repair steps for the failed set under the current topology.

        Multi-failure: the paper treats each failure independently; we fold
        simultaneous failures legion-by-legion (one local shrink per affected
        legion; master steps only for legions that lost their master).

        Scoped repair (Rocco & Palermo): a master failure climbs the levels
        of the recursive topology exactly as far as the dead node held
        masterships — at each affected level the ring neighbours' POVs and
        the *parent group's* comm shrink, never the whole master set. At
        depth 2 the parent group IS the paper's global_comm, reproducing
        Fig. 3 verbatim; at depth >= 3 the participant count is bounded by
        O(k·depth), independent of the cluster size.
        """
        steps: list[RepairStep] = []
        hierarchical = topo.n_legions > 1
        if not hierarchical:
            survivors = tuple(n for n in topo.nodes if n not in failed)
            steps.append(RepairStep(
                op="shrink", comm="world", participants=survivors,
                cost_units=self.cost.s_of_x(topo.size),
            ))
            return steps

        for li, dead in sorted(failures_by_legion(topo, failed).items()):
            lg = next(l for l in topo.legions if l.index == li)
            local_survivors = tuple(n for n in lg.members if n not in failed)
            # 1. local shrink — members noticed directly
            steps.append(RepairStep(
                op="shrink", comm=f"local_{li}", participants=local_survivors,
                cost_units=self.cost.s_of_x(len(lg.members)),
            ))
            if lg.master not in dead:
                continue
            dead_master = lg.master
            level, idx = 0, li
            group_members: tuple[int, ...] = tuple(lg.members)
            promoted: int | None = None    # child master promoted one level down
            while level < topo.depth - 1:
                ring = topo.groups(level)
                k_here = len(group_members)
                succ = None
                if len(ring) > 1:
                    pred = topo.predecessor_at(level, idx)
                    succ = topo.successor_at(level, idx)
                    # 2. predecessor master notifies its POV, then it shrinks
                    pred_pov = tuple(n for n in topo.pov_at(level, pred.index)
                                     if n not in failed)
                    steps.append(RepairStep(
                        op="notify", comm=topo.pov_name(level, pred.index),
                        participants=(pred.master,), cost_units=0.0,
                    ))
                    steps.append(RepairStep(
                        op="shrink", comm=topo.pov_name(level, pred.index),
                        participants=pred_pov,
                        cost_units=self.cost.s_of_x(k_here + 1),
                    ))
                    # 3. own POV shrink (contains the failed master directly)
                    own_pov = tuple(n for n in topo.pov_at(level, idx)
                                    if n not in failed)
                    steps.append(RepairStep(
                        op="shrink", comm=topo.pov_name(level, idx),
                        participants=own_pov,
                        cost_units=self.cost.s_of_x(k_here + 1),
                    ))
                # 4. parent comm shrink — the scope boundary: only the group
                #    that contains the fault, not every master in the cluster
                parent = topo.parent_of(level, idx)
                parent_comm = topo.comm_name(level + 1, parent.index)
                steps.append(RepairStep(
                    op="shrink", comm=parent_comm,
                    participants=tuple(m for m in parent.members
                                       if m not in failed),
                    cost_units=self.cost.s_of_x(len(parent.members)),
                ))
                # 5. promote + include the new master (via succ POV link).
                #    At level >= 1 the master promoted one level down has
                #    just joined this group, so it competes for mastership.
                survivors_here = tuple(n for n in group_members
                                       if n not in failed)
                if promoted is not None:
                    survivors_here = tuple(sorted({*survivors_here, promoted}))
                if survivors_here:
                    new_master = min(survivors_here)
                    promoted = new_master
                    steps.append(RepairStep(
                        op="promote", comm=topo.comm_name(level, idx),
                        participants=(new_master,), cost_units=0.0,
                    ))
                    include = ((new_master, succ.master) if succ is not None
                               else (new_master,))
                    steps.append(RepairStep(
                        op="include", comm=parent_comm,
                        participants=include, cost_units=0.0,
                    ))
                if parent.master != dead_master:
                    break
                # the dead node also mastered the parent group — the repair
                # continues one level up (and only there)
                level, idx = level + 1, parent.index
                group_members = parent.members
        return steps

    # ---- application ---------------------------------------------------------

    def repair(self, topo: LegionTopology, failed: set[int]) -> RepairReport:
        """Plan + mutate the topology. Returns the report (plan, costs, wall)."""
        t0 = time.perf_counter()
        steps = self.plan(topo, failed)
        master_failed = master_failed_in(topo, failed, steps)
        hierarchical = topo.n_legions > 1
        for node in sorted(failed):
            if node in topo.home and any(node in lg.members for lg in topo.legions):
                topo.remove(node)
        topo.compact()
        wall = time.perf_counter() - t0
        return RepairReport(
            trigger=tuple(sorted(failed)),
            hierarchical=hierarchical,
            master_failed=master_failed,
            steps=steps,
            model_cost=sum(st.cost_units for st in steps),
            wall_seconds=wall,
            survivors=topo.size,
        )

    def cost_flat(self, s: int) -> float:
        return self.cost.flat_cost(s)

    def cost_hierarchical(self, s: int, k: int, master_failed: bool) -> float:
        return self.cost.hierarchical_cost(s, k, master_failed)

    def expected_repair_cost(self, s: int, k: int) -> float:
        """E[R_H] under uniform failure probability: P(master) = 1/k."""
        p_master = 1.0 / max(k, 1)
        return (p_master * self.cost.hierarchical_cost(s, k, True)
                + (1 - p_master) * self.cost.hierarchical_cost(s, k, False))
