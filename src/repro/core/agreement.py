"""Fault agreement — the BNP fix (paper §IV) and ULFM ``MPIX_Comm_agree``.

After a collective on a faulty communicator only *some* survivors hold a
PROC_FAILED verdict (the Broadcast Notification Problem, P.3). Legio runs an
agreement that "combines the results obtained by all the processes into a
single one equal for all". Two implementations:

  * :func:`agree_fault` — runtime-level: union of per-observer suspicion
    sets; all survivors adopt the union (what the repair path consumes).
  * :func:`liveness_psum` / :func:`agree_bitmap_inprogram` — in-program:
    a liveness bitmap AND-reduce expressed as a ``shard_map`` ``psum`` so the
    verdict is computed *inside* the jitted step with zero extra host round
    trips (one (n_nodes,) int32 all-reduce riding the gradient reduction).

The agreement itself must tolerate faults (ULFM guarantees this); here the
union over live observers is trivially fault-tolerant because dead observers
simply contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.compat import shard_map


def agree_fault(observations: dict[int, set[int]], live: list[int]) -> set[int]:
    """Union of suspicion sets across live observers -> single verdict.

    ``observations[i]`` is the set of nodes that observer ``i`` noticed as
    failed; observers not in ``live`` are ignored (they may be dead).
    The result is what every survivor adopts — identical everywhere,
    resolving the BNP.
    """
    verdict: set[int] = set()
    for obs, seen in observations.items():
        if obs in live:
            verdict |= seen
    return verdict


def agreement_rounds(n_participants: int) -> int:
    """Tree-agreement depth — used by the repair cost model (log2 rounds)."""
    return max(1, int(np.ceil(np.log2(max(n_participants, 2)))))


# ---------------------------------------------------------------------------
# In-program liveness bitmap (shard_map)
# ---------------------------------------------------------------------------

def liveness_psum(local_bitmap: jax.Array, axis_name: str | tuple[str, ...]) -> jax.Array:
    """AND-reduce liveness bitmaps: each shard holds (n_nodes,) int32 with 1
    for nodes *it* believes alive; the product-reduce (min via multiply on
    0/1) yields the agreed bitmap. Runs inside shard_map/jit."""
    # 0/1 bitmap: AND == min == product. psum of log would be fancy; for 0/1
    # use psum of (1 - x) and threshold: agreed_alive = (sum of dead-votes == 0)
    dead_votes = jax.lax.psum(1 - local_bitmap, axis_name)
    return (dead_votes == 0).astype(jnp.int32)


def agree_bitmap_inprogram(mesh: Mesh, bitmaps: jax.Array) -> np.ndarray:
    """Run the liveness AND-reduce over the mesh's data axes.

    bitmaps: (n_shards, n_nodes) int32 — row i is shard i's local view.
    Returns the agreed (n_nodes,) bitmap (identical for all shards).
    """
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not axes:
        return np.asarray(jnp.min(bitmaps, axis=0))

    shard_axes = axes if len(axes) > 1 else axes[0]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(shard_axes, None),
        out_specs=P(None),
    )
    def run(bm):
        local = jnp.min(bm, axis=0)          # AND within this shard's rows
        return liveness_psum(local, shard_axes)

    return np.asarray(run(bitmaps))
