"""Per-legion checkpoint/restart — the paper's §VII direction, implemented.

The paper stops at a discussion: system-level C/R frameworks are transparent
but global; MANA's per-process checkpoints would allow restarting *only* the
failed processes, and "the steps towards local recovery are part of our
on-going work". This module is that local-recovery step for our runtime:

  * checkpoints are **per-(legion, member)** files written independently —
    file ops run on the local_comm (paper §V), so no global barrier;
  * **restart-only-failed**: a replacement node restores exactly the dead
    member's shard (checkpoint.store.restore_member) while survivors keep
    running from live state;
  * combined with the counter-based data pipeline, the restarted member
    regenerates precisely the shards the dead node would have consumed —
    recovery is bit-exact, not just statistically acceptable.

``LegionCheckpointer`` wraps the store with the topology: it knows which
member owns which state shard and snapshots asynchronously off the training
path.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Callable

from repro.checkpoint import store
from repro.core.hierarchy import LegionTopology

PyTree = Any


@dataclass
class RestartRecord:
    node: int
    legion: int
    step: int
    source: str            # "checkpoint" (store read) | "peer" (ring replica)


class LegionCheckpointer:
    """Topology-aware wrapper over the sharded checkpoint store."""

    def __init__(self, directory: str, *, keep: int = 3, async_writes: bool = True):
        self.directory = directory
        self.async_writer = store.AsyncCheckpointer(directory, keep=keep) \
            if async_writes else None
        self.keep = keep
        self.restarts: list[RestartRecord] = []
        # ShardReplicator wired in by VirtualCluster: every save() also
        # pushes the host-snapshotted shards to their POV-ring buddies
        self.replicator = None

    # -- save ---------------------------------------------------------------------

    def shard_map_for(self, topo: LegionTopology,
                      state_of: Callable[[int], PyTree]
                      ) -> dict[tuple[int, int], PyTree]:
        """{(legion, node): node state} for every live member."""
        return {
            (lg.index, n): state_of(n)
            for lg in topo.legions for n in lg.members
        }

    def save(self, step: int, topo: LegionTopology,
             state_of: Callable[[int], PyTree], *, meta: dict | None = None,
             sync: bool = False) -> float:
        """Snapshot every member's shard. Returns blocking seconds."""
        shards = self.shard_map_for(topo, state_of)
        meta = dict(meta or {})
        meta.setdefault("k", topo.k)
        if self.replicator is not None:
            # ring replication rides every checkpoint: the same host
            # snapshot goes to each member's POV buddy (in-memory, posted
            # through the session ledger — settles at the next boundary)
            self.replicator.push_map(step, topo, shards)
        if self.async_writer is not None and not sync:
            return self.async_writer.save_async(step, shards, meta=meta)
        import time
        t0 = time.perf_counter()
        store.save(self.directory, step, shards, meta=meta)
        return time.perf_counter() - t0

    def wait(self) -> None:
        if self.async_writer is not None:
            self.async_writer.wait()

    def close(self) -> None:
        if self.async_writer is not None:
            self.async_writer.close()

    # -- restart-only-failed ---------------------------------------------------------

    def latest_step(self) -> int | None:
        return store.latest_step(self.directory)

    def restore_failed_member(self, legion: int, node: int,
                              *, step: int | None = None,
                              template: PyTree | None = None) -> PyTree:
        """Load exactly one dead member's shard for its replacement node."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        state = store.restore_member(self.directory, step, legion, node,
                                     template=template)
        self.restarts.append(RestartRecord(node=node, legion=legion, step=step,
                                           source="checkpoint"))
        return state

    def restore_all(self, *, step: int | None = None,
                    template: PyTree | None = None):
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return store.restore(self.directory, step, template=template)

    def files_for_step(self, step: int) -> list[str]:
        sdir = os.path.join(self.directory, f"step_{step:06d}")
        out = []
        for root, _, names in os.walk(sdir):
            out.extend(os.path.join(root, n) for n in names)
        return sorted(out)
