"""Collective operations over the legion topology (paper §V classes).

Two layers:

1. **Runtime schedules** — the paper's operation classes (one-to-one,
   one-to-all, all-to-one, all-to-all, comm-creator, file, local-only) with
   their hierarchical execution plans (Fig. 4): a Bcast runs in the root's
   local_comm, then the global_comm, then the other local_comms in parallel;
   a Reduce is the reverse; an AllReduce is reduce-then-bcast. The schedules
   both (a) actually move data on the virtual cluster (correctness is
   testable: every survivor receives the root's payload / the full sum) and
   (b) produce an alpha-beta time estimate, so the paper's Fig. 5-9 overhead
   benchmarks have a deterministic analogue on CPU.

2. **In-program collectives** — ``shard_map`` implementations used by the
   SPMD train step: :func:`hierarchical_psum` performs the two-stage
   reduction (within-legion, then cross-legion) that maps onto intra-pod ICI
   + cross-pod DCI on real hardware.

The runtime schedules sit on a **data-plane seam**
(:mod:`repro.dist.dataplane`): the schedule walk — who reduces to whom,
the stage list, the alpha-beta charge — is backend-independent control
plane; the actual payload motion (the fold behind a reduce stage, the
broadcast payload hop, the compression round-trip) delegates to the
injected :class:`~repro.dist.dataplane.DataPlane`. The default sim plane
reproduces the pre-seam numpy behavior bit-for-bit; the jax plane moves the
same bytes through device collectives. Stage lists and timing are
identical on both by construction.

Alpha-beta model: a collective over x participants moving m bytes per rank
costs ``ceil(log2 x) * (alpha + m / beta)`` (binomial tree). Intra-legion
hops ride fast links; the cross-legion (global_comm) hop rides slow links —
the constants mirror TPU ICI vs DCI (see roofline constants).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.hierarchy import LegionTopology
from repro.dist.compat import shard_map

# Operation classes (paper §V)
ONE_TO_ONE = "one_to_one"
ONE_TO_ALL = "one_to_all"
ALL_TO_ONE = "all_to_one"
ALL_TO_ALL = "all_to_all"
COMM_CREATOR = "comm_creator"
FILE_OP = "file"
LOCAL_ONLY = "local_only"


@dataclass(frozen=True)
class LinkModel:
    """alpha (s) / beta (B/s) per link class. Defaults: ICI-ish intra,
    DCI-ish cross (an order of magnitude slower — why the hierarchical
    schedule confines bulk traffic to fast links).

    ``level_slowdown`` is the per-level cost accounting knob for depth >= 3
    topologies: a hop at level ℓ >= 1 is ``level_slowdown**(ℓ-1)`` times
    dearer than the first cross hop (rack -> pod -> data-center fabrics each
    slower than the one below). 1.0 (the default) keeps every cross-level
    hop identical — the depth-2 model unchanged."""

    alpha_intra: float = 1.0e-6
    beta_intra: float = 50.0e9        # ~ICI per-link
    alpha_cross: float = 10.0e-6
    beta_cross: float = 5.0e9         # ~DCI / data-center network
    level_slowdown: float = 1.0

    def tree_time(self, participants: int, nbytes: int, cross: bool) -> float:
        if participants <= 1:
            return 0.0
        rounds = math.ceil(math.log2(participants))
        a = self.alpha_cross if cross else self.alpha_intra
        b = self.beta_cross if cross else self.beta_intra
        return rounds * (a + nbytes / b)

    def level_time(self, participants: int, nbytes: int, level: int) -> float:
        """Binomial-tree time for one hop at hierarchy ``level`` — level 0
        rides fast intra-legion links, every level above rides cross links,
        scaled by ``level_slowdown`` per additional level."""
        t = self.tree_time(participants, nbytes, cross=level >= 1)
        if level >= 2 and self.level_slowdown != 1.0:
            t *= self.level_slowdown ** (level - 1)
        return t


@dataclass
class CollectiveResult:
    """Outcome of one scheduled collective on the virtual cluster."""
    op: str
    sim_seconds: float                      # alpha-beta estimate
    data: dict[int, np.ndarray]             # node -> payload after the op
    stages: list[tuple[str, int, float]]    # (comm, participants, seconds)


class HierarchicalCollectives:
    """Executes the paper's §V schedules over a LegionTopology.

    ``compression`` (beyond-paper) applies int8/top-k error-feedback
    compression to the *cross-legion* hop only — the master-to-master stage
    rides the slow links, so that is where volume reduction pays (see
    optim/compression.py). ``residuals`` is the per-master error-feedback
    store; pass a persistent dict (the VirtualCluster owns one) so residuals
    survive across steps — dead masters' residuals are simply abandoned,
    which is safe (their contribution was already incorporated or lost with
    the node, exactly like its batch shard).

    ``dataplane`` selects what moves the payload bytes (see module
    docstring); the default sim plane keeps every schedule numpy-only —
    no jax dispatch ever enters the hot simulator loop.
    """

    def __init__(self, topo: LegionTopology, link: LinkModel | None = None,
                 *, compression: str = "none", topk_fraction: float = 0.05,
                 residuals: dict | None = None, dataplane=None):
        from repro.dist.dataplane import default_dataplane
        self.topo = topo
        self.link = link or LinkModel()
        self.compression = compression
        self.topk_fraction = topk_fraction
        self.residuals = residuals if residuals is not None else {}
        self.dataplane = dataplane if dataplane is not None else default_dataplane()

    def _compress_cross(self, master: int, partial: np.ndarray
                        ) -> tuple[np.ndarray, int]:
        """Error-feedback compress one master's partial for the slow hop.
        Returns (decompressed-at-receiver value, wire bytes). The round-trip
        itself runs on the data plane (numpy twins on sim, Pallas/lax
        kernels on jax — byte-identical either way); the residual update and
        the wire-byte accounting stay here in the control plane, so both
        backends account the hop identically."""
        from repro.optim import compression as C
        if self.compression not in ("int8", "topk"):
            return partial, partial.nbytes
        gf = partial.astype(np.float32) + self.residuals.get(master, 0.0)
        back = self.dataplane.compress(gf, self.compression,
                                       self.topk_fraction)
        self.residuals[master] = gf - back
        nbytes = C.compressed_bytes(gf, self.compression, self.topk_fraction)
        return back, nbytes

    # -- helpers ---------------------------------------------------------------

    def _stage(self, stages, comm, n, nbytes, cross):
        t = self.link.tree_time(n, nbytes, cross)
        stages.append((comm, n, t))
        return t

    def _lstage(self, stages, comm, n, nbytes, level):
        """Stage with per-level cost accounting: level 0 = fast intra links,
        level >= 1 = (progressively) slow cross-level hops."""
        t = self.link.level_time(n, nbytes, level)
        stages.append((comm, n, t))
        return t

    # -- one-to-all (Bcast): the root's chain climbs the levels, then every
    #    subtree propagates downward in parallel (Fig. 4, applied per level) --

    def bcast(self, root: int, payload: np.ndarray) -> CollectiveResult:
        topo = self.topo
        # one data-plane hop moves the root's payload (device round-trip on
        # jax, identity on sim); the schedule below fans the result out
        payload = self.dataplane.bcast_payload(payload)
        nbytes = payload.nbytes
        stages: list[tuple[str, int, float]] = []
        data = {root: payload}
        t_total = 0.0
        if topo.n_legions == 1:
            lg = topo.legions[0]
            t_total += self._stage(stages, "world", len(lg), nbytes, cross=False)
            for n in lg.members:
                data[n] = payload
            return CollectiveResult("bcast", t_total, data, stages)
        root_lg = topo.legion_of(root)
        # 1. up-chain: root's local_comm, then the group containing the root
        #    at every level — each hop hands the payload to that comm's
        #    members (the masters of the level below)
        t_total += self._lstage(stages, f"local_{root_lg.index}",
                                len(root_lg), nbytes, level=0)
        for n in root_lg.members:
            data[n] = payload
        chain = [root_lg.index]                 # group index per level
        for level, groups in enumerate(topo.levels(), start=1):
            g = next(g for g in groups if chain[-1] in g.children)
            t_total += self._lstage(stages, topo.comm_name(level, g.index),
                                    len(g.members), nbytes, level=level)
            for m in g.members:
                data[m] = payload
            chain.append(g.index)
        # 2. down-sweep: levels depth-2 .. 1 then the legions — at each level
        #    every group off the root chain broadcasts within itself, all
        #    groups of a level in parallel (max over the level)
        for level in range(topo.depth - 2, 0, -1):
            t_par = 0.0
            for g in topo.groups(level):
                if g.index == chain[level]:
                    continue                     # delivered by the up-chain
                t = self._lstage(stages, topo.comm_name(level, g.index),
                                 len(g.members), nbytes, level=level)
                t_par = max(t_par, t)
                for m in g.members:
                    data[m] = payload
            t_total += t_par
        t_par = 0.0
        for lg in topo.legions:
            if lg.index == root_lg.index or not lg.members:
                continue
            t = self._lstage(stages, f"local_{lg.index}", len(lg), nbytes,
                             level=0)
            t_par = max(t_par, t)
            for n in lg.members:
                data[n] = payload
        return CollectiveResult("bcast", t_total + t_par, data, stages)

    # -- all-to-one (Reduce): reverse propagation, level by level ---------------

    def reduce(self, root: int, contributions: dict[int, np.ndarray],
               op: Callable[[np.ndarray, np.ndarray], np.ndarray] = np.add
               ) -> CollectiveResult:
        topo = self.topo
        sample = next(iter(contributions.values()))
        nbytes = sample.nbytes
        stages: list[tuple[str, int, float]] = []
        if topo.n_legions == 1:
            lg = topo.legions[0]
            t = self._stage(stages, "world", len(lg), nbytes, cross=False)
            total = self.dataplane.reduce(
                [contributions[n] for n in lg.members if n in contributions], op)
            return CollectiveResult("reduce", t, {root: total}, stages)
        # 1. each local_comm reduces to its master — in parallel
        t_total = 0.0
        t_par = 0.0
        partials: dict[int, np.ndarray] = {}
        for lg in topo.legions:
            if not lg.members:
                continue
            parts = [contributions[n] for n in lg.members if n in contributions]
            if not parts:
                # whole legion is silent this step (e.g. a just-spliced spare
                # that has not computed yet) — it simply contributes nothing
                continue
            t = self._lstage(stages, f"local_{lg.index}", len(lg), nbytes,
                             level=0)
            t_par = max(t_par, t)
            partials[lg.master] = self.dataplane.reduce(parts, op)
        t_total += t_par
        if not partials:
            # every contributor has left the topology (e.g. the whole
            # verdict of a drain) — surface a clear collective error, not a
            # bare StopIteration from the level walk below
            raise ValueError(
                "reduce: no surviving contributor is present in the "
                f"topology (epoch {getattr(topo, 'epoch', '?')}, "
                f"{len(contributions)} contribution(s) offered)")
        # 2. every level reduces its groups' member partials to the group
        #    master, groups of a level in parallel. The first cross hop
        #    (level 1) rides the slowest relative gap — compression applies
        #    there (sum-compatible ops only); upper hops carry the already-
        #    reduced partials
        for level, groups in enumerate(topo.levels(), start=1):
            t_par = 0.0
            next_partials: dict[int, np.ndarray] = {}
            for g in groups:
                contributing = [m for m in g.members if m in partials]
                if not contributing:
                    continue
                gbytes = nbytes
                if level == 1 and self.compression != "none" and op in (np.add,):
                    sent = [self._compress_cross(m, partials[m])
                            for m in contributing]
                    reduced = self.dataplane.reduce([s[0] for s in sent], op)
                    gbytes = max(s[1] for s in sent)
                else:
                    reduced = self.dataplane.reduce(
                        [partials[m] for m in contributing], op)
                t = self._lstage(stages, topo.comm_name(level, g.index),
                                 len(contributing), gbytes, level=level)
                t_par = max(t_par, t)
                next_partials[g.master] = reduced
            t_total += t_par
            partials = next_partials
        total = next(iter(partials.values()))
        # 3. if the root is not its legion's master, one intra hop delivers it
        root_lg = topo.legion_of(root)
        if root != root_lg.master:
            t_total += self._lstage(stages, f"local_{root_lg.index}", 2,
                                    nbytes, level=0)
        return CollectiveResult("reduce", t_total, {root: total}, stages)

    # -- all-to-all (AllReduce) = all-to-one + one-to-all (paper §V) -----------

    def allreduce(self, contributions: dict[int, np.ndarray],
                  op: Callable[[np.ndarray, np.ndarray], np.ndarray] = np.add
                  ) -> CollectiveResult:
        topo = self.topo
        root = topo.masters[0] if topo.masters else topo.nodes[0]
        red = self.reduce(root, contributions, op)
        bc = self.bcast(root, red.data[root])
        return CollectiveResult(
            "allreduce", red.sim_seconds + bc.sim_seconds, bc.data,
            red.stages + bc.stages)

    # -- barrier: an allreduce of zero-byte tokens ------------------------------

    def barrier(self) -> CollectiveResult:
        token = np.zeros((1,), np.int8)
        contributions = {n: token for n in self.topo.nodes}
        res = self.allreduce(contributions, np.maximum)
        return CollectiveResult("barrier", res.sim_seconds,
                                {n: token for n in self.topo.nodes}, res.stages)

    # -- comm-creator: must run on the ENTIRE communicator (paper §V) -----------

    def comm_create(self) -> CollectiveResult:
        n = self.topo.size
        stages: list[tuple[str, int, float]] = []
        t = self._stage(stages, "world", n, 64, cross=True)
        return CollectiveResult("comm_creator", t, {}, stages)

    # -- file / local ops: bounded to the local_comm (no propagation) -----------

    def file_op(self, node: int, nbytes: int) -> CollectiveResult:
        lg = self.topo.legion_of(node)
        stages: list[tuple[str, int, float]] = []
        t = self._stage(stages, f"local_{lg.index}", len(lg), 0, cross=False)
        return CollectiveResult("file", t, {}, stages)

    def local_op(self, node: int) -> CollectiveResult:
        return CollectiveResult("local_only", 0.0, {}, [])


def jnp_asarray(x: np.ndarray):
    return jnp.asarray(x)


def _tree_reduce(parts: list[np.ndarray], op) -> np.ndarray:
    """Sequential fold — the sim data plane's reduction (kept as a module
    helper for direct callers; the schedules go through the seam)."""
    acc = parts[0]
    for p in parts[1:]:
        acc = op(acc, p)
    return acc


def flat_collective_time(link: LinkModel, op: str, n: int, nbytes: int) -> float:
    """Baseline (non-hierarchical) time: one binomial tree over everyone,
    crossing slow links (a flat communicator cannot confine traffic)."""
    if op == ALL_TO_ALL:
        return 2.0 * link.tree_time(n, nbytes, cross=True)
    return link.tree_time(n, nbytes, cross=True)


def agreement_time(link: LinkModel, n: int) -> float:
    """Cost of the post-collective fault agreement (BNP fix): one zero-byte
    allreduce over n participants — Legio's per-call overhead."""
    return 2.0 * link.tree_time(n, 8, cross=True)


# ---------------------------------------------------------------------------
# In-program (shard_map) collectives — the SPMD production path
# ---------------------------------------------------------------------------

def hierarchical_psum(x: jax.Array, *, legion_axis: str, member_axis: str) -> jax.Array:
    """Two-stage all-reduce: within-legion first (fast links), then
    cross-legion (slow links). Numerically identical to
    ``psum(x, (member, legion))``; structurally it pins the reduction order
    so the compiler's collective schedule matches the paper's Fig. 4."""
    x = jax.lax.psum(x, member_axis)
    return jax.lax.psum(x, legion_axis)


def hierarchical_psum_scatter(x: jax.Array, *, legion_axis: str,
                              member_axis: str, scatter_dim: int = 0) -> jax.Array:
    """Bandwidth-optimal variant: reduce-scatter within the legion, all-reduce
    the shards across legions, leaving the result scattered over members
    (caller all-gathers after the optimizer update — ZeRO-style)."""
    x = jax.lax.psum_scatter(x, member_axis, scatter_dimension=scatter_dim,
                             tiled=True)
    return jax.lax.psum(x, legion_axis)


def make_hierarchical_allreduce(mesh: Mesh, spec: P):
    """jit-able fn(x) -> allreduce(x) over the mesh's data axes, two-stage.

    On the multi-pod mesh ('pod','data','model') the legion axis is 'pod'
    (cross-DCI) and the member axis is 'data' (intra-ICI); single-pod falls
    back to one-stage psum over 'data'.
    """
    names = mesh.axis_names
    has_pod = "pod" in names

    @functools.partial(shard_map, mesh=mesh, in_specs=spec, out_specs=spec)
    def _allreduce(x):
        if has_pod:
            return hierarchical_psum(x, legion_axis="pod", member_axis="data")
        return jax.lax.psum(x, "data")

    return jax.jit(_allreduce)
