"""Failure detection: heartbeats, noticing semantics, stragglers.

MPI/ULFM surfaces faults as ``MPIX_ERR_PROC_FAILED`` return codes on the
ranks that *happened to interact* with the dead process (paper §III). On a
TPU cluster the analogue is the coordinator-side heartbeat timeout plus
collective-op errors. This module reproduces both channels:

  * :class:`HeartbeatDetector` — per-node last-seen timestamps against a
    simulated clock; a node whose heartbeat is older than ``timeout`` becomes
    SUSPECT. Suspicion is *local knowledge*: different observers can hold
    different suspicion sets, which is exactly the Broadcast Notification
    Problem (P.3) — resolved by :mod:`repro.core.agreement`.
  * :func:`notice_fault` — given a collective op's participant set and the
    ground-truth failed set, computes *which survivors notice* (P.3: in a
    Bcast only ranks downstream of the failure in the binomial tree notice;
    in Reduce/Allreduce/Barrier everyone does).
  * :class:`StragglerDetector` — per-node step-latency EWMA vs. the median;
    nodes slower than ``threshold ×`` median are soft-failed (the paper's
    discard policy applied to performance faults — beyond-paper feature).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.types import FailureEvent, FailureKind, NodeState


@dataclass
class HeartbeatDetector:
    timeout: float
    last_seen: dict[int, float] = field(default_factory=dict)
    states: dict[int, NodeState] = field(default_factory=dict)
    # incarnation guard: the topology epoch at which a node was confirmed
    # FAILED. A flapped node (transient power/network loss that returns
    # after the repair already evicted it) re-announces itself with its
    # *old* identity; without the guard, register() made it HEALTHY again
    # and the next sweep treated it as freshly live — resurrecting a node
    # the agreement already buried. Re-registration now needs a strictly
    # newer epoch, i.e. a deliberate re-provisioning, not a stale beat.
    epochs: dict[int, int] = field(default_factory=dict)

    def register(self, node: int, now: float = 0.0, *,
                 epoch: int | None = None) -> bool:
        """Admit ``node`` as HEALTHY. Returns False (and changes nothing)
        for a FAILED node unless ``epoch`` is strictly newer than the epoch
        recorded when it was repaired out — the flap guard."""
        if self.states.get(node) is NodeState.FAILED:
            if epoch is None or epoch <= self.epochs.get(node, 0):
                return False
        if epoch is not None:
            self.epochs[node] = max(epoch, self.epochs.get(node, 0))
        self.last_seen[node] = now
        self.states[node] = NodeState.HEALTHY
        return True

    def beat(self, node: int, now: float) -> None:
        if self.states.get(node) == NodeState.FAILED:
            return  # a failed node never comes back (permanent fault model)
        if node not in self.states:
            # a beat from a node nobody registered (e.g. a spare announcing
            # itself before its splice): auto-register instead of writing a
            # last_seen entry with no state — that orphan made the next
            # sweep() raise KeyError
            self.register(node, now)
            return
        self.last_seen[node] = now
        if self.states.get(node) == NodeState.SUSPECT:
            self.states[node] = NodeState.HEALTHY  # false suspicion cleared

    def sweep(self, now: float) -> list[int]:
        """Advance the detector; returns newly-SUSPECT nodes."""
        fresh = []
        for node, seen in self.last_seen.items():
            if self.states[node] == NodeState.HEALTHY and now - seen > self.timeout:
                self.states[node] = NodeState.SUSPECT
                fresh.append(node)
        return sorted(fresh)

    def confirm_failed(self, node: int, *, epoch: int | None = None) -> None:
        """Bury ``node``. ``epoch`` (the topology epoch of the repair that
        evicted it) arms the flap guard: see :meth:`register`."""
        self.states[node] = NodeState.FAILED
        if epoch is not None:
            self.epochs[node] = max(epoch, self.epochs.get(node, 0))

    def suspects(self) -> list[int]:
        return sorted(n for n, s in self.states.items() if s == NodeState.SUSPECT)

    def suspicions(self, now: float, within: list[int]) -> tuple[int, ...]:
        """Pipeline detect-stage entry point: advance the sweep to ``now``
        and return every currently-SUSPECT node among ``within`` (newly
        suspect or still unresolved from an earlier sweep). Suspicion is
        local knowledge — the caller feeds it to agreement, never straight
        to repair."""
        self.sweep(now)
        members = set(within)
        return tuple(n for n in self.suspects() if n in members)

    def healthy(self) -> list[int]:
        return sorted(n for n, s in self.states.items() if s == NodeState.HEALTHY)


# ---------------------------------------------------------------------------
# Noticing semantics (paper §III P.2/P.3)
# ---------------------------------------------------------------------------

def _bcast_children(v: int, size: int) -> list[int]:
    """Children of relative-rank ``v`` in the binomial bcast tree:
    v + 2^j for every 2^j > v with v + 2^j < size (root v=0 gets 1,2,4,...)."""
    out, j = [], 1
    while j <= v:
        j <<= 1
    while v + j < size:
        out.append(v + j)
        j <<= 1
    return out


def _bcast_notice_rel(size: int, failed_rel: set[int]) -> set[int]:
    """Relative ranks of *survivors* that notice a failure in a binomial
    bcast: live parents of a dead child (their send errors out) plus live
    descendants of a dead node (never receive -> timeout)."""
    noticers: set[int] = set()
    unreached: set[int] = set()

    def visit(v: int, cut: bool) -> None:
        dead = v in failed_rel
        if cut and not dead:
            unreached.add(v)
        for c in _bcast_children(v, size):
            if (not cut) and (not dead) and c in failed_rel:
                noticers.add(v)          # send to dead child fails
            visit(c, cut or dead)

    visit(0, False)
    return (noticers | unreached) - failed_rel


def notice_fault(
    op: str,
    participants: list[int],
    failed: set[int],
    root: int | None = None,
) -> set[int]:
    """Which *survivors* notice the fault after running ``op``.

    Mirrors the paper's P.2/P.3 observations:
      * bcast       — only ranks whose tree path crosses the failure notice
                      (the Broadcast Notification Problem);
      * reduce / allreduce / barrier / agree — every survivor notices;
      * p2p         — only the peer notices;
      * local       — nobody notices (P.1: local ops succeed).
    """
    live = [p for p in participants if p not in failed]
    hit = [p for p in participants if p in failed]
    if not hit:
        return set()
    if op in ("local", "comm_rank", "comm_size"):
        return set()
    if op == "p2p":
        return set(live)  # both endpoints involved; survivor notices
    if op == "bcast":
        if root is None:
            root = participants[0]
        size = len(participants)
        pos = {p: i for i, p in enumerate(participants)}
        root_pos = pos[root]
        failed_rel = {(pos[p] - root_pos) % size for p in hit}
        rel_notice = _bcast_notice_rel(size, failed_rel)
        return {participants[(r + root_pos) % size] for r in rel_notice}
    # reduce / allreduce / barrier / gather / scatter / agree: global notice
    return set(live)


# ---------------------------------------------------------------------------
# Stragglers
# ---------------------------------------------------------------------------

@dataclass
class StragglerDetector:
    """Soft-failure detection from per-node step latencies.

    A node is a straggler when its latency EWMA exceeds ``threshold`` times
    the cluster median AND the absolute excess clears ``min_latency`` —
    the floor keeps microsecond-scale timing noise from soft-failing nodes
    whose steps are all effectively instantaneous. threshold <= 0 disables.
    """

    threshold: float = 3.0
    alpha: float = 0.5                      # EWMA smoothing
    min_latency: float = 0.05               # s; below this, never a straggler
    ewma: dict[int, float] = field(default_factory=dict)
    min_samples: int = 3
    counts: dict[int, int] = field(default_factory=dict)

    def observe(self, node: int, latency: float) -> None:
        prev = self.ewma.get(node)
        self.ewma[node] = latency if prev is None else \
            self.alpha * latency + (1 - self.alpha) * prev
        self.counts[node] = self.counts.get(node, 0) + 1

    def drop(self, node: int) -> None:
        self.ewma.pop(node, None)
        self.counts.pop(node, None)

    def stragglers(self) -> list[int]:
        if self.threshold <= 0 or len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        if median <= 0:
            return []
        return sorted(
            n for n, v in self.ewma.items()
            if self.counts.get(n, 0) >= self.min_samples
            and v > self.threshold * median
            and v > self.min_latency
        )


@dataclass
class FaultInjector:
    """Deterministic fault schedule for tests/benchmarks/examples.

    ``schedule`` maps step -> list of FailureEvents delivered at that step.
    """

    events: list[FailureEvent] = field(default_factory=list)

    @staticmethod
    def at(pairs: list[tuple[int, int]],
           kind: FailureKind = FailureKind.CRASH) -> "FaultInjector":
        """pairs: [(step, node), ...]"""
        return FaultInjector([FailureEvent(node=n, step=s, kind=kind)
                              for s, n in pairs])

    def due(self, step: int) -> list[FailureEvent]:
        return [e for e in self.events if e.step == step]
