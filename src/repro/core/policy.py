"""Legio knobs and the optimal-legion-size relations (paper Eq. 1–4).

The paper exposes exactly two knobs (§V): the maximum size of the
local_comms (``k``) and a threshold cluster size above which the
hierarchical organization is used. We add the root-failure policy (§IV:
IGNORE vs STOP) and the batch policy (our DROP/REBALANCE rank-translation
analogue) as first-class settings.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def optimal_k_linear(s: int) -> int:
    """Eq. 3: s = k(k^2 - 2)/2  ->  k (assumes S(x) linear in x).

    Solves k^3 - 2k - 2s = 0 for the positive real root. The paper's
    Marconi100 runs configure local_comm size with this relation.
    """
    if s <= 2:
        return max(s, 1)
    # Cardano for t^3 + pt + q with p=-2, q=-2s (one real root for s >= 1)
    p, q = -2.0, -2.0 * float(s)
    disc = (q / 2.0) ** 2 + (p / 3.0) ** 3
    root = (-q / 2.0 + math.sqrt(disc)) ** (1.0 / 3.0) \
        - ((q / 2.0 + math.sqrt(disc)) ** (1.0 / 3.0) if (q / 2.0 + math.sqrt(disc)) > 0
           else -(abs(q / 2.0 + math.sqrt(disc)) ** (1.0 / 3.0)))
    k = max(2, round(root))
    # snap to the integer minimizing |s - k(k^2-2)/2|
    best = min((abs(s - kk * (kk * kk - 2) / 2.0), kk) for kk in (k - 1, k, k + 1) if kk >= 2)
    return best[1]


def optimal_k_quadratic(s: int) -> int:
    """Eq. 4: s = sqrt(2 k^2 (2 k^2 - 1) / 3)  ->  k (S(x) quadratic)."""
    if s <= 2:
        return max(s, 1)
    # s^2 = (4k^4 - 2k^2)/3  ->  4k^4 - 2k^2 - 3s^2 = 0
    k2 = (1.0 + math.sqrt(1.0 + 12.0 * float(s) ** 2)) / 4.0
    return max(2, round(math.sqrt(k2)))


def eq3_s_of_k(k: int) -> float:
    return k * (k * k - 2) / 2.0


def eq4_s_of_k(k: int) -> float:
    return math.sqrt(2.0 * k * k * (2.0 * k * k - 1.0) / 3.0)


def optimal_kd(s: int, depth: int) -> int:
    """Generalize Eq. 3/4's optimal-k to the (k, d) pair of the recursive
    N-level topology: with ``depth - 1`` grouping levels of branching
    factor k over s nodes, the levels balance (every comm, legions and
    super-legions alike, has ~k members) at ``k ≈ s^(1/depth)``. For
    depth 2 the paper's Eq. 3 relation is kept verbatim."""
    if depth <= 1:
        return max(s, 1)
    if depth == 2:
        return optimal_k_linear(s)
    if s <= 2:
        return max(s, 1)
    return max(2, round(s ** (1.0 / depth)))


RECOVERY_MODES = ("shrink", "substitute", "substitute_then_shrink",
                  "adaptive")


@dataclass(frozen=True)
class LegioPolicy:
    legion_size: int = 0                # k; 0 = auto via Eq. 3 (paper's setting)
    hierarchical_threshold: int = 12    # paper: hierarchy wins for s > 11 (linear S)
    # levels of the recursive topology including the root comm: 1 = flat,
    # 2 = the paper's legions + global_comm, d >= 3 inserts super-legion
    # levels (masters grouped k at a time) between legions and root.
    # 0 = auto: 2 in the paper's regime, one level deeper every time the
    # master comm itself outgrows hierarchical_threshold (the paper's own
    # rule applied recursively to the comm it creates).
    hierarchy_depth: int = 0
    root_failure_policy: str = "ignore" # ignore | stop (paper §IV)
    batch_policy: str = "drop"          # drop | rebalance
    straggler_threshold: float = 3.0    # x median step latency; 0 disables
    heartbeat_timeout: float = 10.0     # sim seconds
    grad_compression: str = "none"      # none | int8 | topk (cross-legion hop)
    topk_fraction: float = 0.05
    spare_nodes: int = 0                # standby pool for elastic regrow
    # --- substitution recovery (beyond-paper: Ashraf et al. "Shrink or
    # Substitute"): shrink discards capacity, substitute splices a warm
    # spare into the failed node's legion slot. substitute_then_shrink
    # falls back to shrink once the pool is exhausted; bare substitute
    # treats exhaustion as fatal (SparePoolExhausted).
    recovery_mode: str = "shrink"       # shrink | substitute |
                                        # substitute_then_shrink | adaptive
    spare_fraction: float = 0.0         # provision ceil(f * n) warm spares
    # --- adaptive recovery (CostModelStrategy): recovery_mode="adaptive"
    # scores shrink / substitute / nonblocking / restart-from-checkpoint per
    # fault from the engines' cost models plus per-stage pipeline latencies
    # fitted online (EWMA over FaultPipeline.traces, keyed by verdict size)
    # and dispatches the winner. adaptive_ewma_horizon is the EWMA window in
    # drains (alpha = 2/(h+1)); adaptive_horizon_steps amortizes a shrink's
    # lost capacity over the steps the run is expected to keep going.
    adaptive_ewma_horizon: int = 8
    adaptive_horizon_steps: int = 24
    # --- peer-replicated shard checkpoints (checkpoint.replicate): every
    # async checkpoint also pushes each member's host shard to its POV-ring
    # buddy, so a substituted spare warm-starts from the surviving buddy in
    # O(shard) — the store read remains the correlated-loss fallback.
    peer_replication: bool = True
    # non-blocking flavor (Bouteiller & Bosilca): after the fault step,
    # spare_warmup_steps steps run shrunk while the substitute warms up;
    # the topology then re-expands at the next step boundary.
    nonblocking_substitution: bool = False
    spare_warmup_steps: int = 1
    # --- background (overlapped) repair: revoke-then-repair. The structural
    # repair still lands inside the drain, but its clock charge is deferred
    # to a BackgroundRepair window — healthy subtrees keep issuing
    # collectives on their pinned epoch while the torn scope's survivors
    # stay busy (excluded from schedules) until the window's finish_sim
    # passes; membership reconciles at the next Session boundary. Applies
    # to every recovery_mode whose strategy declares overlap_safe.
    repair_overlap: bool = False
    # baseline simulated seconds charged per step — this is what makes the
    # heartbeat channel live: with no collective (final_collective="none")
    # the sim clock still advances, so a silent node eventually crosses
    # heartbeat_timeout and the pipeline's detect stage picks it up.
    step_sim_seconds: float = 1.0
    # --- elastic spare re-spawn (the MPI_Comm_spawn analogue): when the
    # warm pool drains below the watermark, the SpareProvisioner schedules
    # replacement spares that come up after a provisioning delay and feed
    # back through the SparePool. watermark=0 disables the provisioner.
    spare_refill_watermark: int = 0
    spare_provision_delay_steps: int = 2
    spare_churn_cap: int = 0            # max re-spawned spares; 0 = unlimited
    # --- serving (repro.serve): per-node micro-batch size drained from a
    # legion queue each round, and the redelivery ceiling for a request that
    # keeps landing on dying nodes (0 = retry forever; the at-least-once
    # guarantee holds either way — a request that hits the ceiling is parked
    # in ServeMetrics.parked, never silently dropped).
    serve_microbatch: int = 4
    serve_max_attempts: int = 0
    # --- continuous batching (repro.serve, PR 7): per-node in-flight window
    # (micro-batch slots a node works concurrently — admission refills a slot
    # the tick after its batch completes, per legion, independent of other
    # legions' progress or in-flight repairs), SLO-aware admission control
    # ("none" admits everything; "shed" rejects a request at the door when
    # the target legion's backlog already makes its deadline infeasible —
    # recorded in ServeMetrics.shed; "park" records it in .parked instead),
    # and decode-state migration (a request that dies mid-decode keeps its
    # decode progress on redelivery instead of restarting from prefill).
    serve_window: int = 1
    serve_admission: str = "none"        # none | shed | park
    serve_admission_slack: float = 0.0   # extra headroom (sim s) required
    serve_slo_seconds: float = 0.0       # default deadline; 0 = no deadline
    serve_migrate_decode: bool = True
    # --- correlated-failure scenarios (repro.core.faultmodel): knobs the
    # named presets read when generating seeded chaos campaigns.
    chaos_fault_fraction: float = 0.125  # independent: fraction of nodes hit
    chaos_partition_fence: bool = True   # fence the minority side of a split
    chaos_flap_delay_steps: int = 2      # steps between repair-out and return
    chaos_cascade_victims: int = 2       # secondary stragglers per cascade
    chaos_cascade_slowdown: float = 4.0  # latency multiplier on secondaries
    # --- data plane (repro.dist.dataplane): what actually moves the bytes
    # behind the scheduled collectives. "sim" keeps the numpy alpha-beta
    # simulator (the CI path — schedules and accounting bit-for-bit as
    # before); "jax" backs the data motion with real device collectives
    # (psum/ppermute under shard_map over a make_mesh device mesh) and runs
    # the compression hop on-device; "auto" picks jax when more than one
    # device is visible, sim otherwise. The control plane (schedules, stage
    # lists, alpha-beta clock charges) is backend-independent.
    data_plane: str = "sim"              # sim | jax | auto

    def __post_init__(self) -> None:
        if self.hierarchy_depth < 0:
            raise ValueError("hierarchy_depth must be >= 0 (0 = auto)")
        if self.recovery_mode not in RECOVERY_MODES:
            raise ValueError(
                f"recovery_mode must be one of {RECOVERY_MODES}, "
                f"got {self.recovery_mode!r}")
        if self.adaptive_ewma_horizon < 1:
            raise ValueError("adaptive_ewma_horizon must be >= 1")
        if self.adaptive_horizon_steps < 1:
            raise ValueError("adaptive_horizon_steps must be >= 1")
        if self.spare_refill_watermark < 0:
            raise ValueError("spare_refill_watermark must be >= 0")
        if self.spare_provision_delay_steps < 0:
            raise ValueError("spare_provision_delay_steps must be >= 0")
        if self.spare_churn_cap < 0:
            raise ValueError("spare_churn_cap must be >= 0")
        if self.serve_microbatch <= 0:
            raise ValueError("serve_microbatch must be positive")
        if self.serve_max_attempts < 0:
            raise ValueError("serve_max_attempts must be >= 0")
        if self.serve_window < 1:
            raise ValueError("serve_window must be >= 1")
        if self.serve_admission not in ("none", "shed", "park"):
            raise ValueError(
                "serve_admission must be one of ('none', 'shed', 'park'), "
                f"got {self.serve_admission!r}")
        if self.serve_admission_slack < 0:
            raise ValueError("serve_admission_slack must be >= 0")
        if self.serve_slo_seconds < 0:
            raise ValueError("serve_slo_seconds must be >= 0")
        if not 0.0 <= self.chaos_fault_fraction <= 1.0:
            raise ValueError("chaos_fault_fraction must be in [0, 1]")
        if self.chaos_flap_delay_steps < 1:
            raise ValueError("chaos_flap_delay_steps must be >= 1")
        if self.chaos_cascade_victims < 0:
            raise ValueError("chaos_cascade_victims must be >= 0")
        if self.chaos_cascade_slowdown <= 0:
            raise ValueError("chaos_cascade_slowdown must be positive")
        if self.data_plane not in ("sim", "jax", "auto"):
            raise ValueError(
                "data_plane must be one of ('sim', 'jax', 'auto'), "
                f"got {self.data_plane!r}")

    def choose_k(self, s: int) -> int:
        if self.legion_size > 0:
            return min(self.legion_size, s)
        return min(optimal_k_linear(s), s)

    def choose_depth(self, s: int) -> int:
        """How many levels the topology gets for an s-node cluster. Explicit
        ``hierarchy_depth`` wins; auto applies the paper's threshold rule
        recursively — whenever the comm of masters a level creates is itself
        big enough that hierarchy would win inside it, add a level."""
        if self.hierarchy_depth > 0:
            return self.hierarchy_depth if s > 1 else 1
        if not self.use_hierarchical(s):
            return 1
        k = max(self.choose_k(s), 2)
        depth, top = 2, math.ceil(s / k)
        while top > self.hierarchical_threshold:
            nxt = math.ceil(top / k)
            if nxt <= 1:
                break
            depth, top = depth + 1, nxt
        return depth

    def choose_kd(self, s: int) -> tuple[int, int]:
        """The (legion size, depth) pair the topology builder uses —
        Eq. 3's optimal-k generalized to the recursive layout. With an
        explicit ``legion_size`` the depth adapts around it; with both
        knobs on auto, depth is chosen first and k balances the levels
        (``optimal_kd``)."""
        depth = self.choose_depth(s)
        if depth <= 1:
            return max(s, 1), 1
        if self.legion_size > 0:
            return min(self.legion_size, s), depth
        return min(optimal_kd(s, depth), s), depth

    def use_hierarchical(self, s: int) -> bool:
        return s > self.hierarchical_threshold

    def spare_count(self, n_nodes: int) -> int:
        """Warm spares to provision for an n-node cluster: the larger of the
        absolute knob and the fractional one."""
        return max(self.spare_nodes,
                   math.ceil(self.spare_fraction * n_nodes))

    @property
    def substitution_enabled(self) -> bool:
        return self.recovery_mode != "shrink"

    @property
    def strategy_key(self) -> str:
        """Registry key of the RecoveryStrategy this policy composes
        (see :mod:`repro.core.strategy`). New strategies register under new
        keys; the ladder this replaces lived in ``VirtualCluster.repair``."""
        if self.recovery_mode == "adaptive":
            return "adaptive"
        if not self.substitution_enabled:
            return "shrink"
        if self.nonblocking_substitution:
            return "substitute_nonblocking"
        return "substitute"

    @property
    def elastic_spares(self) -> bool:
        return self.spare_refill_watermark > 0
