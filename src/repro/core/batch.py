"""Batch-shard reassignment — the rank-translation policy analogue (§IV).

When an MPI rank dies mid-operation Legio either IGNOREs the op or STOPs the
application. In data-parallel ML the per-rank artifact is the *batch shard*;
the corresponding policies are:

  DROP       — survivors keep their own shards; the failed node's shards are
               simply not computed this step. The gradient mean renormalizes
               over survivors (smaller batch, unbiased estimator — exactly
               the paper's Monte-Carlo "approximate result" trade-off).
  REBALANCE  — the failed node's shards are redistributed round-robin over
               survivors (exact batch, more work per survivor). Possible
               here because the data pipeline is counter-based: any node can
               regenerate any shard bit-exactly (see data/pipeline.py).

Assignments are pure data (no device state), so reassignment is O(shards).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.data.pipeline import ShardAssignment


@dataclass(frozen=True)
class BatchPlan:
    assignments: tuple[ShardAssignment, ...]
    dropped_shards: tuple[int, ...]
    policy: str

    @property
    def active_shards(self) -> int:
        return sum(len(a.shards) for a in self.assignments)

    def shards_of(self, node: int) -> tuple[int, ...]:
        for a in self.assignments:
            if a.node == node:
                return a.shards
        return ()


def initial_assignment(nodes: list[int], shards_per_node: int = 1) -> BatchPlan:
    """Node i owns shards [i*spn, (i+1)*spn) — the no-fault layout."""
    asg = tuple(
        ShardAssignment(node=n, shards=tuple(
            i * shards_per_node + j for j in range(shards_per_node)))
        for i, n in enumerate(sorted(nodes))
    )
    return BatchPlan(assignments=asg, dropped_shards=(), policy="initial")


def reassign(
    plan: BatchPlan,
    failed: set[int],
    policy: str,
) -> BatchPlan:
    """Apply DROP or REBALANCE after ``failed`` nodes left the cluster."""
    survivors = [a for a in plan.assignments if a.node not in failed]
    orphans: list[int] = sorted(
        s for a in plan.assignments if a.node in failed for s in a.shards
    )
    if policy == "drop" or not survivors:
        return BatchPlan(
            assignments=tuple(survivors),
            dropped_shards=tuple(sorted(set(plan.dropped_shards) | set(orphans))),
            policy="drop",
        )
    if policy == "rebalance":
        buckets: dict[int, list[int]] = {a.node: list(a.shards) for a in survivors}
        order = sorted(buckets, key=lambda n: (len(buckets[n]), n))
        for i, shard in enumerate(orphans):
            buckets[order[i % len(order)]].append(shard)
        return BatchPlan(
            assignments=tuple(
                ShardAssignment(node=n, shards=tuple(sorted(buckets[n])))
                for n in sorted(buckets)
            ),
            dropped_shards=plan.dropped_shards,
            policy="rebalance",
        )
    raise ValueError(f"unknown batch policy {policy!r}")


def substitute_assign(plan: BatchPlan, mapping: dict[int, int]) -> BatchPlan:
    """Blocking substitution: each failed node's shards move wholesale to
    its substitute (exact capacity restoration — the counter-based pipeline
    regenerates them bit-exactly on the new owner)."""
    if not mapping:
        return plan
    assignments = tuple(sorted(
        (ShardAssignment(node=mapping.get(a.node, a.node), shards=a.shards)
         for a in plan.assignments),
        key=lambda a: a.node,
    ))
    return BatchPlan(assignments=assignments,
                     dropped_shards=plan.dropped_shards,
                     policy="substitute")


def restore_rank(plan: BatchPlan, node: int,
                 shards: tuple[int, ...] | None = None) -> BatchPlan:
    """Non-blocking substitution, deferred half: return capacity to a
    restored rank. Under DROP the orphaned shards are sitting in
    ``dropped_shards`` — the restored node takes back *its* shards
    (``shards``, the failed slot's assignment at fault time; shards dropped
    for other, never-substituted failures stay dropped). Under REBALANCE
    nothing was dropped, so the restored node pulls shards back from the
    most-loaded survivors until the spread is <= 1 (the inverse of the
    round-robin handout)."""
    if any(a.node == node for a in plan.assignments):
        raise ValueError(f"node {node} already holds an assignment")
    pool = set(plan.dropped_shards)
    take = sorted(pool if shards is None else pool & set(shards))
    if take:
        assignments = plan.assignments + (
            ShardAssignment(node=node, shards=tuple(take)),)
        return BatchPlan(
            assignments=tuple(sorted(assignments, key=lambda a: a.node)),
            dropped_shards=tuple(sorted(pool - set(take))),
            policy="substitute",
        )
    buckets: dict[int, list[int]] = {a.node: list(a.shards)
                                     for a in plan.assignments}
    buckets[node] = []
    while True:
        donor = max(buckets, key=lambda n: (len(buckets[n]), -n))
        if len(buckets[donor]) - len(buckets[node]) <= 1 or donor == node:
            break
        buckets[node].append(buckets[donor].pop())
    return BatchPlan(
        assignments=tuple(
            ShardAssignment(node=n, shards=tuple(sorted(buckets[n])))
            for n in sorted(buckets)
        ),
        dropped_shards=plan.dropped_shards,   # unclaimed drops stay recorded
        policy="substitute",
    )


def validate_plan(plan: BatchPlan, view) -> None:
    """Cross-check a batch plan against a :class:`TopologyView` snapshot —
    the structural half of the epoch discipline: at the moment a collective
    reads the topology, every assigned node must exist in the snapshot and
    no shard may be double-assigned or simultaneously assigned and dropped.
    Raises ``ValueError`` on the first violation."""
    nodes = set(view.nodes)
    seen: set[int] = set()
    for a in plan.assignments:
        if a.node not in nodes:
            raise ValueError(
                f"plan assigns shards to node {a.node} which is not in the "
                f"topology snapshot (epoch {getattr(view, 'epoch', '?')})")
        dup = seen.intersection(a.shards)
        if dup:
            raise ValueError(f"shards {sorted(dup)} assigned twice")
        seen.update(a.shards)
    overlap = seen.intersection(plan.dropped_shards)
    if overlap:
        raise ValueError(
            f"shards {sorted(overlap)} both assigned and dropped")


def gradient_scale(plan: BatchPlan, total_shards: int) -> float:
    """Weight for the gradient mean so the estimator renormalizes over the
    shards actually computed (DROP shrinks the denominator)."""
    active = plan.active_shards
    if active == 0:
        return 0.0
    return float(total_shards) / float(active)
