"""Legion topology — the paper's hierarchical communicator organization (§V).

The target communicator (our cluster of nodes) is split into disjoint
``local_comm``s (*legions*) of max size ``k``: node with rank ``r`` belongs to
legion ``r // k`` — the assignment is final (paper: "The assignment of a
process to a local_comm is final"). A ``global_comm`` holds one *master* per
legion (the lowest surviving rank). Each legion also has a *POV*
(Partially-OVerlapped) communicator: its members plus the master of its
*successor* legion, used exclusively during repair (paper Fig. 2). The last
legion's successor is the first (a ring).

Properties the paper claims — each is asserted by property tests:
  (a) #communicators scales linearly with #nodes;
  (b) every node can reach any other (directly or via masters);
  (c) there is exactly one master-path between any two legions.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Iterator

from repro.core.policy import LegioPolicy


class TopologyTornError(RuntimeError):
    """Raised when a repair tries to mutate the topology while a
    :class:`TopologyView` is pinned — the invariant ULFM gets for free from
    ``MPIX_Comm_shrink``'s collectivity (every participant enters the repair,
    so no collective can be mid-flight on the old structure)."""


@dataclass
class Legion:
    """One local_comm: members are global node ids, sorted ascending."""
    index: int
    members: list[int]

    @property
    def master(self) -> int:
        """Paper: the master is the process with the lowest rank."""
        return min(self.members)

    def __len__(self) -> int:
        return len(self.members)


@dataclass
class LegionTopology:
    """The full hierarchical communicator structure over live nodes."""

    k: int                          # max legion size (paper knob)
    legions: list[Legion]
    # original (pre-fault) legion index per node — assignment is final
    home: dict[int, int] = field(default_factory=dict)
    # epoch counter: bumped by every structural mutation; collectives snapshot
    # the structure behind an epoch-stamped TopologyView and pin it, so a
    # mid-pipeline repair can never tear a structure a collective is reading
    epoch: int = 0
    _pins: int = field(default=0, init=False, repr=False)

    # ---- construction ----------------------------------------------------

    @staticmethod
    def build(nodes: list[int], k: int) -> "LegionTopology":
        nodes = sorted(nodes)
        if k <= 0:
            raise ValueError(f"legion size k must be positive, got {k}")
        legions = [
            Legion(index=i, members=nodes[i * k:(i + 1) * k])
            for i in range((len(nodes) + k - 1) // k)
        ]
        home = {n: i for i, lg in enumerate(legions) for n in lg.members}
        return LegionTopology(k=k, legions=legions, home=home)

    @staticmethod
    def flat(nodes: list[int]) -> "LegionTopology":
        """Degenerate single-legion topology (the non-hierarchical mode)."""
        nodes = sorted(nodes)
        lg = Legion(index=0, members=list(nodes))
        return LegionTopology(k=max(len(nodes), 1), legions=[lg],
                              home={n: 0 for n in nodes})

    # ---- views -------------------------------------------------------------

    @property
    def nodes(self) -> list[int]:
        return sorted(n for lg in self.legions for n in lg.members)

    @property
    def size(self) -> int:
        return sum(len(lg) for lg in self.legions)

    @property
    def n_legions(self) -> int:
        return len(self.legions)

    @property
    def masters(self) -> list[int]:
        """The global_comm membership."""
        return [lg.master for lg in self.legions if lg.members]

    def legion_of(self, node: int) -> Legion:
        for lg in self.legions:
            if node in lg.members:
                return lg
        raise KeyError(f"node {node} not in topology")

    def is_master(self, node: int) -> bool:
        return any(lg.members and lg.master == node for lg in self.legions)

    def successor(self, legion_index: int) -> Legion:
        order = [lg for lg in self.legions if lg.members]
        pos = next(i for i, lg in enumerate(order) if lg.index == legion_index)
        return order[(pos + 1) % len(order)]

    def predecessor(self, legion_index: int) -> Legion:
        order = [lg for lg in self.legions if lg.members]
        pos = next(i for i, lg in enumerate(order) if lg.index == legion_index)
        return order[(pos - 1) % len(order)]

    def pov(self, legion_index: int) -> list[int]:
        """POV_i = members of legion i + master of the successor (paper Fig. 2)."""
        lg = next(l for l in self.legions if l.index == legion_index)
        members = list(lg.members)
        succ = self.successor(legion_index)
        if succ.index != legion_index and succ.members:
            members.append(succ.master)
        return sorted(members)

    def povs(self) -> dict[int, list[int]]:
        return {lg.index: self.pov(lg.index) for lg in self.legions if lg.members}

    def n_communicators(self) -> int:
        """world + per-legion local_comm + per-legion POV + global  — O(n/k)·2+2,
        i.e. linear in the number of nodes (paper property (a))."""
        live = [lg for lg in self.legions if lg.members]
        return 1 + len(live) + len(live) + 1

    def path(self, src: int, dst: int) -> list[int]:
        """The unique minimal master-relay path (paper property (b)/(c)):
        src -> master(src) -> master(dst) -> dst, collapsing duplicates."""
        ls, ld = self.legion_of(src), self.legion_of(dst)
        hops = [src]
        if ls.index == ld.index:
            if dst != src:
                hops.append(dst)
            return hops
        for nxt in (ls.master, ld.master, dst):
            if hops[-1] != nxt:
                hops.append(nxt)
        return hops

    # ---- snapshots (epoch discipline) ---------------------------------------

    def view(self) -> "TopologyView":
        """Epoch-stamped immutable snapshot for collectives/batch consumers."""
        return TopologyView(self)

    @contextmanager
    def pinned(self) -> Iterator["TopologyView"]:
        """Snapshot AND pin: any mutation while the view is live raises
        :class:`TopologyTornError` instead of silently tearing the structure
        out from under the reader."""
        view = self.view()
        self._pins += 1
        try:
            yield view
        finally:
            self._pins -= 1

    def _mutating(self) -> None:
        if self._pins:
            raise TopologyTornError(
                f"topology mutation attempted while {self._pins} "
                f"TopologyView(s) are pinned at epoch {self.epoch}")
        self.epoch += 1

    # ---- mutation (repair) --------------------------------------------------

    def remove(self, node: int) -> tuple[int, bool]:
        """Exclude a failed node. Returns (legion index, was_master)."""
        lg = self.legion_of(node)
        self._mutating()
        was_master = lg.master == node
        lg.members.remove(node)
        return lg.index, was_master

    def compact(self) -> None:
        """Drop empty legions (a legion that lost all members leaves the ring)."""
        if any(not lg.members for lg in self.legions):
            self._mutating()
            self.legions = [lg for lg in self.legions if lg.members]

    def substitute(self, failed: int, spare: int) -> int:
        """Splice ``spare`` into ``failed``'s legion slot. Returns the legion
        index. Preserves the paper's invariants: the spare's assignment is
        final (recorded in ``home``), legion count is unchanged (so the POV
        ring and master-path structure are untouched), and the master stays
        the lowest surviving rank — spare ids are allocated above every
        initial node id, so a substitution never demotes a survivor."""
        if spare in self.home:
            raise ValueError(f"spare {spare} already belongs to legion "
                             f"{self.home[spare]} — assignment is final")
        lg = self.legion_of(failed)
        self._mutating()
        lg.members.remove(failed)
        lg.members.append(spare)
        lg.members.sort()
        self.home[spare] = lg.index
        return lg.index

    def expand(self, legion_index: int, node: int) -> None:
        """Re-admit a slot at ``legion_index`` for ``node`` (the deferred half
        of a non-blocking substitution). If the legion left the ring when it
        emptied, it rejoins at its original position — index order is ring
        order, so the POV ring stays consistent."""
        if node in self.home:
            raise ValueError(f"node {node} already belongs to legion "
                             f"{self.home[node]} — assignment is final")
        self._mutating()
        for lg in self.legions:
            if lg.index == legion_index:
                lg.members.append(node)
                lg.members.sort()
                break
        else:
            lg = Legion(index=legion_index, members=[node])
            pos = next((i for i, other in enumerate(self.legions)
                        if other.index > legion_index), len(self.legions))
            self.legions.insert(pos, lg)
        self.home[node] = legion_index


class TopologyView:
    """Read-only, epoch-stamped snapshot of a :class:`LegionTopology`.

    Collectives and batch planning read from a view, never the live
    topology: the snapshot is deep-copied at construction, so even if the
    pin discipline were bypassed the reader's structure could not change
    underneath it. Mutators are not exposed.
    """

    _MUTATORS = frozenset({"remove", "compact", "substitute", "expand",
                           "view", "pinned"})

    def __init__(self, topo: LegionTopology):
        self.epoch = topo.epoch
        self._snap = LegionTopology(
            k=topo.k,
            legions=[Legion(index=lg.index, members=list(lg.members))
                     for lg in topo.legions],
            home=dict(topo.home),
            epoch=topo.epoch,
        )

    def __getattr__(self, name: str):
        if name == "_snap":          # guard recursion during unpickling/init
            raise AttributeError(name)
        if name in TopologyView._MUTATORS:
            raise TypeError(f"TopologyView is read-only: {name}() is not "
                            f"available on a snapshot")
        return getattr(self._snap, name)

    @property
    def node_set(self) -> frozenset[int]:
        return frozenset(self._snap.nodes)

    def __repr__(self) -> str:
        return (f"TopologyView(epoch={self.epoch}, size={self._snap.size}, "
                f"legions={self._snap.n_legions})")


def make_topology(nodes: list[int], policy: LegioPolicy) -> LegionTopology:
    """Paper-faithful entry point: hierarchical iff size > threshold (s > 11)."""
    s = len(nodes)
    if policy.use_hierarchical(s):
        return LegionTopology.build(nodes, policy.choose_k(s))
    return LegionTopology.flat(nodes)
