"""Legion topology — the paper's hierarchical communicator organization (§V),
generalized from the fixed {flat, 2-level} pair to a recursive N-level tree.

The target communicator (our cluster of nodes) is split into disjoint
``local_comm``s (*legions*) of max size ``k``: node with rank ``r`` belongs to
legion ``r // k`` — the assignment is final (paper: "The assignment of a
process to a local_comm is final"). Above level 0 the structure recurses:
the masters of every ``k`` adjacent legions form a *super-legion* at level 1,
the masters of every ``k`` super-legions form a level-2 group, and so on,
until a single root comm closes the tree at level ``depth - 1``. Each
non-root level has a POV (Partially-OVerlapped) ring: group *i*'s POV is its
members plus the master of its *successor* group at the same level, used
exclusively during repair (paper Fig. 2, applied per level). ``depth == 2``
is exactly the paper's layout (legions + one global_comm of masters);
``depth == 1`` is the degenerate flat mode.

Grouping above level 0 is derived from the *final* legion indices
(legion ``i`` lives under level-ℓ group ``i // k**ℓ``), so the paper's
assignment-finality extends to every level: repairs never migrate a subtree.

Properties the paper claims — each is asserted by property tests, now at
every depth:
  (a) #communicators scales linearly with #nodes;
  (b) every node can reach any other (directly or via its master chain);
  (c) there is exactly one master-path between any two nodes.

Scoped repair (Rocco & Palermo 2022): a fault only forces the repair of the
communicators that actually contain it — :meth:`LegionTopology.fault_groups`
computes that minimal set by climbing the failed node's mastership chain,
and :meth:`LegionTopology.partition_scopes` folds an agreed verdict into
disjoint :class:`~repro.core.types.RepairScope`\\ s whose repairs can proceed
concurrently (disjoint participant sets — healthy subtrees never enter the
repair path).
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field, replace
from typing import Iterator

import numpy as np

from repro.core.policy import LegioPolicy
from repro.core.types import RepairScope


class TopologyTornError(RuntimeError):
    """Raised when a repair tries to mutate the topology while a
    :class:`TopologyView` is pinned — the invariant ULFM gets for free from
    ``MPIX_Comm_shrink``'s collectivity (every participant enters the repair,
    so no collective can be mid-flight on the old structure)."""


class StaleLegionError(KeyError):
    """A group index that no longer names a live group at its level —
    typically a legion that emptied and left the ring. Raised by
    ``successor``/``predecessor``/``pov`` (and their ``*_at`` generalizations)
    instead of leaking a bare ``StopIteration`` from an internal search."""


@dataclass
class Legion:
    """One local_comm: members are global node ids, sorted ascending."""
    index: int
    members: list[int]

    @property
    def master(self) -> int:
        """Paper: the master is the process with the lowest rank."""
        return min(self.members)

    def __len__(self) -> int:
        return len(self.members)


@dataclass(frozen=True)
class LevelGroup:
    """One communicator of the recursive hierarchy at ``level``.

    At level 0 this is a live legion viewed as a group (members are node
    ids, no children). At level ℓ ≥ 1 the members are the masters of the
    child groups at level ℓ-1 and ``children`` carries those groups'
    indices. The top level (``depth - 1``) is a single root group — the
    generalization of the paper's global_comm.
    """

    level: int
    index: int
    members: tuple[int, ...]
    children: tuple[int, ...] = ()

    @property
    def master(self) -> int:
        """Lowest rank of the subtree (min of mins) — the paper's rule."""
        return min(self.members)

    def __len__(self) -> int:
        return len(self.members)


@dataclass
class LegionTopology:
    """The full hierarchical communicator structure over live nodes."""

    k: int                          # max legion size (paper knob)
    legions: list[Legion]
    # original (pre-fault) legion index per node — assignment is final
    home: dict[int, int] = field(default_factory=dict)
    # epoch counter: bumped by every structural mutation; collectives snapshot
    # the structure behind an epoch-stamped TopologyView and pin it, so a
    # mid-pipeline repair can never tear a structure a collective is reading
    epoch: int = 0
    # number of levels including the root comm: 1 = flat, 2 = the paper's
    # legions + global_comm, d >= 3 adds super-legion levels in between
    depth: int = 2
    _pins: int = field(default=0, init=False, repr=False)
    # member -> Legion index kept coherent across every mutation: legion_of
    # is on the serve router's and collectives' hot path (O(1), not a scan)
    _by_member: dict[int, Legion] = field(
        default_factory=dict, init=False, repr=False, compare=False)
    _levels_cache: list[list[LevelGroup]] = field(
        default_factory=list, init=False, repr=False, compare=False)
    _levels_epoch: int = field(default=-1, init=False, repr=False,
                               compare=False)
    # scoped-repair index tables (ring order, parent pointers, numpy member
    # arrays), rebuilt lazily per epoch — see _scope_tables
    _scope_cache: list[dict] = field(
        default_factory=list, init=False, repr=False, compare=False)
    _scope_epoch: int = field(default=-1, init=False, repr=False,
                              compare=False)

    def __post_init__(self) -> None:
        self._reindex()

    def _reindex(self) -> None:
        self._by_member = {n: lg for lg in self.legions for n in lg.members}

    # ---- construction ----------------------------------------------------

    @staticmethod
    def build(nodes: list[int], k: int, depth: int = 2) -> "LegionTopology":
        nodes = sorted(nodes)
        if k <= 0:
            raise ValueError(f"legion size k must be positive, got {k}")
        if depth < 1:
            raise ValueError(f"hierarchy depth must be >= 1, got {depth}")
        if depth == 1:
            return LegionTopology.flat(nodes)
        legions = [
            Legion(index=i, members=nodes[i * k:(i + 1) * k])
            for i in range((len(nodes) + k - 1) // k)
        ]
        home = {n: i for i, lg in enumerate(legions) for n in lg.members}
        return LegionTopology(k=k, legions=legions, home=home, depth=depth)

    @staticmethod
    def flat(nodes: list[int]) -> "LegionTopology":
        """Degenerate single-legion topology (the non-hierarchical mode)."""
        nodes = sorted(nodes)
        lg = Legion(index=0, members=list(nodes))
        return LegionTopology(k=max(len(nodes), 1), legions=[lg],
                              home={n: 0 for n in nodes}, depth=1)

    # ---- views -------------------------------------------------------------

    @property
    def nodes(self) -> list[int]:
        return sorted(n for lg in self.legions for n in lg.members)

    @property
    def size(self) -> int:
        return sum(len(lg) for lg in self.legions)

    @property
    def n_legions(self) -> int:
        return len(self.legions)

    @property
    def masters(self) -> list[int]:
        """The level-1 comm membership (one master per live legion)."""
        return [lg.master for lg in self.legions if lg.members]

    def legion_of(self, node: int) -> Legion:
        try:
            return self._by_member[node]
        except KeyError:
            raise KeyError(f"node {node} not in topology") from None

    def is_master(self, node: int) -> bool:
        lg = self._by_member.get(node)
        return lg is not None and lg.master == node

    # ---- recursive levels ----------------------------------------------------

    def levels(self) -> list[list[LevelGroup]]:
        """Live groups at levels ``1 .. depth-1`` (index 0 of the returned
        list is level 1; the last entry is the single-group root comm).
        Derived from the level-0 structure on demand and cached per epoch,
        so mutations only ever touch the legions and the derivation can
        never drift out of sync."""
        if self._levels_epoch == self.epoch:
            return self._levels_cache
        out: list[list[LevelGroup]] = []
        child_index = [lg.index for lg in self.legions if lg.members]
        child_master = {lg.index: lg.master
                        for lg in self.legions if lg.members}
        for level in range(1, self.depth):
            buckets: dict[int, list[int]] = {}
            if level == self.depth - 1:
                # root comm: one group over every surviving child master
                buckets[0] = list(child_index)
            else:
                for ci in child_index:
                    buckets.setdefault(ci // self.k, []).append(ci)
            groups = [
                LevelGroup(
                    level=level, index=gi,
                    members=tuple(sorted(child_master[ci] for ci in children)),
                    children=tuple(sorted(children)))
                for gi, children in sorted(buckets.items())
            ]
            out.append(groups)
            child_index = [g.index for g in groups]
            child_master = {g.index: g.master for g in groups}
        self._levels_cache, self._levels_epoch = out, self.epoch
        return out

    def groups(self, level: int) -> list[LevelGroup]:
        """Live groups at ``level`` (0 = legions wrapped as groups)."""
        if level == 0:
            return [LevelGroup(level=0, index=lg.index,
                               members=tuple(lg.members))
                    for lg in self.legions if lg.members]
        lv = self.levels()
        if not 1 <= level <= len(lv):
            raise StaleLegionError(
                f"level {level} does not exist (depth {self.depth})")
        return lv[level - 1]

    def group_at(self, level: int, index: int) -> LevelGroup:
        for g in self.groups(level):
            if g.index == index:
                return g
        raise StaleLegionError(
            f"no live group {index} at level {level} "
            f"(depth {self.depth}, epoch {self.epoch})")

    def parent_of(self, level: int, index: int) -> LevelGroup:
        """The level+1 group containing group ``index`` of ``level``."""
        for g in self.groups(level + 1):
            if index in g.children:
                return g
        raise StaleLegionError(
            f"group {index} at level {level} has no parent "
            f"(depth {self.depth}, epoch {self.epoch})")

    def master_chain(self, node: int) -> list[int]:
        """The node's masters at levels 0..depth-1 (legion master first,
        root master last) — the unique relay chain of property (b)."""
        lg = self.legion_of(node)
        chain, idx = [lg.master], lg.index
        for groups in self.levels():
            g = next((g for g in groups if idx in g.children), None)
            if g is None:
                raise StaleLegionError(
                    f"group {idx} lost its parent (epoch {self.epoch})")
            chain.append(g.master)
            idx = g.index
        return chain

    def subtree_of(self, legion_index: int) -> int:
        """Index of the top-level subtree (child group of the root comm)
        containing the legion — what the serve router shards over. For
        depth <= 2 every legion hangs off the root directly."""
        if self.depth <= 2:
            return legion_index
        idx = legion_index
        for groups in self.levels()[:-1]:       # exclude the root comm
            g = next((g for g in groups if idx in g.children), None)
            if g is None:
                raise StaleLegionError(
                    f"legion {legion_index} not under any live subtree "
                    f"(epoch {self.epoch})")
            idx = g.index
        return idx

    # ---- per-level POV rings --------------------------------------------------

    def comm_name(self, level: int, index: int) -> str:
        """Canonical name of a group comm — the single source for the
        strings repair steps and collective stages are keyed on
        (``local_i`` / ``l{level}_{i}`` / ``global`` for the root)."""
        if level == 0:
            return f"local_{index}"
        if level == self.depth - 1:
            return "global"
        return f"l{level}_{index}"

    def pov_name(self, level: int, index: int) -> str:
        """Canonical name of a POV comm (``pov_i`` / ``l{level}_pov_{i}``)."""
        return f"pov_{index}" if level == 0 else f"l{level}_pov_{index}"

    def successor_at(self, level: int, index: int) -> LevelGroup:
        order = self.groups(level)
        for i, g in enumerate(order):
            if g.index == index:
                return order[(i + 1) % len(order)]
        raise StaleLegionError(
            f"group {index} at level {level} is not in the ring "
            f"(emptied or never existed; epoch {self.epoch})")

    def predecessor_at(self, level: int, index: int) -> LevelGroup:
        order = self.groups(level)
        for i, g in enumerate(order):
            if g.index == index:
                return order[(i - 1) % len(order)]
        raise StaleLegionError(
            f"group {index} at level {level} is not in the ring "
            f"(emptied or never existed; epoch {self.epoch})")

    def pov_at(self, level: int, index: int) -> list[int]:
        """POV of group ``index`` at ``level``: its members plus the master
        of the successor group at the same level (paper Fig. 2, per level)."""
        g = self.group_at(level, index)
        members = list(g.members)
        succ = self.successor_at(level, index)
        if succ.index != index and succ.members:
            members.append(succ.master)
        return sorted(members)

    def successor(self, legion_index: int) -> Legion:
        order = [lg for lg in self.legions if lg.members]
        for i, lg in enumerate(order):
            if lg.index == legion_index:
                return order[(i + 1) % len(order)]
        raise StaleLegionError(
            f"legion {legion_index} is not in the ring "
            f"(emptied or never existed; epoch {self.epoch})")

    def predecessor(self, legion_index: int) -> Legion:
        order = [lg for lg in self.legions if lg.members]
        for i, lg in enumerate(order):
            if lg.index == legion_index:
                return order[(i - 1) % len(order)]
        raise StaleLegionError(
            f"legion {legion_index} is not in the ring "
            f"(emptied or never existed; epoch {self.epoch})")

    def pov(self, legion_index: int) -> list[int]:
        """POV_i = members of legion i + master of the successor (paper Fig. 2)."""
        return self.pov_at(0, legion_index)

    def povs(self) -> dict[int, list[int]]:
        return {lg.index: self.pov(lg.index) for lg in self.legions if lg.members}

    def buddy_of(self, node: int) -> int | None:
        """Replica buddy of ``node`` on the level-0 POV ring.

        ``pov()`` already links every legion to the master of its successor;
        the replica ring generalises that one edge to all members: the j-th
        member of a legion is paired with the ``j mod |succ|``-th member of
        the successor legion. Members are sorted ascending and the master is
        the minimum, so the master's buddy is exactly the successor master
        the POV comm names. Returns ``None`` when no out-of-legion buddy
        exists (single surviving legion) — a whole-legion loss then has no
        surviving replica holder and restores fall back to the store.
        """
        lg = self.legion_of(node)
        succ = self.successor(lg.index)
        if succ.index == lg.index or not succ.members:
            return None
        pos = lg.members.index(node)
        return succ.members[pos % len(succ.members)]

    def n_communicators(self) -> int:
        """world + per-group comm + per-group POV at every ring level + the
        root comm. Every level has at most ceil(n / k^(level+1)) groups, so
        the total stays linear in the number of nodes (paper property (a))."""
        total = 2                               # world + root comm
        for level in range(max(self.depth - 1, 1)):
            total += 2 * len(self.groups(level))
        return total

    def path(self, src: int, dst: int) -> list[int]:
        """The unique minimal master-relay path (paper property (b)/(c)):
        climb src's master chain to the lowest level whose group contains
        both endpoints, hop across that comm, descend dst's chain. For
        depth 2 this is exactly src -> master(src) -> master(dst) -> dst,
        collapsing duplicates."""
        ls, ld = self.legion_of(src), self.legion_of(dst)
        hops = [src]
        if ls.index == ld.index:
            if dst != src:
                hops.append(dst)
            return hops
        # group-index chains at levels 0..depth-1 (root shared by construction)
        gs, gd = [ls.index], [ld.index]
        for groups in self.levels():
            gs.append(next(g.index for g in groups if gs[-1] in g.children))
            gd.append(next(g.index for g in groups if gd[-1] in g.children))
        meet = next(i for i in range(len(gs)) if gs[i] == gd[i])
        chain_s, chain_d = self.master_chain(src), self.master_chain(dst)
        for nxt in chain_s[:meet] + list(reversed(chain_d[:meet])) + [dst]:
            if hops[-1] != nxt:
                hops.append(nxt)
        return hops

    # ---- scoped repair (Rocco & Palermo: confine repair to the fault) --------

    def _scope_tables(self) -> list[dict]:
        """Per-epoch index tables for the scoped-repair hot path: ring
        order, position, parent pointers, masters, and numpy member arrays
        per ``(level, group)``. Campaign-scale injection (10^4 chaos
        campaigns) made the per-call O(n) scans in ``fault_groups`` /
        ``partition_scopes`` dominate; one O(n) build per topology epoch
        amortizes every later lookup to O(1)."""
        if self._scope_epoch == self.epoch:
            return self._scope_cache
        per_level = [self.groups(0)] + self.levels()
        tables: list[dict] = []
        for lvl_groups in per_level:
            order = [g.index for g in lvl_groups]
            tables.append({
                "order": order,
                "pos": {gi: i for i, gi in enumerate(order)},
                # members are kept sorted by every mutator, so [0] is the
                # master (lowest rank) without a min() scan
                "members": {g.index: np.asarray(g.members, dtype=np.int64)
                            for g in lvl_groups},
                "master": {g.index: g.members[0]
                           for g in lvl_groups if g.members},
                "parent": {},
            })
        for lvl, lvl_groups in enumerate(per_level[1:], start=1):
            for g in lvl_groups:
                for ci in g.children:
                    tables[lvl - 1]["parent"][ci] = g.index
        self._scope_cache, self._scope_epoch = tables, self.epoch
        return tables

    def fault_groups(self, node: int) -> set[tuple[int, int]]:
        """The minimal set of ``(level, group index)`` comms whose repair the
        failure of ``node`` forces. A worker fault touches only its legion;
        a master fault adds the level-0 ring neighbours' POVs and the parent
        comm, and keeps climbing exactly as long as the dead node also held
        the mastership of the group above.

        O(depth) per call against the per-epoch :meth:`_scope_tables`
        (``_fault_groups_reference`` is the retained O(n) original the
        property tests diff against)."""
        lg = self.legion_of(node)
        touched = {(0, lg.index)}
        if self.depth <= 1:
            return touched
        tables = self._scope_tables()
        if len(tables[0]["order"]) <= 1:
            return touched
        level, idx, master = 0, lg.index, lg.members[0]
        while master == node and level < self.depth - 1:
            tab = tables[level]
            order = tab["order"]
            if len(order) > 1:
                i = tab["pos"][idx]
                touched.add((level, order[(i - 1) % len(order)]))
                touched.add((level, order[(i + 1) % len(order)]))
            parent_idx = tab["parent"].get(idx)
            if parent_idx is None:
                raise StaleLegionError(
                    f"group {idx} at level {level} has no parent "
                    f"(depth {self.depth}, epoch {self.epoch})")
            touched.add((level + 1, parent_idx))
            level, idx = level + 1, parent_idx
            master = tables[level]["master"][idx]
        return touched

    def _fault_groups_reference(self, node: int) -> set[tuple[int, int]]:
        """Pre-vectorization implementation (per-member Python scans),
        kept as the oracle for the byte-identical-output property tests."""
        lg = self.legion_of(node)
        touched = {(0, lg.index)}
        if self.depth <= 1 or len(self.masters) <= 1:
            return touched
        level, idx, master = 0, lg.index, lg.master
        while master == node and level < self.depth - 1:
            ring = self.groups(level)
            if len(ring) > 1:
                touched.add((level, self.predecessor_at(level, idx).index))
                touched.add((level, self.successor_at(level, idx).index))
            parent = self.parent_of(level, idx)
            touched.add((level + 1, parent.index))
            level, idx, master = level + 1, parent.index, parent.master
        return touched

    def partition_scopes(self, verdict: set[int]) -> list[RepairScope]:
        """Fold an agreed verdict into disjoint :class:`RepairScope`\\ s.
        Scopes whose touched comms intersect are merged (their repairs share
        participants and must serialize); the rest are disjoint subtrees
        that repair concurrently. Verdict nodes no longer in the topology
        (a spare that died warm, a node a previous drain already removed)
        ride along on the first scope so the one-terminal-action-per-fault
        invariant holds for them too.

        Vectorized: participant sets are numpy index arrays unioned with
        ``np.unique``/``np.concatenate``, and the transitive merge is a
        union-find keyed on claimed participants/groups — same equivalence
        classes as the reference fixpoint (two scopes merge iff they share
        a participant or a comm), emitted in the same order (each class is
        represented by its earliest component, and components are created
        in ascending verdict order). ``_partition_scopes_reference`` is the
        retained original; tests assert byte-identical output."""
        present = [n for n in sorted(verdict)
                   if n in self.home and n in self._by_member]
        absent = sorted(set(verdict) - set(present))
        tables = self._scope_tables()
        components: list[tuple[int, set[tuple[int, int]], np.ndarray]] = []
        for n in present:
            groups = self.fault_groups(n)
            arrs = [tables[lvl]["members"][gi] for lvl, gi in groups]
            parts = (np.unique(np.concatenate(arrs)) if arrs
                     else np.empty(0, dtype=np.int64))
            components.append((n, groups, parts))
        # union-find over shared claims — merge on PARTICIPANT overlap, not
        # just shared comms: a node that must enter two repairs (e.g. a
        # legion master pulled into both its local shrink and a neighbour's
        # root-comm shrink at depth 2) serializes them — only truly
        # participant-disjoint scopes may claim concurrency
        root = list(range(len(components)))

        def find(i: int) -> int:
            while root[i] != i:
                root[i] = root[root[i]]
                i = root[i]
            return i

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                # the earlier component absorbs the later one, matching the
                # reference fixpoint's output order
                if rb < ra:
                    ra, rb = rb, ra
                root[rb] = ra

        claimed_group: dict[tuple[int, int], int] = {}
        claimed_part: dict[int, int] = {}
        for i, (_, groups, parts) in enumerate(components):
            for g in groups:
                owner = claimed_group.setdefault(g, i)
                if owner != i:
                    union(i, owner)
            for p in parts.tolist():
                owner = claimed_part.setdefault(p, i)
                if owner != i:
                    union(i, owner)
        merged: dict[int, tuple[set[int], set[tuple[int, int]],
                                list[np.ndarray]]] = {}
        order: list[int] = []
        for i, (n, groups, parts) in enumerate(components):
            r = find(i)
            if r not in merged:
                merged[r] = (set(), set(), [])
                order.append(r)
            m_nodes, m_groups, m_parts = merged[r]
            m_nodes.add(n)
            m_groups |= groups
            m_parts.append(parts)
        verdict_arr = np.asarray(sorted(verdict), dtype=np.int64)
        scopes = []
        for r in order:
            nodes, groups, part_arrs = merged[r]
            parts = np.unique(np.concatenate(part_arrs))
            parts = parts[~np.isin(parts, verdict_arr)]
            scopes.append(RepairScope(
                verdict=tuple(sorted(nodes)),
                level=max(lvl for lvl, _ in groups),
                groups=tuple(sorted(groups)),
                participants=tuple(int(p) for p in parts)))
        if absent:
            if scopes:
                scopes[0] = replace(scopes[0], verdict=tuple(
                    sorted(set(scopes[0].verdict) | set(absent))))
            else:
                scopes = [RepairScope(verdict=tuple(absent), level=0,
                                      groups=(), participants=())]
        return scopes

    def _partition_scopes_reference(self, verdict: set[int]
                                    ) -> list[RepairScope]:
        """Pre-vectorization implementation (set fixpoint over per-member
        scans), kept as the oracle for the byte-identical-output tests."""
        present = [n for n in sorted(verdict)
                   if n in self.home and n in self._by_member]
        absent = sorted(set(verdict) - set(present))
        components: list[tuple[set[int], set[tuple[int, int]], set[int]]] = []
        for n in present:
            groups = set(self._fault_groups_reference(n))
            participants: set[int] = set()
            for lvl, gi in groups:
                participants.update(self.group_at(lvl, gi).members)
            components.append(({n}, groups, participants))
        changed = len(components) > 1
        while changed:                  # transitive closure of the merge
            changed = False
            merged: list[tuple[set[int], set[tuple[int, int]], set[int]]] = []
            for nodes, groups, parts in components:
                for i, (m_nodes, m_groups, m_parts) in enumerate(merged):
                    if (m_parts & parts) or (m_groups & groups):
                        merged[i] = (m_nodes | nodes, m_groups | groups,
                                     m_parts | parts)
                        changed = True
                        break
                else:
                    merged.append((nodes, groups, parts))
            components = merged
        scopes = []
        for nodes, groups, participants in components:
            participants -= set(verdict)
            scopes.append(RepairScope(
                verdict=tuple(sorted(nodes)),
                level=max(lvl for lvl, _ in groups),
                groups=tuple(sorted(groups)),
                participants=tuple(sorted(participants))))
        if absent:
            if scopes:
                scopes[0] = replace(scopes[0], verdict=tuple(
                    sorted(set(scopes[0].verdict) | set(absent))))
            else:
                scopes = [RepairScope(verdict=tuple(absent), level=0,
                                      groups=(), participants=())]
        return scopes

    # ---- snapshots (epoch discipline) ---------------------------------------

    def view(self) -> "TopologyView":
        """Epoch-stamped immutable snapshot for collectives/batch consumers."""
        return TopologyView(self)

    @contextmanager
    def pinned(self) -> Iterator["TopologyView"]:
        """Snapshot AND pin: any mutation while the view is live raises
        :class:`TopologyTornError` instead of silently tearing the structure
        out from under the reader."""
        view = self.view()
        self._pins += 1
        try:
            yield view
        finally:
            self._pins -= 1

    def _mutating(self) -> None:
        if self._pins:
            raise TopologyTornError(
                f"topology mutation attempted while {self._pins} "
                f"TopologyView(s) are pinned at epoch {self.epoch}")
        self.epoch += 1

    # ---- mutation (repair) --------------------------------------------------

    def remove(self, node: int) -> tuple[int, bool]:
        """Exclude a failed node. Returns (legion index, was_master)."""
        lg = self.legion_of(node)
        self._mutating()
        was_master = lg.master == node
        lg.members.remove(node)
        del self._by_member[node]
        return lg.index, was_master

    def compact(self) -> None:
        """Drop empty legions (a legion that lost all members leaves the ring)."""
        if any(not lg.members for lg in self.legions):
            self._mutating()
            self.legions = [lg for lg in self.legions if lg.members]

    def substitute(self, failed: int, spare: int) -> int:
        """Splice ``spare`` into ``failed``'s legion slot. Returns the legion
        index. Preserves the paper's invariants: the spare's assignment is
        final (recorded in ``home``), legion count is unchanged (so the POV
        ring and master-path structure are untouched), and the master stays
        the lowest surviving rank — spare ids are allocated above every
        initial node id, so a substitution never demotes a survivor."""
        if spare in self.home:
            raise ValueError(f"spare {spare} already belongs to legion "
                             f"{self.home[spare]} — assignment is final")
        lg = self.legion_of(failed)
        self._mutating()
        lg.members.remove(failed)
        lg.members.append(spare)
        lg.members.sort()
        del self._by_member[failed]
        self._by_member[spare] = lg
        self.home[spare] = lg.index
        return lg.index

    def expand(self, legion_index: int, node: int) -> None:
        """Re-admit a slot at ``legion_index`` for ``node`` (the deferred half
        of a non-blocking substitution). If the legion left the ring when it
        emptied, it rejoins at its original position — index order is ring
        order at every level, so the POV rings stay consistent."""
        if node in self.home:
            raise ValueError(f"node {node} already belongs to legion "
                             f"{self.home[node]} — assignment is final")
        self._mutating()
        for lg in self.legions:
            if lg.index == legion_index:
                lg.members.append(node)
                lg.members.sort()
                break
        else:
            lg = Legion(index=legion_index, members=[node])
            pos = next((i for i, other in enumerate(self.legions)
                        if other.index > legion_index), len(self.legions))
            self.legions.insert(pos, lg)
        self._by_member[node] = lg
        self.home[node] = legion_index


class TopologyView:
    """Read-only, epoch-stamped snapshot of a :class:`LegionTopology`.

    Collectives and batch planning read from a view, never the live
    topology: the snapshot is deep-copied at construction, so even if the
    pin discipline were bypassed the reader's structure could not change
    underneath it. Mutators are not exposed.
    """

    _MUTATORS = frozenset({"remove", "compact", "substitute", "expand",
                           "view", "pinned"})

    def __init__(self, topo: LegionTopology):
        self.epoch = topo.epoch
        self._snap = LegionTopology(
            k=topo.k,
            legions=[Legion(index=lg.index, members=list(lg.members))
                     for lg in topo.legions],
            home=dict(topo.home),
            epoch=topo.epoch,
            depth=topo.depth,
        )

    def __getattr__(self, name: str):
        if name == "_snap":          # guard recursion during unpickling/init
            raise AttributeError(name)
        if name in TopologyView._MUTATORS:
            raise TypeError(f"TopologyView is read-only: {name}() is not "
                            f"available on a snapshot")
        return getattr(self._snap, name)

    @property
    def node_set(self) -> frozenset[int]:
        return frozenset(self._snap.nodes)

    def restrict(self, exclude: "frozenset[int] | set[int]") -> "TopologyView":
        """A structure-preserving sub-view with ``exclude`` members filtered
        out — the healthy-subtree schedule during a background repair
        window. Unlike a ``make_topology`` rebuild this keeps the original
        legion indices, depth, and **epoch stamp** (per-subtree epoch
        pinning: the survivors' collectives run on the same pinned epoch
        they would without the repair, so excluding a busy scope never
        repartitions the healthy subtrees or changes their alpha-beta
        stage structure). A legion whose members are all busy steps out of
        the ring for the window, exactly as if it had compacted away —
        temporarily, on the view only; the live topology is untouched."""
        busy = self.node_set & frozenset(exclude)
        if not busy:
            return self
        legions = [Legion(index=lg.index,
                          members=[m for m in lg.members if m not in busy])
                   for lg in self._snap.legions]
        view = TopologyView.__new__(TopologyView)
        view.epoch = self.epoch
        view._snap = LegionTopology(
            k=self._snap.k,
            legions=[lg for lg in legions if lg.members],
            home={n: i for n, i in self._snap.home.items() if n not in busy},
            epoch=self._snap.epoch,
            depth=self._snap.depth,
        )
        return view

    def __repr__(self) -> str:
        return (f"TopologyView(epoch={self.epoch}, size={self._snap.size}, "
                f"legions={self._snap.n_legions}, depth={self._snap.depth})")


def make_topology(nodes: list[int], policy: LegioPolicy) -> LegionTopology:
    """Paper-faithful entry point: hierarchical iff size > threshold
    (s > 11), with the depth chosen by ``policy.choose_kd`` — 2 levels in
    the paper's regime, deeper once the master comm itself outgrows the
    threshold (or whatever ``policy.hierarchy_depth`` pins)."""
    s = len(nodes)
    k, depth = policy.choose_kd(s)
    if depth <= 1:
        return LegionTopology.flat(nodes)
    return LegionTopology.build(nodes, k, depth=depth)
