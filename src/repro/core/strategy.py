"""RecoveryStrategy layer — pluggable repair behaviors behind one seam.

Ashraf et al. ("Shrink or Substitute", 1801.04523) show shrink and
substitution are interchangeable strategies behind a single recovery
interface; this module makes that literally true of the runtime. The
``VirtualCluster._repair_*`` methods and the if/elif ladder in
``VirtualCluster.repair`` are gone: each recovery mode is one registered
:class:`RecoveryStrategy` class, selected by ``LegioPolicy.strategy_key``.
New modes (checkpoint-restart-all, migrate, ...) are one
``@register_strategy("name")`` class, not executor surgery.

Strategies mutate the cluster (topology, batch plan, spare pool, pending
splices) but never commit bookkeeping: ``VirtualCluster.repair`` /
``VirtualCluster.repair_scoped`` own confirm/charge/record, so every
strategy gets identical accounting.

Scoped invocation: the fault pipeline partitions each drain's verdict into
disjoint :class:`~repro.core.types.RepairScope` subtrees and invokes the
registered strategy once per scope (``repair_scoped``). A strategy
therefore only ever sees verdict nodes whose repairs share participants —
faults in unrelated subtrees arrive as separate calls whose repairs are
charged as concurrent (max cost, not sum). Strategies need no scope
awareness: the scope is stamped onto the returned report by the cluster.

Exhaustion semantics (satellite fix): the non-blocking strategy lands the
shrink FIRST, then checks the pool — so a strict-mode
:class:`SparePoolExhausted` always propagates from a *consistent* (shrunk)
topology, with the committed shrink report attached as ``partial_report``.

Overlap axis (``LegioPolicy.repair_overlap``, the revoke-then-repair mode):
rather than a separate BackgroundRepairStrategy, every registered strategy
carries an ``overlap_safe`` class attribute. A strategy is overlap-safe
when its structural mutation is atomic within the drain — the topology it
leaves behind is fully applied the moment ``repair`` returns, so deferring
only the *clock charge* to a :class:`~repro.core.types.BackgroundRepair`
window cannot expose a half-applied group. All three built-ins qualify
(the non-blocking substitute's deferred splice goes through its own
pending-queue machinery, orthogonal to the window). Set
``overlap_safe = False`` on a future strategy whose mutation spans calls
(e.g. incremental checkpoint restore) and ``VirtualCluster`` falls back to
blocking charges for it, policy knob notwithstanding.

Invariants every strategy must preserve (asserted by tests/test_pipeline.py,
tests/test_substitute.py, and tests/test_serve.py):

  * **one terminal action per fault** — ``repair`` handles each verdict
    node exactly once; a node it removed (or substituted away) never
    reappears in a later verdict, so the pipeline emits exactly one
    terminal RecoveryAction per failed node;
  * **frozen epochs under pin** — strategies mutate the topology only via
    its epoch-guarded mutators (``remove``/``substitute``/``expand``/
    ``compact``), never while a ``TopologyView`` is pinned;
  * **assignment finality + master rule** — a splice lands in the failed
    node's home legion, and spare ids always exceed every initial id, so
    no surviving master is ever demoted;
  * **no capacity is silently lost** — every failed slot is either
    substituted, shrunk into ``RepairReport.unfilled`` (and remembered on
    the provisioner backlog), or scheduled as a ``PendingSubstitution``;
    downstream consumers (batch plan, serve queues) re-own the slot's
    work from the report, which is what makes the serve layer's
    at-least-once re-enqueue possible;
  * **repairs stay inside their scope** — the engines fold failures
    legion-by-legion and spares splice into the failed node's home
    legion, so two disjoint scopes' repairs commute — the property that
    makes per-scope application order irrelevant and the concurrency
    claim sound (asserted structurally by benchmarks/hierarchy_scaling.py).
    The one deliberate exception is shrink-mode's beyond-paper elastic
    regrow, which may expand whichever live legion is smallest.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, runtime_checkable

from repro.core.batch import (
    BatchPlan,
    initial_assignment,
    reassign,
    substitute_assign,
)
from repro.core.policy import LegioPolicy
from repro.core.substitute import (
    PendingSubstitution,
    SparePoolExhausted,
    UnfilledSlot,
    restore_for_substitute,
)
from repro.core.types import RepairReport, RepairStep

if TYPE_CHECKING:
    from repro.core.executor import VirtualCluster


@runtime_checkable
class RecoveryStrategy(Protocol):
    """The repair half of the fault pipeline's apply stage."""

    name: str

    def repair(self, cluster: "VirtualCluster",
               verdict: set[int]) -> RepairReport: ...


_REGISTRY: dict[str, type] = {}


def register_strategy(name: str):
    """Class decorator: register a RecoveryStrategy under ``name``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_strategies() -> list[str]:
    return sorted(_REGISTRY)


def make_strategy(policy: LegioPolicy) -> RecoveryStrategy:
    """Compose the strategy the policy asks for (``policy.strategy_key``)."""
    key = policy.strategy_key
    try:
        return _REGISTRY[key](policy)
    except KeyError:
        raise KeyError(
            f"no RecoveryStrategy registered under {key!r} "
            f"(available: {available_strategies()})") from None


class _PolicyBound:
    # structural mutation is atomic within the drain for every built-in, so
    # the clock charge may be deferred to a background window (see module
    # docstring); strategies whose mutation spans calls override to False
    overlap_safe: bool = True

    def __init__(self, policy: LegioPolicy):
        self.policy = policy


@register_strategy("shrink")
class ShrinkStrategy(_PolicyBound):
    """The paper's native discard-and-continue: shrink every failed slot,
    optionally regrowing from provisioned spares into the smallest legion
    (beyond-paper elastic regrow, kept for recovery_mode="shrink")."""

    def repair(self, cluster: "VirtualCluster", verdict: set[int],
               *, regrow: bool = True) -> RepairReport:
        report = cluster.shrink.repair(cluster.topo, verdict)
        grown = []
        while regrow and cluster.spares and cluster.topo.size < cluster.n_initial:
            spare = cluster.spare_pool.take()
            target = min((lg for lg in cluster.topo.legions if lg.members),
                         key=len, default=None)
            if target is None:
                from repro.core.hierarchy import make_topology
                cluster.topo = make_topology([spare], self.policy)
            else:
                cluster.topo.expand(target.index, spare)
            cluster.detector.register(spare, cluster.clock.sim_seconds)
            grown.append(spare)
        if grown:
            report.steps.append(RepairStep(
                op="include", comm="world", participants=tuple(grown),
                cost_units=0.0))
        cluster.plan = reassign(cluster.plan, verdict, self.policy.batch_policy)
        if grown:
            # new members take over dropped shards (restart-only-failed)
            extra = initial_assignment(grown, cluster.shards_per_node)
            take = list(cluster.plan.dropped_shards)
            new_assignments = list(cluster.plan.assignments)
            for a in extra.assignments:
                shards = tuple(take.pop(0) for _ in a.shards if take)
                new_assignments.append(type(a)(node=a.node, shards=shards))
            cluster.plan = BatchPlan(
                assignments=tuple(new_assignments),
                dropped_shards=tuple(take),
                policy=cluster.plan.policy)
        return report


@register_strategy("substitute")
class SubstituteStrategy(_PolicyBound):
    """Blocking substitution: splice warm spares during the repair itself;
    the substituted ranks compute from the next step. Slots the pool cannot
    cover are shrunk (then_shrink) or refused before mutation (strict) —
    shrunk slots go on the provisioner backlog for later healing."""

    def repair(self, cluster: "VirtualCluster",
               verdict: set[int]) -> RepairReport:
        owned = {n: cluster.plan.shards_of(n) for n in verdict}
        homes = {n: cluster.topo.home.get(n) for n in verdict}
        report = cluster.substitute.repair(cluster.topo, verdict,
                                           cluster.spare_pool)
        for failed, spare in report.substitutions:
            cluster.detector.register(spare, cluster.clock.sim_seconds)
            cluster._note_restored(spare, restore_for_substitute(
                cluster.checkpointer, cluster.topo.home[spare], failed))
        cluster.plan = substitute_assign(cluster.plan, report.substitution_map)
        if report.unfilled:
            cluster.plan = reassign(cluster.plan, set(report.unfilled),
                                    self.policy.batch_policy)
            for node in report.unfilled:
                cluster.note_unfilled(UnfilledSlot(
                    failed=node, legion=homes[node], shards=owned[node]))
        return report


@register_strategy("substitute_nonblocking")
class NonblockingSubstituteStrategy(_PolicyBound):
    """Non-blocking substitution: repair by shrink now (the next step runs
    degraded), schedule the splice for after the spare's warmup. The shrink
    lands BEFORE the pool is consulted, so strict-mode exhaustion leaves a
    consistent topology (dead nodes out) and attaches the committed shrink
    report to the raised :class:`SparePoolExhausted`."""

    def repair(self, cluster: "VirtualCluster",
               verdict: set[int]) -> RepairReport:
        topo = cluster.topo
        homes = {n: topo.home[n] for n in verdict
                 if n in topo.home and n in topo.nodes}
        # each pending splice returns exactly the failed node's own shards
        owned = {n: cluster.plan.shards_of(n) for n in homes}
        report = ShrinkStrategy(self.policy).repair(cluster, verdict,
                                                    regrow=False)
        try:
            cluster.spare_pool.require(
                len(homes), self.policy.recovery_mode == "substitute")
        except SparePoolExhausted as exc:
            exc.partial_report = report
            raise
        scheduled = 0
        for node, legion in sorted(homes.items()):
            spare = cluster.spare_pool.take()
            if spare is None:
                # substitute_then_shrink: stay shrunk, remember the slot
                cluster.note_unfilled(UnfilledSlot(
                    failed=node, legion=legion, shards=owned[node]))
                continue
            scheduled += 1
            # the fault step itself ran degraded; spare_warmup_steps MORE
            # steps run shrunk before the splice lands at a boundary
            cluster.pending.append(PendingSubstitution(
                failed=node, spare=spare, legion=legion,
                ready_step=cluster._step + 1 + self.policy.spare_warmup_steps,
                shards=owned[node]))
        report.mode = ("substitute(nonblocking)" if scheduled == len(homes)
                       else "substitute_then_shrink")
        return report
