"""RecoveryStrategy layer — pluggable repair behaviors behind one seam.

Ashraf et al. ("Shrink or Substitute", 1801.04523) show shrink and
substitution are interchangeable strategies behind a single recovery
interface; this module makes that literally true of the runtime. The
``VirtualCluster._repair_*`` methods and the if/elif ladder in
``VirtualCluster.repair`` are gone: each recovery mode is one registered
:class:`RecoveryStrategy` class, selected by ``LegioPolicy.strategy_key``.
New modes (checkpoint-restart-all, migrate, ...) are one
``@register_strategy("name")`` class, not executor surgery.

Strategies mutate the cluster (topology, batch plan, spare pool, pending
splices) but never commit bookkeeping: ``VirtualCluster.repair`` /
``VirtualCluster.repair_scoped`` own confirm/charge/record, so every
strategy gets identical accounting.

Scoped invocation: the fault pipeline partitions each drain's verdict into
disjoint :class:`~repro.core.types.RepairScope` subtrees and invokes the
registered strategy once per scope (``repair_scoped``). A strategy
therefore only ever sees verdict nodes whose repairs share participants —
faults in unrelated subtrees arrive as separate calls whose repairs are
charged as concurrent (max cost, not sum). Strategies need no scope
awareness: the scope is stamped onto the returned report by the cluster.

Exhaustion semantics (satellite fix): the non-blocking strategy lands the
shrink FIRST, then checks the pool — so a strict-mode
:class:`SparePoolExhausted` always propagates from a *consistent* (shrunk)
topology, with the committed shrink report attached as ``partial_report``.

Overlap axis (``LegioPolicy.repair_overlap``, the revoke-then-repair mode):
rather than a separate BackgroundRepairStrategy, every registered strategy
carries an ``overlap_safe`` class attribute. A strategy is overlap-safe
when its structural mutation is atomic within the drain — the topology it
leaves behind is fully applied the moment ``repair`` returns, so deferring
only the *clock charge* to a :class:`~repro.core.types.BackgroundRepair`
window cannot expose a half-applied group. All three built-ins qualify
(the non-blocking substitute's deferred splice goes through its own
pending-queue machinery, orthogonal to the window). Set
``overlap_safe = False`` on a future strategy whose mutation spans calls
(e.g. incremental checkpoint restore) and ``VirtualCluster`` falls back to
blocking charges for it, policy knob notwithstanding.

Invariants every strategy must preserve (asserted by tests/test_pipeline.py,
tests/test_substitute.py, and tests/test_serve.py):

  * **one terminal action per fault** — ``repair`` handles each verdict
    node exactly once; a node it removed (or substituted away) never
    reappears in a later verdict, so the pipeline emits exactly one
    terminal RecoveryAction per failed node;
  * **frozen epochs under pin** — strategies mutate the topology only via
    its epoch-guarded mutators (``remove``/``substitute``/``expand``/
    ``compact``), never while a ``TopologyView`` is pinned;
  * **assignment finality + master rule** — a splice lands in the failed
    node's home legion, and spare ids always exceed every initial id, so
    no surviving master is ever demoted;
  * **no capacity is silently lost** — every failed slot is either
    substituted, shrunk into ``RepairReport.unfilled`` (and remembered on
    the provisioner backlog), or scheduled as a ``PendingSubstitution``;
    downstream consumers (batch plan, serve queues) re-own the slot's
    work from the report, which is what makes the serve layer's
    at-least-once re-enqueue possible;
  * **repairs stay inside their scope** — the engines fold failures
    legion-by-legion and spares splice into the failed node's home
    legion, so two disjoint scopes' repairs commute — the property that
    makes per-scope application order irrelevant and the concurrency
    claim sound (asserted structurally by benchmarks/hierarchy_scaling.py).
    The one deliberate exception is shrink-mode's beyond-paper elastic
    regrow, which may expand whichever live legion is smallest.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Protocol, runtime_checkable

from repro.core.batch import (
    BatchPlan,
    initial_assignment,
    reassign,
    substitute_assign,
)
from repro.core.policy import LegioPolicy
from repro.core.substitute import (
    PendingSubstitution,
    SparePoolExhausted,
    UnfilledSlot,
    restore_member_state,
)
from repro.core.types import RepairReport, RepairStep

if TYPE_CHECKING:
    from repro.core.executor import VirtualCluster


@runtime_checkable
class RecoveryStrategy(Protocol):
    """The repair half of the fault pipeline's apply stage."""

    name: str

    def repair(self, cluster: "VirtualCluster",
               verdict: set[int]) -> RepairReport: ...


_REGISTRY: dict[str, type] = {}


def register_strategy(name: str):
    """Class decorator: register a RecoveryStrategy under ``name``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_strategies() -> list[str]:
    return sorted(_REGISTRY)


def make_strategy(policy: LegioPolicy) -> RecoveryStrategy:
    """Compose the strategy the policy asks for (``policy.strategy_key``)."""
    key = policy.strategy_key
    try:
        return _REGISTRY[key](policy)
    except KeyError:
        raise KeyError(
            f"no RecoveryStrategy registered under {key!r} "
            f"(available: {available_strategies()})") from None


class _PolicyBound:
    # structural mutation is atomic within the drain for every built-in, so
    # the clock charge may be deferred to a background window (see module
    # docstring); strategies whose mutation spans calls override to False
    overlap_safe: bool = True

    def __init__(self, policy: LegioPolicy):
        self.policy = policy


@register_strategy("shrink")
class ShrinkStrategy(_PolicyBound):
    """The paper's native discard-and-continue: shrink every failed slot,
    optionally regrowing from provisioned spares into the smallest legion
    (beyond-paper elastic regrow, kept for recovery_mode="shrink")."""

    def repair(self, cluster: "VirtualCluster", verdict: set[int],
               *, regrow: bool = True) -> RepairReport:
        report = cluster.shrink.repair(cluster.topo, verdict)
        grown = []
        while regrow and cluster.spares and cluster.topo.size < cluster.n_initial:
            spare = cluster.spare_pool.take()
            target = min((lg for lg in cluster.topo.legions if lg.members),
                         key=len, default=None)
            if target is None:
                from repro.core.hierarchy import make_topology
                cluster.topo = make_topology([spare], self.policy)
            else:
                cluster.topo.expand(target.index, spare)
            cluster.detector.register(spare, cluster.clock.sim_seconds)
            grown.append(spare)
        if grown:
            report.steps.append(RepairStep(
                op="include", comm="world", participants=tuple(grown),
                cost_units=0.0))
        cluster.plan = reassign(cluster.plan, verdict, self.policy.batch_policy)
        if grown:
            # new members take over dropped shards (restart-only-failed)
            extra = initial_assignment(grown, cluster.shards_per_node)
            take = list(cluster.plan.dropped_shards)
            new_assignments = list(cluster.plan.assignments)
            for a in extra.assignments:
                shards = tuple(take.pop(0) for _ in a.shards if take)
                new_assignments.append(type(a)(node=a.node, shards=shards))
            cluster.plan = BatchPlan(
                assignments=tuple(new_assignments),
                dropped_shards=tuple(take),
                policy=cluster.plan.policy)
        return report


@register_strategy("substitute")
class SubstituteStrategy(_PolicyBound):
    """Blocking substitution: splice warm spares during the repair itself;
    the substituted ranks compute from the next step. Slots the pool cannot
    cover are shrunk (then_shrink) or refused before mutation (strict) —
    shrunk slots go on the provisioner backlog for later healing."""

    def repair(self, cluster: "VirtualCluster",
               verdict: set[int]) -> RepairReport:
        owned = {n: cluster.plan.shards_of(n) for n in verdict}
        homes = {n: cluster.topo.home.get(n) for n in verdict}
        report = cluster.substitute.repair(cluster.topo, verdict,
                                           cluster.spare_pool)
        restore_steps = {st.participants[0]: st for st in report.steps
                         if st.op == "restore" and st.participants}
        for failed, spare in report.substitutions:
            cluster.detector.register(spare, cluster.clock.sim_seconds)
            outcome = restore_member_state(cluster, cluster.topo.home[spare],
                                           failed)
            cluster._note_restored(spare, outcome.state)
            # a peer hit re-costs the splice's restore stage: one O(shard)
            # cross-member transfer instead of the O(model) store read
            step = restore_steps.get(spare)
            if step is not None and outcome.source == "peer":
                step.cost_units = outcome.cost_seconds
        report.model_cost = sum(st.cost_units for st in report.steps)
        cluster.plan = substitute_assign(cluster.plan, report.substitution_map)
        if report.unfilled:
            cluster.plan = reassign(cluster.plan, set(report.unfilled),
                                    self.policy.batch_policy)
            for node in report.unfilled:
                cluster.note_unfilled(UnfilledSlot(
                    failed=node, legion=homes[node], shards=owned[node]))
        return report


@register_strategy("substitute_nonblocking")
class NonblockingSubstituteStrategy(_PolicyBound):
    """Non-blocking substitution: repair by shrink now (the next step runs
    degraded), schedule the splice for after the spare's warmup. The shrink
    lands BEFORE the pool is consulted, so strict-mode exhaustion leaves a
    consistent topology (dead nodes out) and attaches the committed shrink
    report to the raised :class:`SparePoolExhausted`."""

    def repair(self, cluster: "VirtualCluster",
               verdict: set[int]) -> RepairReport:
        topo = cluster.topo
        homes = {n: topo.home[n] for n in verdict
                 if n in topo.home and n in topo.nodes}
        # each pending splice returns exactly the failed node's own shards
        owned = {n: cluster.plan.shards_of(n) for n in homes}
        report = ShrinkStrategy(self.policy).repair(cluster, verdict,
                                                    regrow=False)
        try:
            cluster.spare_pool.require(
                len(homes), self.policy.recovery_mode == "substitute")
        except SparePoolExhausted as exc:
            exc.partial_report = report
            raise
        scheduled = 0
        for node, legion in sorted(homes.items()):
            spare = cluster.spare_pool.take()
            if spare is None:
                # substitute_then_shrink: stay shrunk, remember the slot
                cluster.note_unfilled(UnfilledSlot(
                    failed=node, legion=legion, shards=owned[node]))
                continue
            scheduled += 1
            # the fault step itself ran degraded; spare_warmup_steps MORE
            # steps run shrunk before the splice lands at a boundary
            cluster.pending.append(PendingSubstitution(
                failed=node, spare=spare, legion=legion,
                ready_step=cluster._step + 1 + self.policy.spare_warmup_steps,
                shards=owned[node]))
        report.mode = ("substitute(nonblocking)" if scheduled == len(homes)
                       else "substitute_then_shrink")
        return report


@dataclass(frozen=True)
class AdaptiveDecision:
    """One :class:`CostModelStrategy` dispatch, fully explained: every
    candidate's estimated recovery seconds and the winner that ran."""

    step: int
    verdict: tuple[int, ...]
    scores: dict[str, float] = field(default_factory=dict)
    chosen: str = "shrink"
    # EWMA-fitted detect/notice/agree/plan seconds for this verdict size —
    # paid identically by every candidate, so recorded rather than scored
    pipeline_overhead: float = 0.0


@register_strategy("adaptive")
class CostModelStrategy(_PolicyBound):
    """Adaptive recovery: score every registered mode per fault, run the
    cheapest (``recovery_mode="adaptive"``).

    The scorer combines three ingredients, all of them live state rather
    than configuration:

      * the engines' **pure plans** — ``ShrinkEngine.plan`` and
        ``SubstituteEngine.plan`` are dry-run against the current topology,
        so the structural S(x) costs scored are exactly the costs the
        winning strategy will charge;
      * the **restore ladder's actual path** — a failed node whose POV-ring
        buddy holds a live replica is scored at the O(shard) link-model
        transfer; otherwise at the store read (``restore_seconds``), the
        same decision :func:`~repro.core.substitute.restore_member_state`
        will make;
      * **online-fitted pipeline latencies** — per-stage wall seconds from
        ``FaultPipeline.traces``, EWMA-smoothed per verdict-size bucket
        (alpha = 2/(adaptive_ewma_horizon+1)). The non-apply stages are paid
        identically by every candidate, so they ride on the decision record
        (``pipeline_overhead``) instead of perturbing the argmin.

    Capacity lost to a shrink is charged as opportunity cost: a slot left
    shrunk forfeits its share of cluster throughput
    (``step_sim_seconds / size``) for ``adaptive_horizon_steps`` steps —
    the knob that decides when splicing a spare beats running degraded.

    The rollback strawman (snippet-1-style CONTROL_POINT loop: every
    survivor rolls back to the last checkpoint and re-executes) is scored
    as the ``"restart"`` baseline on every decision, but never dispatched —
    restart-only-failed dominates it by construction, and the recorded
    margin is the evidence (benchmarks/recovery_cost.py plots it).

    Inner strategies are composed per dispatch with non-strict policies
    (``substitute_then_shrink``), so the adaptive mode NEVER raises
    :class:`SparePoolExhausted` — an empty pool simply prices substitution
    at shrink-or-worse and the tie-break prefers shrink. Dispatched shrinks
    pass ``regrow=False``: choosing shrink means the scorer judged spares
    not worth spending here.
    """

    #: modes the scorer may dispatch ("restart" is baseline-only)
    DISPATCHABLE = ("shrink", "substitute", "substitute_nonblocking")

    def __init__(self, policy: LegioPolicy):
        super().__init__(policy)
        self.decisions: list[AdaptiveDecision] = []
        self._ewma: dict[tuple[str, int], float] = {}  # (stage, bucket)
        self._seen_traces = 0

    # -- online fitting from pipeline traces ----------------------------------

    @staticmethod
    def _bucket(n: int) -> int:
        """Power-of-two verdict-size bucket (1-node faults dominate; rack
        drains land in coarser buckets with their own latency profile)."""
        b = 1
        while b < n:
            b *= 2
        return b

    def _ingest(self, cluster: "VirtualCluster") -> None:
        alpha = 2.0 / (self.policy.adaptive_ewma_horizon + 1.0)
        traces = cluster.pipeline.traces
        for tr in traces[self._seen_traces:]:
            bucket = self._bucket(max(1, len(tr.verdict)))
            for stage, secs in tr.stage_seconds.items():
                key = (stage, bucket)
                prev = self._ewma.get(key)
                self._ewma[key] = secs if prev is None else \
                    prev + alpha * (secs - prev)
        self._seen_traces = len(traces)

    def fitted_overhead(self, n_failed: int) -> float:
        """EWMA detect/notice/agree/plan seconds for an n-node verdict."""
        bucket = self._bucket(max(1, n_failed))
        return sum(secs for (stage, b), secs in self._ewma.items()
                   if b == bucket and stage != "apply")

    # -- scoring ---------------------------------------------------------------

    def _restore_cost(self, cluster: "VirtualCluster", node: int) -> float:
        """What the restore ladder would charge for ``node`` right now."""
        store_cost = cluster.substitute.cost.restore_seconds
        replicator = getattr(cluster, "replicator", None)
        if replicator is None or not replicator.enabled:
            return store_cost
        record = replicator.replicas.get(node)
        if record is None or record.holder in cluster.failed \
                or record.holder not in cluster.topo.nodes:
            return store_cost
        return replicator.transfer_seconds(record.nbytes)

    def score(self, cluster: "VirtualCluster",
              verdict: set[int]) -> dict[str, float]:
        """Estimated total recovery seconds per candidate mode."""
        pol, topo = self.policy, cluster.topo
        present = [n for n in sorted(verdict)
                   if n in topo.home and n in topo.nodes]
        size = max(1, topo.size)
        # opportunity cost of one slot-step: a shrunk slot forfeits its
        # share of cluster throughput until the horizon runs out
        slot_step = pol.step_sim_seconds / size
        horizon = pol.adaptive_horizon_steps

        teardown = sum(st.cost_units
                       for st in cluster.shrink.plan(topo, set(verdict)))
        spares = list(cluster.spare_pool.available)
        filled = min(len(present), len(spares))
        unfilled = len(present) - filled

        scores: dict[str, float] = {}
        scores["shrink"] = teardown + len(present) * slot_step * horizon

        # blocking substitution: the engine's own (pure) plan, each restore
        # stage re-costed the way the ladder would actually charge it
        hypo = dict(zip(present, spares))
        spare_of = {s: n for n, s in hypo.items()}
        sub = 0.0
        for st in cluster.substitute.plan(topo, set(verdict), hypo):
            if st.op == "restore" and st.participants:
                sub += self._restore_cost(cluster, spare_of[st.participants[0]])
            else:
                sub += st.cost_units
        scores["substitute"] = sub + unfilled * slot_step * horizon

        # non-blocking: shrink lands now, the splice charge lands after
        # warmup (restore overlaps the warmup — uncharged, see
        # VirtualCluster.poll_substitutions); filled slots run shrunk
        # through the warmup window only
        warmup = min(1 + pol.spare_warmup_steps, horizon)
        splices = filled * cluster.substitute.cost.splice_cost(
            max(1, topo.k) - 1)
        scores["substitute_nonblocking"] = (
            teardown + splices
            + filled * slot_step * warmup
            + unfilled * slot_step * horizon)

        # restart-from-checkpoint baseline: every survivor rolls back to
        # the last snapshot (full O(model) restore) and re-executes the
        # lost steps; the dead slots still shrink away
        ck = cluster.checkpointer
        last = ck.latest_step() if ck is not None else None
        lost = cluster._step - last if last is not None else cluster._step
        scores["restart"] = (
            teardown
            + max(0, lost) * pol.step_sim_seconds
            + cluster.substitute.cost.restore_seconds * size
            + len(present) * slot_step * horizon)
        return scores

    # -- dispatch --------------------------------------------------------------

    def repair(self, cluster: "VirtualCluster",
               verdict: set[int]) -> RepairReport:
        self._ingest(cluster)
        scores = self.score(cluster, verdict)
        # ties prefer the earlier entry — with an empty pool every
        # substitution candidate collapses to shrink's score, and shrink
        # wins without touching the provisioner
        chosen = min(self.DISPATCHABLE, key=lambda m: scores[m])
        self.decisions.append(AdaptiveDecision(
            step=cluster._step, verdict=tuple(sorted(verdict)),
            scores=scores, chosen=chosen,
            pipeline_overhead=self.fitted_overhead(len(verdict))))
        if chosen == "shrink":
            inner = replace(self.policy, recovery_mode="shrink")
            return ShrinkStrategy(inner).repair(cluster, verdict,
                                                regrow=False)
        inner = replace(
            self.policy, recovery_mode="substitute_then_shrink",
            nonblocking_substitution=(chosen == "substitute_nonblocking"))
        if chosen == "substitute_nonblocking":
            return NonblockingSubstituteStrategy(inner).repair(cluster,
                                                               verdict)
        return SubstituteStrategy(inner).repair(cluster, verdict)
