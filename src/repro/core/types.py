"""Core vocabulary of the Legio runtime.

Terminology follows the paper (§III):

  * a node *notices* a fault when an operation it participates in returns
    ``PROC_FAILED`` (our :class:`OpStatus`);
  * a communicator is *faulty* when a member has failed but nobody noticed;
  * a communicator is *failed* once a member noticed.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class NodeState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"       # missed heartbeats, not yet agreed failed
    FAILED = "failed"
    STRAGGLER = "straggler"   # alive but slower than median * threshold
    SPARE = "spare"           # standby, can regrow a legion (elastic)


class OpStatus(enum.Enum):
    OK = "ok"
    PROC_FAILED = "proc_failed"    # MPIX_ERR_PROC_FAILED analogue
    REVOKED = "revoked"            # communicator revoked


class FailureKind(enum.Enum):
    CRASH = "crash"          # permanent node loss
    STRAGGLE = "straggle"    # performance fault (soft-failed by policy)


@dataclass(frozen=True)
class FailureEvent:
    node: int
    step: int
    kind: FailureKind = FailureKind.CRASH


class FaultSource(enum.Enum):
    """Detection channel a fault signal arrived on (pipeline detect stage)."""

    COLLECTIVE = "collective"    # PROC_FAILED surfaced by a collective op
    HEARTBEAT = "heartbeat"      # HeartbeatDetector.sweep timeout
    STRAGGLER = "straggler"      # StragglerDetector soft-fail
    INJECTED = "injected"        # ground-truth feed (trainer/driver sims)


PIPELINE_STAGES = ("detect", "notice", "agree", "plan", "apply")


@dataclass(frozen=True)
class RepairScope:
    """The minimal subtree of the N-level topology whose members must
    participate in one repair (Rocco & Palermo: confine reparation to the
    communicators that actually contain the fault).

    ``groups`` lists the ``(level, group index)`` comms the repair touches;
    ``participants`` is the union of those comms' surviving members — the
    only nodes that enter the repair path. Nodes outside ``participants``
    (healthy subtrees) keep progressing while this scope repairs. Scopes in
    one pipeline drain have pairwise-disjoint participants by construction
    (``LegionTopology.partition_scopes`` merges overlapping ones), which is
    what makes their repairs concurrent.
    """

    verdict: tuple[int, ...]             # failed nodes this scope covers
    level: int                           # highest level the repair reaches
    groups: tuple[tuple[int, int], ...]  # (level, group index) comms touched
    participants: tuple[int, ...]        # surviving nodes that take part

    @property
    def n_participants(self) -> int:
        return len(self.participants)

    @property
    def legions(self) -> tuple[int, ...]:
        """Level-0 legion indices inside the scope."""
        return tuple(gi for lvl, gi in self.groups if lvl == 0)

    def summary(self) -> str:
        return (f"scope(level={self.level}, legions={list(self.legions)}, "
                f"participants={self.n_participants})")


@dataclass(frozen=True)
class FaultEvent:
    """One fault signal flowing through the FaultPipeline.

    Unlike :class:`FailureEvent` (the injector's ground-truth schedule), a
    FaultEvent is *observational*: it records what some channel saw, before
    noticing semantics and agreement have run.
    """

    nodes: tuple[int, ...]
    step: int
    source: FaultSource
    kind: FailureKind = FailureKind.CRASH
    op: str | None = None        # collective op that surfaced it (COLLECTIVE)
    root: int | None = None      # the op's root, for bcast noticing
    participants: tuple[int, ...] | None = None  # the op's member set; None
                                 # = resolve against the topology at drain
    # who holds this suspicion (correlated-failure channel): None keeps the
    # historical semantics — every live node reads the coordinator state.
    # A network partition is the asymmetric case: each side suspects the
    # *other* side, so its event carries only that side's observers, and
    # agreement (the union over LIVE observers) is what makes the fenced
    # side's accusations moot — both sides converge on one verdict.
    observers: tuple[int, ...] | None = None


class ChaosAction(enum.Enum):
    """What one timed event of a fault campaign does to the cluster
    (:mod:`repro.core.faultmodel` presets emit these; the
    :class:`~repro.core.chaos.ChaosHarness` applies them)."""

    CRASH = "crash"            # ground-truth node death (FaultInjector)
    SUSPECT = "suspect"        # one-sided suspicion held by `observers` only
    SLOWDOWN = "slowdown"      # inflate a node's observed step latency
    FLAP_RETURN = "flap_return"  # a repaired-out node tries to come back


@dataclass(frozen=True)
class RecoveryAction:
    """Terminal outcome of one pipeline drain: the agreed verdict plus the
    repair the active RecoveryStrategy applied for it. Exactly one terminal
    action exists per agreed-failed node (property-tested)."""

    step: int
    verdict: tuple[int, ...]
    strategy: str                          # registry key of the strategy
    sources: tuple[FaultSource, ...]
    report: "RepairReport | None" = None
    terminal: bool = True
    stage_seconds: dict[str, float] = field(default_factory=dict)
    # the subtree this action repaired; one drain emits one terminal action
    # per disjoint scope, so faults in unrelated subtrees land as separate,
    # concurrently-applied actions
    scope: RepairScope | None = None
    # the repair's clock charge was deferred to a background window
    # (LegioPolicy.repair_overlap): the structure mutated eagerly, but the
    # scope's participants stay busy until the window's finish_sim passes
    overlapped: bool = False


@dataclass(frozen=True)
class PipelineTrace:
    """Per-drain stage-latency record (benchmarks read these)."""

    step: int
    n_events: int
    verdict: tuple[int, ...]
    stage_seconds: dict[str, float] = field(default_factory=dict)


@dataclass
class RepairStep:
    """One stage of a repair plan (a shrink, a notify, a promote, or a
    spare splice)."""
    op: str                      # shrink | notify | promote | include | substitute | restore
    comm: str                    # local_<i> | pov_<i> | global | world
    participants: tuple[int, ...]
    cost_units: float = 0.0      # S(x) model cost of this stage


@dataclass
class RepairReport:
    trigger: tuple[int, ...]             # failed nodes handled by this repair
    hierarchical: bool
    master_failed: bool
    steps: list[RepairStep] = field(default_factory=list)
    model_cost: float = 0.0              # sum of S(x) stage costs (sim seconds)
    wall_seconds: float = 0.0            # measured runtime of our repair path
    recompiled: bool = False
    survivors: int = 0
    mode: str = "shrink"                 # recovery mode that produced this plan
    substitutions: tuple[tuple[int, int], ...] = ()   # (failed, spare) splices
    unfilled: tuple[int, ...] = ()       # failed slots shrunk for lack of spares
    scope: RepairScope | None = None     # subtree the repair was confined to
    repair_participants: int = 0         # survivors that entered the repair
                                         # path (0 = unscoped/legacy repair)

    @property
    def substitution_map(self) -> dict[int, int]:
        return dict(self.substitutions)

    def summary(self) -> str:
        kind = "hierarchical" if self.hierarchical else "flat"
        role = "master" if self.master_failed else "worker"
        sub = f" subs={list(self.substitutions)}" if self.substitutions else ""
        scoped = (f" participants={self.repair_participants}"
                  if self.scope is not None else "")
        return (f"[repair/{kind}/{self.mode}] failed={list(self.trigger)} "
                f"role={role} stages={len(self.steps)} "
                f"model_cost={self.model_cost:.4f}s "
                f"wall={self.wall_seconds * 1e3:.2f}ms "
                f"survivors={self.survivors}{sub}{scoped}")


@dataclass
class BackgroundRepair:
    """One in-flight overlapped repair window (revoke-then-repair).

    The structural repair has already landed (topology mutated, detector
    confirmed, report recorded) — what is *deferred* is the repair's clock
    charge: the scope occupies the simulated interval
    ``[start_sim, finish_sim]`` while healthy subtrees keep computing.
    Until the cluster clock passes ``finish_sim`` the scope's surviving
    ``participants`` are busy: collective schedules exclude them and their
    p2p envelopes stay pending in the ledger (never discarded — they were
    posted to live nodes). ``VirtualCluster.reconcile_repairs`` merges the
    window back at the first ``Session`` boundary whose clock has passed
    ``finish_sim`` — by construction with zero residual wait, so a repair
    shorter than one step of compute is fully hidden.
    """

    scope: RepairScope
    report: RepairReport
    start_step: int
    start_sim: float
    finish_sim: float

    @property
    def busy(self) -> tuple[int, ...]:
        """Surviving nodes occupied by this repair for the window."""
        return self.scope.participants

    def done(self, now: float) -> bool:
        return self.finish_sim <= now + 1e-12

    def residual(self, now: float) -> float:
        """Sim-seconds of the repair not yet hidden behind compute."""
        return max(0.0, self.finish_sim - now)


@dataclass
class ClusterClock:
    """Simulated time accumulator (repair cost model) + real wall time.

    Overlapped repairs split their cost into the part *absorbed* behind
    concurrent compute (never added to ``sim_seconds``) and the *residual*
    the application actually waited for (charged like any other cost) —
    the chaos harness asserts ``residual_seconds == 0`` for disjoint-scope
    overlap runs: healthy subtrees never pay for a remote scope's repair.
    """

    sim_seconds: float = 0.0
    hidden_seconds: float = 0.0      # overlapped repair cost fully absorbed
    residual_seconds: float = 0.0    # overlapped repair cost waited out

    def charge(self, seconds: float) -> None:
        self.sim_seconds += seconds

    def absorb(self, seconds: float) -> None:
        self.hidden_seconds += seconds

    def wait(self, seconds: float) -> None:
        self.residual_seconds += seconds
        self.sim_seconds += seconds
