"""Core vocabulary of the Legio runtime.

Terminology follows the paper (§III):

  * a node *notices* a fault when an operation it participates in returns
    ``PROC_FAILED`` (our :class:`OpStatus`);
  * a communicator is *faulty* when a member has failed but nobody noticed;
  * a communicator is *failed* once a member noticed.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class NodeState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"       # missed heartbeats, not yet agreed failed
    FAILED = "failed"
    STRAGGLER = "straggler"   # alive but slower than median * threshold
    SPARE = "spare"           # standby, can regrow a legion (elastic)


class OpStatus(enum.Enum):
    OK = "ok"
    PROC_FAILED = "proc_failed"    # MPIX_ERR_PROC_FAILED analogue
    REVOKED = "revoked"            # communicator revoked


class FailureKind(enum.Enum):
    CRASH = "crash"          # permanent node loss
    STRAGGLE = "straggle"    # performance fault (soft-failed by policy)


@dataclass(frozen=True)
class FailureEvent:
    node: int
    step: int
    kind: FailureKind = FailureKind.CRASH


@dataclass
class RepairStep:
    """One stage of a repair plan (a shrink, a notify, a promote, or a
    spare splice)."""
    op: str                      # shrink | notify | promote | include | substitute | restore
    comm: str                    # local_<i> | pov_<i> | global | world
    participants: tuple[int, ...]
    cost_units: float = 0.0      # S(x) model cost of this stage


@dataclass
class RepairReport:
    trigger: tuple[int, ...]             # failed nodes handled by this repair
    hierarchical: bool
    master_failed: bool
    steps: list[RepairStep] = field(default_factory=list)
    model_cost: float = 0.0              # sum of S(x) stage costs (sim seconds)
    wall_seconds: float = 0.0            # measured runtime of our repair path
    recompiled: bool = False
    survivors: int = 0
    mode: str = "shrink"                 # recovery mode that produced this plan
    substitutions: tuple[tuple[int, int], ...] = ()   # (failed, spare) splices
    unfilled: tuple[int, ...] = ()       # failed slots shrunk for lack of spares

    @property
    def substitution_map(self) -> dict[int, int]:
        return dict(self.substitutions)

    def summary(self) -> str:
        kind = "hierarchical" if self.hierarchical else "flat"
        role = "master" if self.master_failed else "worker"
        sub = f" subs={list(self.substitutions)}" if self.substitutions else ""
        return (f"[repair/{kind}/{self.mode}] failed={list(self.trigger)} "
                f"role={role} stages={len(self.steps)} "
                f"model_cost={self.model_cost:.4f}s "
                f"wall={self.wall_seconds * 1e3:.2f}ms "
                f"survivors={self.survivors}{sub}")


@dataclass
class ClusterClock:
    """Simulated time accumulator (repair cost model) + real wall time."""
    sim_seconds: float = 0.0

    def charge(self, seconds: float) -> None:
        self.sim_seconds += seconds
