"""Mesh management: survivors -> jax.Mesh, live-state resharding, compile cache.

ULFM's shrink hands back a working communicator; the XLA analogue has three
parts (the actual cost of "shrink" on a TPU cluster — see DESIGN.md §2):

  (a) rebuild the collective topology  -> a new ``jax.Mesh`` over survivor
      devices (a failed node removes its whole host = its ICI slice);
  (b) reshard live state               -> ``jax.device_put`` of params/opt
      state onto the new mesh (GSPMD moves only the shards that must move);
  (c) recompile                        -> re-lower the step for the new mesh;
      memoized in :class:`CompileCache` so a *re-grown* cluster (elastic
      regrow back to a previously-seen size) reuses the old executable.

A node owns ``chips_per_node`` consecutive devices. The data-parallel axis
spans nodes; the model axis spans chips within a node, so node failure only
ever shrinks the data axis — the model axis (which would split tensors) is
never fractured by a fault. This mirrors the paper's setting where each MPI
rank's loss removes one worker, not a slice of a tensor.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclass
class DevicePool:
    """Logical node -> device mapping over the available jax devices.

    With fewer physical devices than nodes (the CPU container), multiple
    logical nodes map onto the same device — collective *structure* is still
    exercised; placement is virtual. With enough devices (dry-run's 512, or
    real TPUs) the mapping is 1:1 and meshes are physical.
    """

    n_nodes: int
    chips_per_node: int = 1
    n_spares: int = 0          # warm spares hold devices too (they idle warm)
    devices: list = field(default_factory=lambda: list(jax.devices()))

    def node_devices(self, node: int) -> list:
        want = self.chips_per_node
        n_dev = len(self.devices)
        if self.total_nodes * want <= n_dev:
            return self.devices[node * want:(node + 1) * want]
        return [self.devices[(node * want + j) % n_dev] for j in range(want)]

    @property
    def total_nodes(self) -> int:
        """Initial workers plus the provisioned spare slots: a substituted
        spare must map onto real devices just like the node it replaces."""
        return self.n_nodes + self.n_spares

    @property
    def physical(self) -> bool:
        return self.total_nodes * self.chips_per_node <= len(self.devices)


class MeshManager:
    """Builds survivor meshes and reshards live state after repair."""

    def __init__(self, pool: DevicePool, *, model_axis: int | None = None):
        self.pool = pool
        self.model_axis = model_axis or pool.chips_per_node

    def survivor_mesh(self, survivors: list[int]) -> Mesh:
        """Mesh over the survivors' devices: (data=len(survivors), model=chips).

        Falls back to a (1, 1) virtual mesh when the pool is not physical
        (CPU container) — the logical shrink still happens at the batch/
        topology layer; see executor.
        """
        survivors = sorted(survivors)
        if self.pool.physical:
            devs = np.array(
                [self.pool.node_devices(n) for n in survivors], dtype=object
            ).reshape(len(survivors), self.model_axis)
            return Mesh(devs, ("data", "model"))
        n_dev = len(self.pool.devices)
        dp = min(len(survivors), n_dev)
        devs = np.array(self.pool.devices[:dp], dtype=object).reshape(dp, 1)
        return Mesh(devs, ("data", "model"))

    @staticmethod
    def reshard(tree: PyTree, mesh: Mesh, specs: PyTree) -> PyTree:
        """Move live state onto a (new) mesh. GSPMD computes the minimal
        redistribution; for a pure data-axis shrink the param shards that
        lived on survivors stay put."""
        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(tree, shardings)


@dataclass
class CompileRecord:
    compiled: Any
    lower_seconds: float
    compile_seconds: float
    hits: int = 0


class CompileCache:
    """Memoizes jitted executables by (fn, mesh shape, input avals).

    Elastic regrow returns the cluster to a previously-seen size; the repair
    then skips (c) entirely — the dominant term of S(x) for big programs.
    """

    def __init__(self):
        self._store: dict[tuple, CompileRecord] = {}

    @staticmethod
    def _aval_key(tree: PyTree) -> tuple:
        leaves = jax.tree.leaves(tree)
        return tuple((l.shape, str(l.dtype)) for l in leaves)

    def key(self, tag: str, mesh: Mesh, *trees: PyTree) -> tuple:
        return (tag, tuple(mesh.devices.shape), tuple(mesh.axis_names),
                tuple(self._aval_key(t) for t in trees))

    def get(self, key: tuple) -> CompileRecord | None:
        rec = self._store.get(key)
        if rec is not None:
            rec.hits += 1
        return rec

    def put(self, key: tuple, compiled: Any, lower_s: float, compile_s: float
            ) -> CompileRecord:
        rec = CompileRecord(compiled, lower_s, compile_s)
        self._store[key] = rec
        return rec

    def lower_and_compile(self, tag: str, mesh: Mesh, jitted, *args) -> tuple[Any, bool]:
        """Returns (compiled-or-jitted callable, cache_hit)."""
        key = self.key(tag, mesh, args)
        rec = self.get(key)
        if rec is not None:
            return rec.compiled, True
        t0 = time.perf_counter()
        lowered = jitted.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        self.put(key, compiled, t1 - t0, t2 - t1)
        return compiled, False

    def stats(self) -> dict:
        return {
            "entries": len(self._store),
            "hits": sum(r.hits for r in self._store.values()),
            "compile_seconds": sum(r.compile_seconds for r in self._store.values()),
        }
