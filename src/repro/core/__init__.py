"""Legio core — the paper's contribution as a composable JAX runtime.

Layering (paper section -> module):
  §V  hierarchy      legions / masters / POVs / ring, topology epochs + views
  §III detector      heartbeats, noticing semantics (BNP), stragglers
  §IV agreement      fault agreement (BNP fix), in-program bitmap psum
  §IV pipeline       detect → notice → agree → plan → apply fault pipeline
  —   strategy       RecoveryStrategy registry (shrink / substitute / …)
  §V  shrink         S(x) cost model, Eq. 1-4, Fig. 3 repair plans
  —   substitute     warm spare pool, substitution repair, elastic provisioner
  §V  collectives    hierarchical op schedules + shard_map psum variants
  §IV batch          DROP / REBALANCE shard reassignment
  —   mesh_manager   survivors -> jax.Mesh, reshard, compile cache
  §IV executor       transparent orchestration draining the pipeline
  §VII cr            per-legion C/R, restart-only-failed
  —   trainer        SPMD resilient training integration

Applications do not consume these pieces directly: the MPI-shaped surface
they program against is :mod:`repro.mpi` (Session/Comm — the paper's PMPI
interposition seam); everything here is the machinery behind it.
"""
from repro.core.agreement import agree_fault, agreement_rounds, liveness_psum
from repro.core.batch import (
    BatchPlan,
    gradient_scale,
    initial_assignment,
    reassign,
    restore_rank,
    substitute_assign,
    validate_plan,
)
from repro.core.chaos import (
    ChaosHarness,
    ChaosReport,
    InvariantCheck,
    check_topology_coherence,
)
from repro.core.collectives import (
    HierarchicalCollectives,
    LinkModel,
    agreement_time,
    flat_collective_time,
)
from repro.core.cr import LegionCheckpointer
from repro.core.detector import (
    FaultInjector,
    HeartbeatDetector,
    StragglerDetector,
    notice_fault,
)
from repro.core.executor import (
    LegioExecutor,
    RootFailedError,
    StepReport,
    VirtualCluster,
)
from repro.core.faultmodel import ChaosEvent, FaultCampaign, FaultModel
from repro.core.hierarchy import (
    Legion,
    LegionTopology,
    LevelGroup,
    StaleLegionError,
    TopologyTornError,
    TopologyView,
    make_topology,
)
from repro.core.mesh_manager import CompileCache, DevicePool, MeshManager
from repro.core.pipeline import FaultPipeline
from repro.core.policy import (
    RECOVERY_MODES,
    LegioPolicy,
    eq3_s_of_k,
    eq4_s_of_k,
    optimal_k_linear,
    optimal_k_quadratic,
    optimal_kd,
)
from repro.core.shrink import ShrinkCostModel, ShrinkEngine, failures_by_legion
from repro.core.strategy import (
    AdaptiveDecision,
    CostModelStrategy,
    NonblockingSubstituteStrategy,
    RecoveryStrategy,
    ShrinkStrategy,
    SubstituteStrategy,
    available_strategies,
    make_strategy,
    register_strategy,
)
from repro.core.substitute import (
    PendingSubstitution,
    RestoreOutcome,
    SparePool,
    SparePoolExhausted,
    SpareProvisioner,
    SubstituteCostModel,
    SubstituteEngine,
    UnfilledSlot,
    restore_for_substitute,
    restore_member_state,
)
from repro.core.trainer import ResilientTrainer, TrainerReport, make_train_step
from repro.core.types import (
    ChaosAction,
    FailureEvent,
    FailureKind,
    FaultEvent,
    FaultSource,
    NodeState,
    OpStatus,
    PipelineTrace,
    RecoveryAction,
    RepairReport,
    RepairScope,
    RepairStep,
)

__all__ = [
    "AdaptiveDecision", "BatchPlan", "ChaosAction", "ChaosEvent",
    "ChaosHarness", "ChaosReport",
    "CompileCache", "CostModelStrategy", "DevicePool",
    "FailureEvent", "FailureKind",
    "FaultCampaign", "FaultEvent", "FaultInjector", "FaultModel",
    "FaultPipeline", "FaultSource",
    "HeartbeatDetector", "HierarchicalCollectives", "InvariantCheck",
    "Legion", "LegionCheckpointer", "LegionTopology", "LegioExecutor",
    "LegioPolicy", "LevelGroup", "LinkModel", "MeshManager", "NodeState",
    "NonblockingSubstituteStrategy", "OpStatus", "PendingSubstitution",
    "PipelineTrace", "RECOVERY_MODES", "RecoveryAction", "RecoveryStrategy",
    "RepairReport",
    "RepairScope", "RepairStep", "ResilientTrainer", "RestoreOutcome",
    "RootFailedError",
    "ShrinkCostModel", "ShrinkEngine", "ShrinkStrategy", "SparePool",
    "SparePoolExhausted", "SpareProvisioner", "StaleLegionError",
    "StepReport", "StragglerDetector",
    "SubstituteCostModel", "SubstituteEngine", "SubstituteStrategy",
    "TopologyTornError", "TopologyView", "TrainerReport", "UnfilledSlot",
    "VirtualCluster", "agree_fault", "agreement_rounds", "agreement_time",
    "available_strategies", "check_topology_coherence",
    "failures_by_legion", "flat_collective_time",
    "gradient_scale",
    "initial_assignment", "liveness_psum",
    "make_strategy", "make_topology", "make_train_step", "notice_fault",
    "optimal_k_linear", "optimal_k_quadratic", "optimal_kd",
    "eq3_s_of_k", "eq4_s_of_k",
    "reassign", "register_strategy", "restore_for_substitute",
    "restore_member_state", "restore_rank",
    "substitute_assign", "validate_plan",
]
