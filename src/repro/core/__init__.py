"""Legio core — the paper's contribution as a composable JAX runtime.

Layering (paper section -> module):
  §V  hierarchy      legions / masters / POVs / ring
  §III detector      heartbeats, noticing semantics (BNP), stragglers
  §IV agreement      fault agreement (BNP fix), in-program bitmap psum
  §V  shrink         S(x) cost model, Eq. 1-4, Fig. 3 repair plans
  —   substitute     warm spare pool, slot-preserving substitution repair
  §V  collectives    hierarchical op schedules + shard_map psum variants
  §IV batch          DROP / REBALANCE shard reassignment
  —   mesh_manager   survivors -> jax.Mesh, reshard, compile cache
  §IV executor       transparent run -> detect -> agree -> repair loop
  §VII cr            per-legion C/R, restart-only-failed
  —   trainer        SPMD resilient training integration
"""
from repro.core.agreement import agree_fault, agreement_rounds, liveness_psum
from repro.core.batch import (
    BatchPlan,
    gradient_scale,
    initial_assignment,
    reassign,
    restore_rank,
    substitute_assign,
)
from repro.core.collectives import (
    HierarchicalCollectives,
    LinkModel,
    agreement_time,
    flat_collective_time,
    hierarchical_psum,
    hierarchical_psum_scatter,
    make_hierarchical_allreduce,
)
from repro.core.cr import LegionCheckpointer
from repro.core.detector import (
    FaultInjector,
    HeartbeatDetector,
    StragglerDetector,
    notice_fault,
)
from repro.core.executor import (
    LegioExecutor,
    RootFailedError,
    StepReport,
    VirtualCluster,
)
from repro.core.hierarchy import Legion, LegionTopology, make_topology
from repro.core.mesh_manager import CompileCache, DevicePool, MeshManager
from repro.core.policy import (
    LegioPolicy,
    eq3_s_of_k,
    eq4_s_of_k,
    optimal_k_linear,
    optimal_k_quadratic,
)
from repro.core.shrink import ShrinkCostModel, ShrinkEngine, failures_by_legion
from repro.core.substitute import (
    PendingSubstitution,
    SparePool,
    SparePoolExhausted,
    SubstituteCostModel,
    SubstituteEngine,
    restore_for_substitute,
)
from repro.core.trainer import ResilientTrainer, TrainerReport, make_train_step
from repro.core.types import (
    FailureEvent,
    FailureKind,
    NodeState,
    OpStatus,
    RepairReport,
    RepairStep,
)

__all__ = [
    "BatchPlan", "CompileCache", "DevicePool", "FailureEvent", "FailureKind",
    "FaultInjector", "HeartbeatDetector", "HierarchicalCollectives",
    "Legion", "LegionCheckpointer", "LegionTopology", "LegioExecutor",
    "LegioPolicy", "LinkModel", "MeshManager", "NodeState", "OpStatus",
    "PendingSubstitution", "RepairReport", "RepairStep", "ResilientTrainer",
    "RootFailedError", "ShrinkCostModel", "ShrinkEngine", "SparePool",
    "SparePoolExhausted", "StepReport", "StragglerDetector",
    "SubstituteCostModel", "SubstituteEngine", "TrainerReport",
    "VirtualCluster", "agree_fault", "agreement_rounds",
    "agreement_time", "failures_by_legion", "flat_collective_time",
    "gradient_scale", "hierarchical_psum", "hierarchical_psum_scatter",
    "initial_assignment", "liveness_psum", "make_hierarchical_allreduce",
    "make_topology", "make_train_step", "notice_fault", "optimal_k_linear",
    "optimal_k_quadratic", "eq3_s_of_k", "eq4_s_of_k", "reassign",
    "restore_for_substitute", "restore_rank", "substitute_assign",
]
