"""FaultModel — seeded, correlated-failure campaigns from named scenarios.

Every fault the stack injected before this module was an independent
single-node death, but the paper's target clusters fail in *patterns*:
racks lose power, switches gray-fail into partitions, flapping nodes
come back after the repair already evicted them, and a repair's own load
pushes neighbours over the straggler threshold. "To Repair or Not to
Repair" (PAPERS.md) argues a recovery policy can only be judged against
realistic fault distributions; this module generates them, determin-
istically, as data — a :class:`FaultCampaign` is a seeded, replayable
list of timed :class:`ChaosEvent`\\ s that the
:class:`~repro.core.chaos.ChaosHarness` applies against a live
``Session``-driven workload.

Scenario presets (``FaultModel.SCENARIOS``):

``independent``
    Today's baseline: uncorrelated single-node deaths, one per step,
    covering ``LegioPolicy.chaos_fault_fraction`` of the cluster.
``rack_outage``
    A whole legion dies at once — the failure domain the topology was
    aligned with. The rack is resolved against the *initial* topology
    via :meth:`LegionTopology.subtree_of` and deliberately chosen to be
    an **interior** legion (its master is not also the parent group's
    master), so the repair stays confined to one top-level subtree and
    healthy subtrees contribute exactly zero participants. Multiple
    racks land in distinct top-level subtrees → disjoint RepairScopes
    in one drain.
``network_partition``
    A switch splits the cluster: each side suspects the *other* side,
    emitted as one-sided :attr:`ChaosAction.SUSPECT` events whose
    ``observers`` field carries only that side's membership (the
    correlated channel ``FaultPipeline.observe_suspicion`` feeds).
    With ``chaos_partition_fence`` the minority side is also crashed
    (ground truth) — agreement's union over *live* observers then kills
    the minority's symmetric accusation and both sides converge on one
    verdict. Unfenced symmetric suspicion is the documented hazard: the
    union would bury everyone (see docs/fault-models.md).
``transient_flap``
    A node crashes, is repaired out, then *returns*
    ``chaos_flap_delay_steps`` later (:attr:`ChaosAction.FLAP_RETURN`)
    and tries to re-register with its old identity — the event the
    :class:`HeartbeatDetector` epoch guard must refuse, and which must
    not consume :class:`SpareProvisioner` churn-cap budget.
``cascade``
    A primary master crash whose repair load pushes
    ``chaos_cascade_victims`` of the *would-be scope participants* over
    the straggler threshold (:attr:`ChaosAction.SLOWDOWN` inflates
    their observed latencies by ``chaos_cascade_slowdown``) — secondary
    soft-fails surface through the STRAGGLER channel in later drains.

Campaigns are pure data and reproducible: the generator is
``np.random.default_rng((seed, scenario, n))`` — the same
:class:`FaultModel` produces byte-identical campaigns for the same
arguments, across processes and runs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.detector import FaultInjector
from repro.core.hierarchy import LegionTopology, make_topology
from repro.core.policy import LegioPolicy
from repro.core.types import ChaosAction, FailureEvent, FailureKind

__all__ = ["ChaosEvent", "FaultCampaign", "FaultModel"]


@dataclass(frozen=True)
class ChaosEvent:
    """One timed campaign event. ``nodes`` are the targets; ``observers``
    is non-empty only for SUSPECT (who holds the one-sided suspicion);
    ``factor`` only matters for SLOWDOWN (latency multiplier)."""

    step: int
    action: ChaosAction
    nodes: tuple[int, ...]
    observers: tuple[int, ...] = ()
    factor: float = 1.0


@dataclass(frozen=True)
class FaultCampaign:
    """A replayable, seeded schedule of correlated chaos events."""

    scenario: str
    seed: int
    n_nodes: int
    events: tuple[ChaosEvent, ...]
    meta: dict = field(default_factory=dict)

    @property
    def horizon(self) -> int:
        """Last step any event fires at (drive the workload past this)."""
        return max((e.step for e in self.events), default=0)

    @property
    def crashed(self) -> tuple[int, ...]:
        """Ground-truth dead nodes across the whole campaign."""
        return tuple(sorted({n for e in self.events
                             if e.action is ChaosAction.CRASH
                             for n in e.nodes}))

    def at(self, step: int) -> list[ChaosEvent]:
        return [e for e in self.events if e.step == step]

    def injector(self) -> FaultInjector:
        """The CRASH events as a ground-truth :class:`FaultInjector`
        schedule (the non-crash actions are applied by the harness
        through their own channels)."""
        return FaultInjector([
            FailureEvent(node=n, step=e.step, kind=FailureKind.CRASH)
            for e in self.events if e.action is ChaosAction.CRASH
            for n in e.nodes])

    def summary(self) -> str:
        kinds = {}
        for e in self.events:
            kinds[e.action.value] = kinds.get(e.action.value, 0) + 1
        parts = ", ".join(f"{v}×{k}" for k, v in sorted(kinds.items()))
        return (f"campaign({self.scenario}, seed={self.seed}, "
                f"n={self.n_nodes}, events=[{parts}])")


class FaultModel:
    """Generates :class:`FaultCampaign`\\ s from named scenario presets.

    Scenario knobs come from the policy's ``chaos_*`` fields; per-call
    keyword overrides (e.g. ``racks=2``) refine a single campaign.
    """

    SCENARIOS = ("independent", "rack_outage", "network_partition",
                 "transient_flap", "cascade")

    def __init__(self, policy: LegioPolicy | None = None, seed: int = 0):
        self.policy = policy or LegioPolicy()
        self.seed = seed

    def campaign(self, scenario: str, n_nodes: int, *, at_step: int = 3,
                 **knobs) -> FaultCampaign:
        if scenario not in self.SCENARIOS:
            raise ValueError(f"unknown scenario {scenario!r}; "
                             f"choose from {self.SCENARIOS}")
        if n_nodes < 2:
            raise ValueError("chaos campaigns need at least 2 nodes")
        rng = np.random.default_rng(
            (self.seed, self.SCENARIOS.index(scenario), n_nodes))
        events, meta = getattr(self, f"_{scenario}")(
            rng, n_nodes, at_step, **knobs)
        events = tuple(sorted(events, key=lambda e: (e.step, e.action.value,
                                                     e.nodes)))
        return FaultCampaign(scenario=scenario, seed=self.seed,
                            n_nodes=n_nodes, events=events, meta=meta)

    # -- shared topology resolution ---------------------------------------

    def _topo(self, n: int) -> LegionTopology:
        """The *initial* topology the campaign targets are resolved
        against — chaos is scheduled before the workload starts, exactly
        like a real fault plan drawn against the cluster's rack map."""
        return make_topology(list(range(n)), self.policy)

    @staticmethod
    def _interior_legions(topo: LegionTopology) -> list[tuple[int, int]]:
        """``(legion index, top-level subtree)`` for every legion that is
        strictly interior to its level-1 parent group: not the first child
        (its master would also hold the parent mastership, so its death
        climbs out of the subtree) and not the last child (its successor
        POV at level 0 would pull the next group's master in). Killing a
        strictly interior legion keeps every repair participant inside one
        top-level subtree — the property the rack scenario (and the
        healthy-subtree-participation = 0 acceptance bar) is built on."""
        if topo.depth <= 1:
            return []
        out = []
        for parent in topo.levels()[0]:          # level-1 groups
            first, last = min(parent.children), max(parent.children)
            out.extend((ci, topo.subtree_of(ci))
                       for ci in parent.children
                       if ci != first and ci != last)
        return out

    @staticmethod
    def _subtree_members(topo: LegionTopology) -> dict[int, list[int]]:
        """Top-level subtree index -> sorted member node ids."""
        sides: dict[int, list[int]] = {}
        for lg in topo.legions:
            sides.setdefault(topo.subtree_of(lg.index),
                             []).extend(lg.members)
        return {st: sorted(ms) for st, ms in sides.items()}

    # -- presets ------------------------------------------------------------

    def _independent(self, rng, n: int, at_step: int,
                     fraction: float | None = None):
        """Uncorrelated single-node deaths — the pre-PR-6 baseline."""
        frac = (self.policy.chaos_fault_fraction if fraction is None
                else fraction)
        count = min(max(1, round(frac * n)), n - 2)
        victims = sorted(int(v) for v in
                         rng.choice(np.arange(1, n), size=count,
                                    replace=False))
        events = [ChaosEvent(step=at_step + i, action=ChaosAction.CRASH,
                             nodes=(v,))
                  for i, v in enumerate(victims)]
        return events, {"victims": victims}

    def _rack_outage(self, rng, n: int, at_step: int, racks: int = 1):
        """Whole-legion death, rack = interior legion, one distinct
        top-level subtree per rack — disjoint scopes in a single drain."""
        topo = self._topo(n)
        cands = self._interior_legions(topo)
        if not cands:
            raise ValueError(
                f"rack_outage needs a hierarchical topology with interior "
                f"legions (n={n} builds depth {topo.depth} with "
                f"{topo.n_legions} legions)")
        by_subtree: dict[int, list[int]] = {}
        for li, st in cands:
            by_subtree.setdefault(st, []).append(li)
        if racks > len(by_subtree):
            raise ValueError(
                f"{racks} racks need {racks} distinct top-level subtrees "
                f"with interior legions; only {len(by_subtree)} available")
        subtrees = sorted(int(s) for s in
                          rng.choice(sorted(by_subtree), size=racks,
                                     replace=False))
        chosen, members_of = [], {}
        for st in subtrees:
            li = int(rng.choice(sorted(by_subtree[st])))
            chosen.append(li)
            members_of[li] = list(next(lg.members for lg in topo.legions
                                       if lg.index == li))
        events = [ChaosEvent(step=at_step, action=ChaosAction.CRASH,
                             nodes=tuple(members_of[li]))
                  for li in chosen]
        return events, {"racks": [
            {"legion": li, "subtree": st, "members": members_of[li]}
            for li, st in zip(chosen, subtrees)]}

    def _network_partition(self, rng, n: int, at_step: int,
                           fence: bool | None = None):
        """Two-sided suspicion across a subtree boundary; the minority is
        fenced (crashed) so agreement can converge."""
        fence = (self.policy.chaos_partition_fence if fence is None
                 else fence)
        topo = self._topo(n)
        sides = self._subtree_members(topo)
        root_master = min(topo.nodes)
        cands = sorted(st for st, ms in sides.items()
                       if root_master not in ms)
        if cands:
            minority_st = int(rng.choice(cands))
            minority = sides[minority_st]
            meta_side = {"subtree": minority_st}
        else:
            # flat / single-subtree cluster: partition a random quarter off
            count = max(1, n // 4)
            pool = np.asarray([x for x in topo.nodes if x != root_master])
            minority = sorted(int(v) for v in
                              rng.choice(pool, size=min(count, len(pool)),
                                         replace=False))
            meta_side = {"subtree": None}
        majority = sorted(set(topo.nodes) - set(minority))
        events = [
            ChaosEvent(step=at_step, action=ChaosAction.SUSPECT,
                       nodes=tuple(minority), observers=tuple(majority)),
            ChaosEvent(step=at_step, action=ChaosAction.SUSPECT,
                       nodes=tuple(majority), observers=tuple(minority)),
        ]
        if fence:
            events.append(ChaosEvent(step=at_step, action=ChaosAction.CRASH,
                                     nodes=tuple(minority)))
        return events, {"minority": minority, "majority": majority,
                        "fenced": fence, **meta_side}

    def _transient_flap(self, rng, n: int, at_step: int,
                        delay: int | None = None):
        """Crash, repair-out, then a stale return the epoch guard must
        refuse — and which must not burn SpareProvisioner churn budget."""
        delay = (self.policy.chaos_flap_delay_steps if delay is None
                 else delay)
        topo = self._topo(n)
        workers = [m for lg in topo.legions for m in lg.members
                   if m != lg.master]
        pool = workers or [m for m in topo.nodes if m != min(topo.nodes)]
        victim = int(rng.choice(np.asarray(sorted(pool))))
        return_step = at_step + delay
        events = [
            ChaosEvent(step=at_step, action=ChaosAction.CRASH,
                       nodes=(victim,)),
            ChaosEvent(step=return_step, action=ChaosAction.FLAP_RETURN,
                       nodes=(victim,)),
        ]
        return events, {"victim": victim, "return_step": return_step}

    def _cascade(self, rng, n: int, at_step: int,
                 victims: int | None = None, slowdown: float | None = None):
        """Primary master crash whose repair load slows scope neighbours
        past the straggler threshold — secondary soft-fails follow."""
        victims = (self.policy.chaos_cascade_victims if victims is None
                   else victims)
        slowdown = (self.policy.chaos_cascade_slowdown if slowdown is None
                    else slowdown)
        topo = self._topo(n)
        interior = self._interior_legions(topo)
        if interior:
            li = int(rng.choice(sorted(l for l, _ in interior)))
            primary = next(lg.master for lg in topo.legions
                           if lg.index == li)
        else:
            pool = [m for m in topo.nodes if m != min(topo.nodes)]
            primary = int(rng.choice(np.asarray(pool)))
        scope = topo.partition_scopes({primary})[0]
        pool = np.asarray(scope.participants)
        count = min(victims, len(pool))
        secondaries = sorted(int(v) for v in
                             rng.choice(pool, size=count, replace=False)
                             ) if count else []
        events = [ChaosEvent(step=at_step, action=ChaosAction.CRASH,
                             nodes=(primary,))]
        if secondaries:
            events.append(ChaosEvent(
                step=at_step, action=ChaosAction.SLOWDOWN,
                nodes=tuple(secondaries), factor=float(slowdown)))
        return events, {"primary": primary, "secondaries": secondaries,
                        "scope_participants": list(scope.participants),
                        "slowdown": float(slowdown)}
