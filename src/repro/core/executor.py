"""LegioExecutor — the transparent fault-resiliency loop (paper §IV).

Communication goes exclusively through the ``repro.mpi`` facade: the
executor holds a :class:`repro.mpi.Session` over its cluster and issues the
step-final collective as an ordinary MPI-shaped call on the world
:class:`repro.mpi.Comm`. The PMPI-style interposition inside that call owns
everything Legio owns in MPI — trapping the simulated PROC_FAILED, draining
the FaultPipeline (detect → notice → agree → plan → apply), applying the
registered RecoveryStrategy, and retrying on the repaired communicator —
so ``run_step`` is orchestration only:

  1. step boundary (``Session.boundary``): the SpareProvisioner delivers
     re-spawned spares (elastic refill, the MPI_Comm_spawn analogue),
     warmed-up non-blocking substitutes rejoin, and ground-truth faults
     land;
  2. per-node shard work (EP: no interaction until the final collective);
  3. the step-final collective runs on the comm — faults are repaired
     inside the call, before the schedule re-runs against a pinned
     TopologyView (paper §IV: check after the op; if confirmed repair,
     repeat). A failed op *root* surfaces per policy: STOP raises
     RootFailedError from the gate, IGNORE skips the op for the step
     (the facade's PeerFailedError, caught here);
  4. the straggler channel drains through the same pipeline
     (``Session.poll`` — soft-fails routed through the same strategies),
     and the StepReport surfaces every action the session recorded.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import numpy as np

from repro.core.batch import (
    BatchPlan,
    initial_assignment,
    restore_rank,
    validate_plan,
)
from repro.core.collectives import HierarchicalCollectives, LinkModel
from repro.core.detector import FaultInjector, HeartbeatDetector, StragglerDetector
from repro.core.hierarchy import LegionTopology, TopologyView, make_topology
from repro.core.pipeline import FaultPipeline
from repro.core.policy import LegioPolicy
from repro.core.shrink import ShrinkEngine
from repro.core.strategy import RecoveryStrategy, make_strategy
from repro.core.substitute import (
    PendingSubstitution,
    SparePool,
    SparePoolExhausted,
    SpareProvisioner,
    SubstituteEngine,
    UnfilledSlot,
    restore_member_state,
)
from repro.core.types import (
    BackgroundRepair,
    ClusterClock,
    FailureEvent,
    FaultSource,
    RecoveryAction,
    RepairReport,
    RepairScope,
    RepairStep,
)


class RootFailedError(RuntimeError):
    """Raised under the STOP policy when an operation's root has failed."""


@dataclass
class StepReport:
    step: int
    results: dict[int, Any]                  # node -> shard work output
    reduced: Any | None                      # step-final collective output
    failed_now: tuple[int, ...] = ()         # every node repaired this step
    repair: RepairReport | None = None       # first crash repair (back-compat)
    actions: tuple[RecoveryAction, ...] = () # all terminal pipeline actions
    skipped_op: bool = False                 # IGNORE policy fired
    sim_collective_seconds: float = 0.0
    wall_seconds: float = 0.0
    grad_scale: float = 1.0
    expanded: tuple[tuple[int, int], ...] = ()  # non-blocking splices applied
    respawned: tuple[int, ...] = ()          # provisioner deliveries this step
    repairing: tuple[int, ...] = ()          # survivors busy in an overlap
                                             # window (excluded this step)
    reconciled: tuple[RepairScope, ...] = () # windows merged at the boundary


class VirtualCluster:
    """A simulated cluster: N logical nodes, ground-truth failure state,
    simulated clock, and the Legio substitute structures."""

    def __init__(
        self,
        n_nodes: int,
        policy: LegioPolicy | None = None,
        injector: FaultInjector | None = None,
        link: LinkModel | None = None,
        shards_per_node: int = 1,
        checkpointer: Any = None,       # LegionCheckpointer (state restoration)
    ):
        self.policy = policy or LegioPolicy()
        self.injector = injector or FaultInjector()
        self.link = link or LinkModel()
        self.nodes = list(range(n_nodes))
        self.n_initial = n_nodes
        self.topo: LegionTopology = make_topology(self.nodes, self.policy)
        self.detector = HeartbeatDetector(timeout=self.policy.heartbeat_timeout)
        for n in self.nodes:
            self.detector.register(n)
        self.straggler = StragglerDetector(threshold=self.policy.straggler_threshold)
        self.shrink = ShrinkEngine(self.policy)
        self.substitute = SubstituteEngine(self.policy)
        self.strategy: RecoveryStrategy = make_strategy(self.policy)
        self.clock = ClusterClock()
        self.failed: set[int] = set()            # ground truth (hidden from app)
        self.plan: BatchPlan = initial_assignment(self.nodes, shards_per_node)
        self.shards_per_node = shards_per_node
        self.total_shards = n_nodes * shards_per_node
        self.spare_pool = SparePool.provision(n_nodes, self.policy)
        self.provisioner = SpareProvisioner.for_pool(
            n_nodes, self.spare_pool, self.policy)
        self.backlog: list[UnfilledSlot] = []    # shrunk slots awaiting refill
        self.pending: list[PendingSubstitution] = []
        self.pipeline = FaultPipeline(self)
        self.background: list[BackgroundRepair] = []  # in-flight overlap windows
        # peer-replicated shard checkpoints: lazy import keeps repro.core
        # importable without pulling repro.checkpoint into the module graph
        from repro.checkpoint.replicate import ShardReplicator
        self.replicator = ShardReplicator(
            link=self.link, enabled=self.policy.peer_replication,
            cluster=self)
        self.checkpointer = checkpointer
        self.restored_state: dict[int, Any] = {}  # this step's splices only
        self._restored_step = -1
        self.repairs: list[RepairReport] = []
        self._step = 0
        # error-feedback residuals for compressed cross-legion reduction
        self.compress_residuals: dict[int, Any] = {}
        # data plane: what moves the bytes behind the scheduled collectives
        # (policy.data_plane — sim | jax | auto); lazy import keeps the
        # module graph acyclic (dist.dataplane never imports repro.core)
        from repro.dist.dataplane import make_dataplane
        self.dataplane = make_dataplane(self.policy)
        self.reshards: list[Any] = []   # ReshardReport log (jax plane)

    @property
    def spares(self) -> list[int]:
        """Warm spares still available (legacy view of the pool)."""
        return self.spare_pool.available

    @property
    def checkpointer(self) -> Any:
        return self._checkpointer

    @checkpointer.setter
    def checkpointer(self, value: Any) -> None:
        """Attaching a checkpointer wires it to the cluster's replicator, so
        every ``save()`` also pushes shards to their POV-ring buddies."""
        self._checkpointer = value
        if value is not None:
            try:
                value.replicator = self.replicator
            except AttributeError:
                pass     # frozen/slotted stand-in: store-only checkpoints

    # -- fault plumbing ---------------------------------------------------------

    def inject(self, step: int) -> list[FailureEvent]:
        self._step = step
        events = self.injector.due(step)
        for e in events:
            if e.node in self.topo.nodes:
                self.failed.add(e.node)
            elif e.node in self.spare_pool.available:
                # a warm spare can die too — it must never be spliced in,
                # and the detector must bury it: without confirm_failed a
                # later stale beat auto-registered the dead spare HEALTHY
                self.failed.add(e.node)
                self.spare_pool.available.remove(e.node)
                self.detector.confirm_failed(e.node, epoch=self.topo.epoch)
            elif any(p.spare == e.node for p in self.pending):
                # died while warming up: reschedule the splice on the next
                # warm spare (fresh warmup); with the pool empty the slot
                # stays shrunk — fatal under strict substitute semantics
                self.failed.add(e.node)
                self.detector.confirm_failed(e.node, epoch=self.topo.epoch)
                dead = [p for p in self.pending if p.spare == e.node]
                self.pending = [p for p in self.pending if p.spare != e.node]
                for p in dead:
                    self.spare_pool.require(
                        1, self.policy.recovery_mode == "substitute")
                    replacement = self.spare_pool.take()
                    if replacement is None:
                        self.note_unfilled(UnfilledSlot(
                            failed=p.failed, legion=p.legion, shards=p.shards))
                        continue
                    self.pending.append(PendingSubstitution(
                        failed=p.failed, spare=replacement, legion=p.legion,
                        ready_step=step + 1 + self.policy.spare_warmup_steps,
                        shards=p.shards))
        return events

    def collectives(self, view: TopologyView | None = None
                    ) -> HierarchicalCollectives:
        return HierarchicalCollectives(
            view if view is not None else self.topo, self.link,
            compression=self.policy.grad_compression,
            topk_fraction=self.policy.topk_fraction,
            residuals=self.compress_residuals,
            dataplane=self.dataplane)

    @property
    def live_nodes(self) -> list[int]:
        return [n for n in self.topo.nodes if n not in self.failed]

    # -- repair (strategy dispatch) -------------------------------------------

    def _note_restored(self, spare: int, state: Any) -> None:
        """Record a splice's restored state, evicting previous steps' entries
        — consumers copy what they need within the step; unbounded retention
        would keep one full model+opt snapshot per fault for the campaign's
        lifetime."""
        if self._restored_step != self._step:
            self.restored_state.clear()
            self._restored_step = self._step
        self.restored_state[spare] = state

    def note_unfilled(self, slot: UnfilledSlot) -> None:
        """Remember a slot shrunk for lack of spares so the provisioner can
        heal it once replacements come up (no-op without elastic spares)."""
        if self.provisioner.enabled:
            self.backlog.append(slot)

    def repair(self, verdict: set[int],
               scope: "RepairScope | None" = None) -> RepairReport | None:
        """Apply the registered RecoveryStrategy for the agreed verdict.

        The strategy mutates the structures; this method owns the
        bookkeeping every strategy shares: detector confirmation, straggler
        eviction, clock charge, and the repair record. A strategy that
        raises after committing work (non-blocking strict exhaustion)
        attaches the committed report as ``partial_report`` — it is recorded
        before the error propagates, so the campaign log stays truthful.
        """
        if not verdict:
            return None
        if scope is None:
            scopes = self.topo.partition_scopes(set(verdict))
            scope = scopes[0] if len(scopes) == 1 else None
        try:
            report = self.strategy.repair(self, set(verdict))
        except SparePoolExhausted as exc:
            if exc.partial_report is not None:
                self._stamp_scope(exc.partial_report, scope)
                self._commit_repair(verdict, exc.partial_report)
            raise
        self._stamp_scope(report, scope)
        self._commit_repair(verdict, report)
        self._reshard_after_repair()
        return report

    def repair_scoped(self, scopes: "list[RepairScope]"
                      ) -> "list[tuple[RepairScope, RepairReport]]":
        """Apply the strategy once per disjoint :class:`RepairScope`.

        The scopes partition one drain's verdict into subtrees with
        pairwise-disjoint participant sets, so their repairs proceed
        concurrently: the simulated clock is charged the *maximum* scope
        cost, not the sum — healthy subtrees (and the faster of two
        concurrent repairs) never wait on an unrelated subtree's recovery
        (Bouteiller & Bosilca's non-blocking argument applied across
        subtrees). Bookkeeping per scope is identical to :meth:`repair`.

        Under ``policy.repair_overlap`` (revoke-then-repair) even the max
        is not charged synchronously: each scope's cost opens a
        :class:`BackgroundRepair` window on the simulated clock instead —
        the scope's survivors stay busy (schedules exclude them) until the
        clock, advanced by the healthy subtrees' own compute, passes
        ``finish_sim``; :meth:`reconcile_repairs` then merges the window
        with zero residual. A new scope whose participants or verdict
        touch an in-flight window serializes *behind* it (its window
        starts at the earlier window's finish), never observing a
        half-applied group.
        """
        out: list[tuple[RepairScope, RepairReport]] = []
        overlap = self.overlap_enabled
        worst = 0.0
        for scope in scopes:
            verdict = set(scope.verdict)
            if not verdict:
                continue
            try:
                report = self.strategy.repair(self, verdict)
            except SparePoolExhausted as exc:
                # strict-mode exhaustion is the documented overlap-unsafe
                # case: the fatal error must surface synchronously, so the
                # committed partial work is charged blocking
                if exc.partial_report is not None:
                    self._stamp_scope(exc.partial_report, scope)
                    self._commit_repair(verdict, exc.partial_report,
                                        charge=False)
                    worst = max(worst, exc.partial_report.model_cost)
                if worst:
                    self.clock.charge(worst)
                    self._refresh_liveness()
                raise
            self._stamp_scope(report, scope)
            self._commit_repair(verdict, report, charge=False)
            if overlap:
                self._open_window(scope, report)
            else:
                worst = max(worst, report.model_cost)
            out.append((scope, report))
        if worst:
            self.clock.charge(worst)
            self._refresh_liveness()
        if out:
            self._reshard_after_repair()
        return out

    # -- background (overlapped) repair ---------------------------------------

    @property
    def overlap_enabled(self) -> bool:
        """Revoke-then-repair is on iff the policy asks for it AND the
        registered strategy declares itself overlap-safe."""
        return (self.policy.repair_overlap
                and getattr(self.strategy, "overlap_safe", False))

    def _open_window(self, scope: RepairScope,
                     report: RepairReport) -> None:
        """Defer a scope's repair charge to a BackgroundRepair window. A
        window whose participants/verdict touch an in-flight one starts at
        that window's finish (serialized — busy survivors cannot enter a
        second repair mid-window); disjoint windows run concurrently."""
        now = self.clock.sim_seconds
        involved = set(scope.participants) | set(scope.verdict)
        start = now
        for br in self.background:
            if involved & (set(br.busy) | set(br.scope.verdict)):
                start = max(start, br.finish_sim)
        self.background.append(BackgroundRepair(
            scope=scope, report=report, start_step=self._step,
            start_sim=start, finish_sim=start + report.model_cost))

    def repairing_participants(self) -> set[int]:
        """Survivors busy in an in-flight background repair window —
        excluded from collective schedules and serve admission until
        :meth:`reconcile_repairs` merges their window."""
        return {n for br in self.background for n in br.busy}

    def reconcile_repairs(self, *, force: bool = False
                          ) -> list[BackgroundRepair]:
        """Merge background repair windows back into full membership —
        the deferred half of revoke-then-repair, run at every
        ``Session`` boundary.

        Without ``force`` only windows the clock has already passed merge
        (zero residual: the whole repair hid behind concurrent compute).
        With ``force`` (an explicit barrier, a rooted op on a busy root)
        every window merges *now* and the unhidden remainder is charged
        as residual wait — the price of synchronizing with a repair that
        had not finished."""
        now = self.clock.sim_seconds
        merged = [br for br in self.background
                  if force or br.done(now)]
        if not merged:
            return []
        self.background = [br for br in self.background
                           if br not in merged]
        # windows merge concurrently: a forced synchronization waits out
        # the *makespan* (max residual — serialized windows' finish times
        # already chain), and each window hides only the part of its cost
        # that actually elapsed behind compute before the merge
        waited = max(br.residual(now) for br in merged)
        for br in merged:
            self.clock.absorb(min(br.report.model_cost,
                                  max(0.0, now - br.start_sim)))
        if waited > 0.0:
            self.clock.wait(waited)
            # survivors collectively waited out the residual — their
            # heartbeat deadlines must not count the repair (same rule
            # as the blocking path's _refresh_liveness)
            self._refresh_liveness()
        return merged

    @staticmethod
    def _stamp_scope(report: RepairReport,
                     scope: "RepairScope | None") -> None:
        if scope is not None and report.scope is None:
            report.scope = scope
            report.repair_participants = scope.n_participants

    def _commit_repair(self, verdict: set[int], report: RepairReport,
                       charge: bool = True) -> None:
        for n in verdict:
            self.detector.confirm_failed(n, epoch=self.topo.epoch)
            self.straggler.drop(n)
        if charge:
            self.clock.charge(report.model_cost)
            self._refresh_liveness()
        self.repairs.append(report)

    # -- data-plane state redistribution --------------------------------------

    def register_sharded_state(self, name: str, getter: Callable[[], Any],
                               setter: Callable[[Any], None] | None = None
                               ) -> None:
        """Register a live-state pytree (via getter/setter) for post-repair
        redistribution on the data plane. On the jax plane every repair that
        changes membership triggers a mesh rebuild + one measured device_put
        pass over each registered tree (charged to the clock from wall
        time); on the sim plane this is bookkeeping only. Consumers call
        this — never the data plane directly — so backend selection stays
        behind LegioPolicy/Session."""
        self.dataplane.register_state(name, getter, setter)

    def _reshard_after_repair(self) -> None:
        """Redistribute registered state onto the survivors' mesh — the
        "Shrink or Substitute" observation operationalized: the real cost
        of in-situ recovery is data motion, so it is measured (wall time of
        the device_put pass), not modeled by the alpha-beta formula."""
        report = self.dataplane.reshard_registered(self.topo.view())
        if report is not None:
            self.reshards.append(report)
            self.clock.charge(report.wall_seconds)
            self._refresh_liveness()

    def _refresh_liveness(self) -> None:
        """Re-stamp every survivor's heartbeat after a repair charge. The
        repair is collective among the survivors (ULFM: everyone enters
        MPIX_Comm_shrink), so its simulated duration must not count
        against their heartbeat deadlines — without this, a repair whose
        S(x) cost exceeds heartbeat_timeout (a whole rack under
        substitution) made the next sweep condemn the entire cluster."""
        now = self.clock.sim_seconds
        for n in self.live_nodes:
            self.detector.beat(n, now)

    # -- deferred (non-blocking) substitution --------------------------------

    def poll_substitutions(self, step: int) -> list[RepairReport]:
        """Apply every pending splice whose warmup has elapsed — called at
        the step boundary, before new work is assigned. Re-expansion is a
        mini-repair of its own: an include into the home legion plus the
        (overlapped, hence uncharged) state restore."""
        ready = [p for p in self.pending if p.ready_step <= step]
        if not ready:
            return []
        self.pending = [p for p in self.pending if p.ready_step > step]
        self._step = step
        reports = []
        for p in ready:
            t0 = time.perf_counter()
            self.topo.expand(p.legion, p.spare)
            self.detector.register(p.spare, self.clock.sim_seconds)
            # peer-first ladder: the replica settled (or re-homed) during
            # the warmup window, so the splice warm-starts in O(shard)
            self._note_restored(
                p.spare, restore_member_state(self, p.legion, p.failed).state)
            self.plan = restore_rank(self.plan, p.spare, shards=p.shards)
            k = len(self.topo.legion_of(p.spare).members)
            steps = [RepairStep(op="substitute", comm=f"local_{p.legion}",
                                participants=(p.spare,),
                                cost_units=self.substitute.cost.splice_cost(k - 1))]
            report = RepairReport(
                trigger=(p.failed,),
                hierarchical=self.topo.n_legions > 1,
                master_failed=False,
                steps=steps,
                model_cost=sum(st.cost_units for st in steps),
                wall_seconds=time.perf_counter() - t0,
                survivors=self.topo.size,
                mode="substitute(nonblocking)",
                substitutions=((p.failed, p.spare),),
            )
            self.clock.charge(report.model_cost)
            self.repairs.append(report)
            reports.append(report)
        # the splices changed membership: the data-plane mesh regrows and
        # registered state spreads back over the rejoined devices
        self._reshard_after_repair()
        return reports

    # -- elastic spare re-spawn (provisioner stage) ---------------------------

    def poll_provisioner(self, step: int) -> list[int]:
        """Provisioner boundary stage: deliver due replacement spares, then
        feed refilled capacity back into slots shrunk during exhaustion —
        each healed slot goes through the same pending-splice path as a
        non-blocking substitution (warmup included), so assignment finality
        and master rules hold by construction."""
        if not self.provisioner.enabled:
            return []
        delivered = self.provisioner.poll(step)
        while self.backlog and self.spare_pool.available:
            slot = self.backlog.pop(0)
            spare = self.spare_pool.take()
            self.pending.append(PendingSubstitution(
                failed=slot.failed, spare=spare, legion=slot.legion,
                ready_step=step + self.policy.spare_warmup_steps,
                shards=slot.shards))
        # the backlog may have drained what poll() just delivered — re-check
        # the watermark now so replacement provisioning overlaps the healing
        # splices' warmup instead of losing a boundary
        self.provisioner.refill(step)
        return delivered


class LegioExecutor:
    """Runs per-shard work under transparent fault resiliency."""

    def __init__(
        self,
        cluster: VirtualCluster,
        work_fn: Callable[[int, int, int], Any],
        *,
        reduce_op: Callable[[Any, Any], Any] | None = None,
        final_collective: str = "allreduce",   # allreduce | reduce | bcast | none
        root: int = 0,
    ):
        # the facade is the only communication surface; lazy import keeps
        # repro.core importable without repro.mpi in the module graph
        from repro.mpi import Session

        self.cluster = cluster
        self.session = Session.adopt(cluster)
        self.comm = self.session.world
        # keyed: the world comm is shared per cluster — a rebuilt executor
        # replaces its hook instead of stacking another
        self.comm.attach(self._validate_pin, key="executor-validate-plan")
        self.work_fn = work_fn
        self.reduce_op = reduce_op or np.add
        self.final_collective = final_collective
        self.root = root
        self.step_count = 0
        self._skip_op = False

    # -- facade hooks (PMPI-style interposers) -----------------------------------

    def _validate_pin(self, op: str, view: TopologyView) -> None:
        """Interposer run on every comm call against the pinned view: the
        shard plan must agree with the structure the schedule reads."""
        validate_plan(self.cluster.plan, view)

    def _root_gate(self, verdict: set[int]) -> None:
        """Runs between agree and apply: the paper's root-failure knob.
        STOP raises before any repair mutates state; IGNORE lets the
        repair proceed — the facade then surfaces the dead root as
        PeerFailedError, which run_step turns into a skipped op."""
        if self.root in verdict and self.final_collective in ("bcast", "reduce"):
            if self.cluster.policy.root_failure_policy == "stop":
                raise RootFailedError(
                    f"root node {self.root} failed at step "
                    f"{self.cluster._step}")

    # -- step phases --------------------------------------------------------------

    def _work_phase(self, step: int) -> tuple[dict[int, Any], int]:
        """Per-node shard work; every live node heartbeats (idle nodes too —
        liveness is not throughput)."""
        cl = self.cluster
        results: dict[int, Any] = {}
        computed_shards = 0
        busy = cl.repairing_participants()
        for node in cl.live_nodes:
            cl.detector.beat(node, cl.clock.sim_seconds)
            if node in busy:
                continue        # occupied by a background repair window
            shards = cl.plan.shards_of(node)
            if not shards:
                continue
            t0 = time.perf_counter()
            out = [self.work_fn(node, s, step) for s in shards]
            results[node] = out[0] if len(out) == 1 else _sum_results(out)
            computed_shards += len(shards)
            cl.straggler.observe(node, time.perf_counter() - t0)
        return results, computed_shards

    def _collective_phase(self, results: dict[int, Any]
                          ) -> tuple[Any, float]:
        """Issue the step-final collective as one MPI-shaped call on the
        facade comm. The interposition inside the call traps PROC_FAILED,
        drains the crash channels (gated by the root-failure policy),
        repairs, and runs the schedule against a pinned TopologyView —
        the executor neither observes nor repairs anything itself."""
        from repro.mpi import PeerFailedError

        contributions = {n: np.asarray(v) for n, v in results.items()}
        try:
            if self.final_collective == "allreduce":
                res = self.comm.allreduce(contributions, self.reduce_op,
                                          gate=self._root_gate)
                members = self.comm.members
                reduced = res.data.get(members[0]) if members else None
            elif self.final_collective == "reduce":
                res = self.comm.reduce(contributions, self.root,
                                       self.reduce_op, gate=self._root_gate)
                reduced = next(iter(res.data.values()), None)
            elif self.final_collective == "bcast":
                res = self.comm.bcast(contributions, self.root,
                                      gate=self._root_gate)
                reduced = next(iter(res.data.values()), None)
            else:
                return None, 0.0
        except PeerFailedError:
            # the op's root was in this call's verdict and the policy is
            # IGNORE: the repair has landed, the op result is discarded
            self._skip_op = True
            return None, 0.0
        return reduced, res.sim_seconds

    # -- one transparent step -----------------------------------------------------

    def run_step(self, step: int | None = None) -> StepReport:
        cl = self.cluster
        step = self.step_count if step is None else step
        t_start = time.perf_counter()
        # 0. step boundary (Session.boundary): the provisioner delivers
        #    re-spawned spares (and reschedules shrunk slots), warmed-up
        #    substitutes rejoin, faults due this step land in the ground
        #    truth, the sim clock ticks
        boundary = self.session.boundary(step)

        # 1. per-node shard work (only live nodes actually compute)
        results, computed_shards = self._work_phase(step)

        # 2. the step-final collective as one facade call — fault trap,
        #    pipeline drain, repair, and the retried schedule all happen
        #    behind it (paper §IV). With no collective this step, the crash
        #    channels still drain so heartbeat timeouts reach agreement.
        self._skip_op = False
        reduced, sim_t = (None, 0.0)
        if self.final_collective != "none" and results:
            reduced, sim_t = self._collective_phase(results)
        else:
            self.session.poll(
                (FaultSource.COLLECTIVE, FaultSource.HEARTBEAT),
                gate=self._root_gate)

        # 3. straggler soft-fails drain through the same pipeline, after
        #    the op (a lagging node's contribution still counts this step)
        self.session.poll((FaultSource.STRAGGLER,))
        actions = list(self.session.take_actions())

        self.step_count = step + 1
        # back-compat: `repair` carries the first CRASH repair only; straggler
        # soft-fail repairs are surfaced through `actions` and `failed_now`
        crash_reports = [a.report for a in actions if a.report is not None
                         and FaultSource.STRAGGLER not in a.sources]
        failed_now = tuple(sorted({n for a in actions for n in a.verdict}))
        return StepReport(
            step=step,
            results=results,
            reduced=reduced,
            failed_now=failed_now,
            repair=crash_reports[0] if crash_reports else None,
            actions=tuple(actions),
            skipped_op=self._skip_op,
            sim_collective_seconds=sim_t,
            wall_seconds=time.perf_counter() - t_start,
            # renormalize over the shards that actually contributed THIS step
            # (the post-repair plan may already show restored capacity a
            # just-spliced spare did not compute yet)
            grad_scale=(cl.total_shards / computed_shards
                        if computed_shards else 0.0),
            expanded=boundary.expanded,
            respawned=boundary.respawned,
            repairing=tuple(sorted(cl.repairing_participants())),
            reconciled=boundary.reconciled,
        )

    def run(self, n_steps: int) -> list[StepReport]:
        return [self.run_step() for _ in range(n_steps)]


def _sum_results(outs: list[Any]) -> Any:
    acc = outs[0]
    for o in outs[1:]:
        acc = np.add(acc, o) if isinstance(acc, np.ndarray) else acc + o
    return acc
