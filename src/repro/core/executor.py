"""LegioExecutor — the transparent fault-resiliency loop (paper §IV).

PMPI interposition has no JAX analogue at the call level; the equivalent
*seam* is the step boundary: applications hand the executor a per-shard work
function and the executor owns everything Legio owns in MPI — substitute
structures (the legion topology standing in for the application's
communicator), post-collective error checking, agreement, repair, and
shard reassignment. Application code never sees a fault.

Per step:
  1. run every live node's shard work (EP: no interaction until the final
     collective — exactly the paper's target class);
  2. the step-final collective (reduce of results / gradient psum) runs on
     the substitute topology; injected faults surface there, with
     bcast-shaped ops noticing only partially (BNP, detector.notice_fault);
  3. agreement unifies the survivors' verdicts (agreement.agree_fault);
  4. the shrink engine repairs the topology (flat or hierarchical per
     policy), masters are re-elected, and the batch plan is reassigned
     (DROP / REBALANCE);
  5. if the op's root died: IGNORE (skip, buffers unchanged) or STOP
     (raise) per ``policy.root_failure_policy`` — the paper's compile-time
     knob, here a config value.

Straggler mitigation (beyond-paper): step latencies feed a
StragglerDetector; flagged nodes are soft-failed through the *same* repair
path (FailureKind.STRAGGLE) — the paper's discard semantics applied to
performance faults.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.agreement import agree_fault
from repro.core.batch import (
    BatchPlan,
    initial_assignment,
    reassign,
    restore_rank,
    substitute_assign,
)
from repro.core.collectives import HierarchicalCollectives, LinkModel
from repro.core.detector import (
    FaultInjector,
    HeartbeatDetector,
    StragglerDetector,
    notice_fault,
)
from repro.core.hierarchy import LegionTopology, make_topology
from repro.core.policy import LegioPolicy
from repro.core.shrink import ShrinkEngine
from repro.core.substitute import (
    PendingSubstitution,
    SparePool,
    SubstituteEngine,
    restore_for_substitute,
)
from repro.core.types import (
    ClusterClock,
    FailureEvent,
    FailureKind,
    NodeState,
    RepairReport,
    RepairStep,
)


class RootFailedError(RuntimeError):
    """Raised under the STOP policy when an operation's root has failed."""


@dataclass
class StepReport:
    step: int
    results: dict[int, Any]                  # node -> shard work output
    reduced: Any | None                      # step-final collective output
    failed_now: tuple[int, ...] = ()
    repair: RepairReport | None = None
    skipped_op: bool = False                 # IGNORE policy fired
    sim_collective_seconds: float = 0.0
    wall_seconds: float = 0.0
    grad_scale: float = 1.0
    expanded: tuple[tuple[int, int], ...] = ()  # non-blocking splices applied


class VirtualCluster:
    """A simulated cluster: N logical nodes, ground-truth failure state,
    simulated clock, and the Legio substitute structures."""

    def __init__(
        self,
        n_nodes: int,
        policy: LegioPolicy | None = None,
        injector: FaultInjector | None = None,
        link: LinkModel | None = None,
        shards_per_node: int = 1,
        checkpointer: Any = None,       # LegionCheckpointer (state restoration)
    ):
        self.policy = policy or LegioPolicy()
        self.injector = injector or FaultInjector()
        self.link = link or LinkModel()
        self.nodes = list(range(n_nodes))
        self.n_initial = n_nodes
        self.topo: LegionTopology = make_topology(self.nodes, self.policy)
        self.detector = HeartbeatDetector(timeout=self.policy.heartbeat_timeout)
        for n in self.nodes:
            self.detector.register(n)
        self.straggler = StragglerDetector(threshold=self.policy.straggler_threshold)
        self.shrink = ShrinkEngine(self.policy)
        self.substitute = SubstituteEngine(self.policy)
        self.clock = ClusterClock()
        self.failed: set[int] = set()            # ground truth (hidden from app)
        self.plan: BatchPlan = initial_assignment(self.nodes, shards_per_node)
        self.shards_per_node = shards_per_node
        self.total_shards = n_nodes * shards_per_node
        self.spare_pool = SparePool.provision(n_nodes, self.policy)
        self.pending: list[PendingSubstitution] = []
        self.checkpointer = checkpointer
        self.restored_state: dict[int, Any] = {}  # this step's splices only
        self._restored_step = -1
        self.repairs: list[RepairReport] = []
        self._step = 0
        # error-feedback residuals for compressed cross-legion reduction
        self.compress_residuals: dict[int, Any] = {}

    @property
    def spares(self) -> list[int]:
        """Warm spares still available (legacy view of the pool)."""
        return self.spare_pool.available

    # -- fault plumbing ---------------------------------------------------------

    def inject(self, step: int) -> list[FailureEvent]:
        self._step = step
        events = self.injector.due(step)
        for e in events:
            if e.node in self.topo.nodes:
                self.failed.add(e.node)
            elif e.node in self.spare_pool.available:
                # a warm spare can die too — it must never be spliced in
                self.failed.add(e.node)
                self.spare_pool.available.remove(e.node)
            elif any(p.spare == e.node for p in self.pending):
                # died while warming up: reschedule the splice on the next
                # warm spare (fresh warmup); with the pool empty the slot
                # stays shrunk — fatal under strict substitute semantics
                self.failed.add(e.node)
                dead = [p for p in self.pending if p.spare == e.node]
                self.pending = [p for p in self.pending if p.spare != e.node]
                for p in dead:
                    self.spare_pool.require(
                        1, self.policy.recovery_mode == "substitute")
                    replacement = self.spare_pool.take()
                    if replacement is None:
                        continue
                    self.pending.append(PendingSubstitution(
                        failed=p.failed, spare=replacement, legion=p.legion,
                        ready_step=step + 1 + self.policy.spare_warmup_steps,
                        shards=p.shards))
        return events

    def collectives(self) -> HierarchicalCollectives:
        return HierarchicalCollectives(
            self.topo, self.link,
            compression=self.policy.grad_compression,
            topk_fraction=self.policy.topk_fraction,
            residuals=self.compress_residuals)

    @property
    def live_nodes(self) -> list[int]:
        return [n for n in self.topo.nodes if n not in self.failed]

    # -- repair -------------------------------------------------------------------

    def _note_restored(self, spare: int, state: Any) -> None:
        """Record a splice's restored state, evicting previous steps' entries
        — consumers copy what they need within the step; unbounded retention
        would keep one full model+opt snapshot per fault for the campaign's
        lifetime."""
        if self._restored_step != self._step:
            self.restored_state.clear()
            self._restored_step = self._step
        self.restored_state[spare] = state

    def repair(self, verdict: set[int]) -> RepairReport | None:
        if not verdict:
            return None
        if self.policy.substitution_enabled \
                and not self.policy.nonblocking_substitution:
            report = self._repair_substitute(verdict)
        elif self.policy.substitution_enabled:
            report = self._repair_nonblocking(verdict)
        else:
            report = self._repair_shrink(verdict)
        for n in verdict:
            self.detector.confirm_failed(n)
            self.straggler.drop(n)
        self.clock.charge(report.model_cost)
        self.repairs.append(report)
        return report

    def _repair_substitute(self, verdict: set[int]) -> RepairReport:
        """Blocking substitution: splice spares in during the repair itself;
        the substituted ranks compute from the next step."""
        report = self.substitute.repair(self.topo, verdict, self.spare_pool)
        for failed, spare in report.substitutions:
            self.detector.register(spare)
            self._note_restored(spare, restore_for_substitute(
                self.checkpointer, self.topo.home[spare], failed))
        self.plan = substitute_assign(self.plan, report.substitution_map)
        if report.unfilled:
            self.plan = reassign(self.plan, set(report.unfilled),
                                 self.policy.batch_policy)
        return report

    def _repair_nonblocking(self, verdict: set[int]) -> RepairReport:
        """Non-blocking substitution: repair by shrink now (the next step
        runs degraded), schedule the splice for after the spare's warmup."""
        homes = {n: self.topo.home[n] for n in verdict
                 if n in self.topo.home and n in self.topo.nodes}
        self.spare_pool.require(len(homes),
                                self.policy.recovery_mode == "substitute")
        # each pending splice returns exactly the failed node's own shards
        owned = {n: self.plan.shards_of(n) for n in homes}
        report = self._repair_shrink(verdict, regrow=False)
        scheduled = 0
        for node, legion in sorted(homes.items()):
            spare = self.spare_pool.take()
            if spare is None:
                break  # substitute_then_shrink: stay shrunk
            scheduled += 1
            # the fault step itself ran degraded; spare_warmup_steps MORE
            # steps run shrunk before the splice lands at a boundary
            self.pending.append(PendingSubstitution(
                failed=node, spare=spare, legion=legion,
                ready_step=self._step + 1 + self.policy.spare_warmup_steps,
                shards=owned[node]))
        report.mode = ("substitute(nonblocking)" if scheduled == len(homes)
                       else "substitute_then_shrink")
        return report

    def _repair_shrink(self, verdict: set[int], *,
                       regrow: bool = True) -> RepairReport:
        report = self.shrink.repair(self.topo, verdict)
        # elastic regrow: pull spares into the smallest legion (beyond-paper;
        # predates slot-preserving substitution — kept for recovery_mode=
        # "shrink" with a provisioned pool)
        grown = []
        while regrow and self.spares and self.topo.size < self.n_initial:
            spare = self.spare_pool.take()
            target = min((lg for lg in self.topo.legions if lg.members),
                         key=len, default=None)
            if target is None:
                self.topo = make_topology([spare], self.policy)
            else:
                target.members.append(spare)
                target.members.sort()
                self.topo.home[spare] = target.index
            self.detector.register(spare)
            grown.append(spare)
        if grown:
            report.steps.append(RepairStep(
                op="include", comm="world", participants=tuple(grown),
                cost_units=0.0))
        self.plan = reassign(self.plan, verdict, self.policy.batch_policy)
        if grown:
            # new members take over dropped shards (restart-only-failed)
            extra = initial_assignment(grown, self.shards_per_node)
            take = list(self.plan.dropped_shards)
            new_assignments = list(self.plan.assignments)
            for a in extra.assignments:
                shards = tuple(take.pop(0) for _ in a.shards if take)
                new_assignments.append(type(a)(node=a.node, shards=shards))
            self.plan = BatchPlan(
                assignments=tuple(new_assignments),
                dropped_shards=tuple(take),
                policy=self.plan.policy)
        return report

    # -- deferred (non-blocking) substitution --------------------------------

    def poll_substitutions(self, step: int) -> list[RepairReport]:
        """Apply every pending splice whose warmup has elapsed — called at
        the step boundary, before new work is assigned. Re-expansion is a
        mini-repair of its own: an include into the home legion plus the
        (overlapped, hence uncharged) state restore."""
        ready = [p for p in self.pending if p.ready_step <= step]
        if not ready:
            return []
        self.pending = [p for p in self.pending if p.ready_step > step]
        self._step = step
        reports = []
        for p in ready:
            t0 = time.perf_counter()
            self.topo.expand(p.legion, p.spare)
            self.detector.register(p.spare)
            self._note_restored(p.spare, restore_for_substitute(
                self.checkpointer, p.legion, p.failed))
            self.plan = restore_rank(self.plan, p.spare, shards=p.shards)
            k = len(self.topo.legion_of(p.spare).members)
            steps = [RepairStep(op="substitute", comm=f"local_{p.legion}",
                                participants=(p.spare,),
                                cost_units=self.substitute.cost.splice_cost(k - 1))]
            report = RepairReport(
                trigger=(p.failed,),
                hierarchical=self.topo.n_legions > 1,
                master_failed=False,
                steps=steps,
                model_cost=sum(st.cost_units for st in steps),
                wall_seconds=time.perf_counter() - t0,
                survivors=self.topo.size,
                mode="substitute(nonblocking)",
                substitutions=((p.failed, p.spare),),
            )
            self.clock.charge(report.model_cost)
            self.repairs.append(report)
            reports.append(report)
        return reports


class LegioExecutor:
    """Runs per-shard work under transparent fault resiliency."""

    def __init__(
        self,
        cluster: VirtualCluster,
        work_fn: Callable[[int, int, int], Any],
        *,
        reduce_op: Callable[[Any, Any], Any] | None = None,
        final_collective: str = "allreduce",   # allreduce | reduce | bcast | none
        root: int = 0,
    ):
        self.cluster = cluster
        self.work_fn = work_fn
        self.reduce_op = reduce_op or np.add
        self.final_collective = final_collective
        self.root = root
        self.step_count = 0

    # -- one transparent step -----------------------------------------------------

    def run_step(self, step: int | None = None) -> StepReport:
        cl = self.cluster
        step = self.step_count if step is None else step
        t_start = time.perf_counter()
        # 0. step boundary: warmed-up non-blocking substitutes rejoin first,
        #    so the work assignment below already covers the restored slots
        expansions = cl.poll_substitutions(step)
        events = cl.inject(step)
        del events  # ground truth is hidden; detection is observational

        # 1. per-node shard work (only live nodes actually compute)
        results: dict[int, Any] = {}
        computed_shards = 0
        for node in cl.live_nodes:
            t0 = time.perf_counter()
            shards = cl.plan.shards_of(node)
            if not shards:
                continue
            out = [self.work_fn(node, s, step) for s in shards]
            results[node] = out[0] if len(out) == 1 else _sum_results(out)
            computed_shards += len(shards)
            cl.straggler.observe(node, time.perf_counter() - t0)
            cl.detector.beat(node, cl.clock.sim_seconds)

        # 2. step-final collective on the substitute topology
        live_set = cl.live_nodes
        failed_in_topo = {n for n in cl.topo.nodes if n in cl.failed}
        reduced = None
        sim_t = 0.0
        skipped = False
        if self.final_collective != "none" and results:
            op_kind = "bcast" if self.final_collective == "bcast" else "allreduce"
            noticers = notice_fault(op_kind, cl.topo.nodes, failed_in_topo,
                                    root=self.root)
            # 3. BNP agreement: union of suspicion sets over live observers
            observations = {obs: set(failed_in_topo) for obs in noticers}
            verdict = agree_fault(observations, live_set)
            # paper §IV: presence of fault checked AFTER the op; if confirmed
            # repair, then repeat the operation.
            if verdict:
                if self.root in verdict and self.final_collective in ("bcast", "reduce"):
                    if cl.policy.root_failure_policy == "stop":
                        raise RootFailedError(
                            f"root node {self.root} failed at step {step}")
                    skipped = True  # IGNORE: skip the op, buffers unchanged
                repair = cl.repair(verdict)
            else:
                repair = None
            if not skipped:
                coll = cl.collectives()
                contributions = {n: np.asarray(v) for n, v in results.items()
                                 if n in cl.topo.nodes}
                if self.final_collective == "allreduce":
                    res = coll.allreduce(contributions, self.reduce_op)
                    reduced = res.data.get(cl.topo.nodes[0]) if cl.topo.nodes else None
                elif self.final_collective == "reduce":
                    rt = self.root if self.root in cl.topo.nodes else cl.topo.nodes[0]
                    res = coll.reduce(rt, contributions, self.reduce_op)
                    reduced = res.data[rt]
                elif self.final_collective == "bcast":
                    rt = self.root if self.root in cl.topo.nodes else cl.topo.nodes[0]
                    res = coll.bcast(rt, contributions.get(rt, np.zeros(1)))
                    reduced = res.data[rt]
                sim_t = res.sim_seconds
                cl.clock.charge(sim_t)
        else:
            verdict = set(failed_in_topo)
            repair = cl.repair(verdict) if verdict else None

        # 5. straggler soft-fail (routed through the same repair path)
        lagging = [n for n in cl.straggler.stragglers() if n in cl.topo.nodes]
        if lagging:
            for n in lagging:
                cl.failed.add(n)
            cl.repair(set(lagging))

        self.step_count = step + 1
        return StepReport(
            step=step,
            results=results,
            reduced=reduced,
            failed_now=tuple(sorted(verdict)) if verdict else (),
            repair=repair,
            skipped_op=skipped,
            sim_collective_seconds=sim_t,
            wall_seconds=time.perf_counter() - t_start,
            # renormalize over the shards that actually contributed THIS step
            # (the post-repair plan may already show restored capacity a
            # just-spliced spare did not compute yet)
            grad_scale=(cl.total_shards / computed_shards
                        if computed_shards else 0.0),
            expanded=tuple(s for r in expansions for s in r.substitutions),
        )

    def run(self, n_steps: int) -> list[StepReport]:
        return [self.run_step() for _ in range(n_steps)]


def _sum_results(outs: list[Any]) -> Any:
    acc = outs[0]
    for o in outs[1:]:
        acc = np.add(acc, o) if isinstance(acc, np.ndarray) else acc + o
    return acc
