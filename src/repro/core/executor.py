"""LegioExecutor — the transparent fault-resiliency loop (paper §IV).

PMPI interposition has no JAX analogue at the call level; the equivalent
*seam* is the step boundary: applications hand the executor a per-shard work
function and the executor owns everything Legio owns in MPI — substitute
structures (the legion topology standing in for the application's
communicator), post-collective error checking, agreement, repair, and
shard reassignment. Application code never sees a fault.

Per step:
  1. run every live node's shard work (EP: no interaction until the final
     collective — exactly the paper's target class);
  2. the step-final collective (reduce of results / gradient psum) runs on
     the substitute topology; injected faults surface there, with
     bcast-shaped ops noticing only partially (BNP, detector.notice_fault);
  3. agreement unifies the survivors' verdicts (agreement.agree_fault);
  4. the shrink engine repairs the topology (flat or hierarchical per
     policy), masters are re-elected, and the batch plan is reassigned
     (DROP / REBALANCE);
  5. if the op's root died: IGNORE (skip, buffers unchanged) or STOP
     (raise) per ``policy.root_failure_policy`` — the paper's compile-time
     knob, here a config value.

Straggler mitigation (beyond-paper): step latencies feed a
StragglerDetector; flagged nodes are soft-failed through the *same* repair
path (FailureKind.STRAGGLE) — the paper's discard semantics applied to
performance faults.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.agreement import agree_fault
from repro.core.batch import BatchPlan, gradient_scale, initial_assignment, reassign
from repro.core.collectives import HierarchicalCollectives, LinkModel
from repro.core.detector import (
    FaultInjector,
    HeartbeatDetector,
    StragglerDetector,
    notice_fault,
)
from repro.core.hierarchy import LegionTopology, make_topology
from repro.core.policy import LegioPolicy
from repro.core.shrink import ShrinkEngine
from repro.core.types import (
    ClusterClock,
    FailureEvent,
    FailureKind,
    NodeState,
    RepairReport,
)


class RootFailedError(RuntimeError):
    """Raised under the STOP policy when an operation's root has failed."""


@dataclass
class StepReport:
    step: int
    results: dict[int, Any]                  # node -> shard work output
    reduced: Any | None                      # step-final collective output
    failed_now: tuple[int, ...] = ()
    repair: RepairReport | None = None
    skipped_op: bool = False                 # IGNORE policy fired
    sim_collective_seconds: float = 0.0
    wall_seconds: float = 0.0
    grad_scale: float = 1.0


class VirtualCluster:
    """A simulated cluster: N logical nodes, ground-truth failure state,
    simulated clock, and the Legio substitute structures."""

    def __init__(
        self,
        n_nodes: int,
        policy: LegioPolicy | None = None,
        injector: FaultInjector | None = None,
        link: LinkModel | None = None,
        shards_per_node: int = 1,
    ):
        self.policy = policy or LegioPolicy()
        self.injector = injector or FaultInjector()
        self.link = link or LinkModel()
        self.nodes = list(range(n_nodes))
        self.n_initial = n_nodes
        self.topo: LegionTopology = make_topology(self.nodes, self.policy)
        self.detector = HeartbeatDetector(timeout=self.policy.heartbeat_timeout)
        for n in self.nodes:
            self.detector.register(n)
        self.straggler = StragglerDetector(threshold=self.policy.straggler_threshold)
        self.shrink = ShrinkEngine(self.policy)
        self.clock = ClusterClock()
        self.failed: set[int] = set()            # ground truth (hidden from app)
        self.plan: BatchPlan = initial_assignment(self.nodes, shards_per_node)
        self.shards_per_node = shards_per_node
        self.total_shards = n_nodes * shards_per_node
        self.spares: list[int] = [n_nodes + i for i in range(self.policy.spare_nodes)]
        self.repairs: list[RepairReport] = []
        # error-feedback residuals for compressed cross-legion reduction
        self.compress_residuals: dict[int, Any] = {}

    # -- fault plumbing ---------------------------------------------------------

    def inject(self, step: int) -> list[FailureEvent]:
        events = self.injector.due(step)
        for e in events:
            if e.node in self.topo.nodes:
                self.failed.add(e.node)
        return events

    def collectives(self) -> HierarchicalCollectives:
        return HierarchicalCollectives(
            self.topo, self.link,
            compression=self.policy.grad_compression,
            topk_fraction=self.policy.topk_fraction,
            residuals=self.compress_residuals)

    @property
    def live_nodes(self) -> list[int]:
        return [n for n in self.topo.nodes if n not in self.failed]

    # -- repair -------------------------------------------------------------------

    def repair(self, verdict: set[int]) -> RepairReport | None:
        if not verdict:
            return None
        report = self.shrink.repair(self.topo, verdict)
        for n in verdict:
            self.detector.confirm_failed(n)
            self.straggler.drop(n)
        self.clock.charge(report.model_cost)
        # elastic regrow: pull spares into the smallest legion (beyond-paper)
        grown = []
        while self.spares and self.topo.size < self.n_initial \
                and self.policy.spare_nodes > 0:
            spare = self.spares.pop(0)
            target = min((lg for lg in self.topo.legions if lg.members),
                         key=len, default=None)
            if target is None:
                self.topo = make_topology([spare], self.policy)
            else:
                target.members.append(spare)
                target.members.sort()
                self.topo.home[spare] = target.index
            self.detector.register(spare)
            grown.append(spare)
        if grown:
            from repro.core.types import RepairStep
            report.steps.append(RepairStep(
                op="include", comm="world", participants=tuple(grown),
                cost_units=0.0))
        self.plan = reassign(self.plan, verdict, self.policy.batch_policy)
        if grown:
            # new members take over dropped shards (restart-only-failed)
            extra = initial_assignment(grown, self.shards_per_node)
            take = list(self.plan.dropped_shards)
            new_assignments = list(self.plan.assignments)
            for a in extra.assignments:
                shards = tuple(take.pop(0) for _ in a.shards if take)
                new_assignments.append(type(a)(node=a.node, shards=shards))
            self.plan = BatchPlan(
                assignments=tuple(new_assignments),
                dropped_shards=tuple(take),
                policy=self.plan.policy)
        self.repairs.append(report)
        return report


class LegioExecutor:
    """Runs per-shard work under transparent fault resiliency."""

    def __init__(
        self,
        cluster: VirtualCluster,
        work_fn: Callable[[int, int, int], Any],
        *,
        reduce_op: Callable[[Any, Any], Any] | None = None,
        final_collective: str = "allreduce",   # allreduce | reduce | bcast | none
        root: int = 0,
    ):
        self.cluster = cluster
        self.work_fn = work_fn
        self.reduce_op = reduce_op or np.add
        self.final_collective = final_collective
        self.root = root
        self.step_count = 0

    # -- one transparent step -----------------------------------------------------

    def run_step(self, step: int | None = None) -> StepReport:
        cl = self.cluster
        step = self.step_count if step is None else step
        t_start = time.perf_counter()
        events = cl.inject(step)
        del events  # ground truth is hidden; detection is observational

        # 1. per-node shard work (only live nodes actually compute)
        results: dict[int, Any] = {}
        for node in cl.live_nodes:
            t0 = time.perf_counter()
            shards = cl.plan.shards_of(node)
            if not shards:
                continue
            out = [self.work_fn(node, s, step) for s in shards]
            results[node] = out[0] if len(out) == 1 else _sum_results(out)
            cl.straggler.observe(node, time.perf_counter() - t0)
            cl.detector.beat(node, cl.clock.sim_seconds)

        # 2. step-final collective on the substitute topology
        live_set = cl.live_nodes
        failed_in_topo = {n for n in cl.topo.nodes if n in cl.failed}
        reduced = None
        sim_t = 0.0
        skipped = False
        if self.final_collective != "none" and results:
            op_kind = "bcast" if self.final_collective == "bcast" else "allreduce"
            noticers = notice_fault(op_kind, cl.topo.nodes, failed_in_topo,
                                    root=self.root)
            # 3. BNP agreement: union of suspicion sets over live observers
            observations = {obs: set(failed_in_topo) for obs in noticers}
            verdict = agree_fault(observations, live_set)
            # paper §IV: presence of fault checked AFTER the op; if confirmed
            # repair, then repeat the operation.
            if verdict:
                if self.root in verdict and self.final_collective in ("bcast", "reduce"):
                    if cl.policy.root_failure_policy == "stop":
                        raise RootFailedError(
                            f"root node {self.root} failed at step {step}")
                    skipped = True  # IGNORE: skip the op, buffers unchanged
                repair = cl.repair(verdict)
            else:
                repair = None
            if not skipped:
                coll = cl.collectives()
                contributions = {n: np.asarray(v) for n, v in results.items()
                                 if n in cl.topo.nodes}
                if self.final_collective == "allreduce":
                    res = coll.allreduce(contributions, self.reduce_op)
                    reduced = res.data.get(cl.topo.nodes[0]) if cl.topo.nodes else None
                elif self.final_collective == "reduce":
                    rt = self.root if self.root in cl.topo.nodes else cl.topo.nodes[0]
                    res = coll.reduce(rt, contributions, self.reduce_op)
                    reduced = res.data[rt]
                elif self.final_collective == "bcast":
                    rt = self.root if self.root in cl.topo.nodes else cl.topo.nodes[0]
                    res = coll.bcast(rt, contributions.get(rt, np.zeros(1)))
                    reduced = res.data[rt]
                sim_t = res.sim_seconds
                cl.clock.charge(sim_t)
        else:
            verdict = set(failed_in_topo)
            repair = cl.repair(verdict) if verdict else None

        # 5. straggler soft-fail (routed through the same repair path)
        lagging = [n for n in cl.straggler.stragglers() if n in cl.topo.nodes]
        if lagging:
            for n in lagging:
                cl.failed.add(n)
            cl.repair(set(lagging))

        self.step_count = step + 1
        return StepReport(
            step=step,
            results=results,
            reduced=reduced,
            failed_now=tuple(sorted(verdict)) if verdict else (),
            repair=repair,
            skipped_op=skipped,
            sim_collective_seconds=sim_t,
            wall_seconds=time.perf_counter() - t_start,
            grad_scale=gradient_scale(cl.plan, cl.total_shards),
        )

    def run(self, n_steps: int) -> list[StepReport]:
        return [self.run_step() for _ in range(n_steps)]


def _sum_results(outs: list[Any]) -> Any:
    acc = outs[0]
    for o in outs[1:]:
        acc = np.add(acc, o) if isinstance(acc, np.ndarray) else acc + o
    return acc
