"""ResilientTrainer — SPMD data-parallel training under the Legio runtime.

This is the production integration of the paper's technique: a jitted
``train_step`` over a mesh, wrapped so node failures are survived by
*discard-and-continue* rather than global restart:

  * the virtual cluster's nodes each own one data-parallel batch shard;
  * a failure (injected here; heartbeat-detected in production) triggers the
    Legio repair path — agreement, hierarchical shrink, master re-election —
    and the trainer then (a) rebuilds its mesh from survivors, (b) reshards
    params/optimizer state, (c) recompiles through the CompileCache;
  * the global batch shrinks (DROP) or redistributes (REBALANCE); gradient
    means renormalize over the shards actually computed, so the SGD
    estimator stays unbiased — the paper's Monte-Carlo argument, applied to
    stochastic gradients;
  * per-legion checkpoints (cr.py) bound the loss of a *non-recoverable*
    event, and restart-only-failed brings replacements back without touching
    survivors.

On the CPU container meshes are virtual (1 device); on real TPUs the same
code path shrinks physical meshes — the dry-run proves those lower/compile.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.cr import LegionCheckpointer
from repro.core.executor import VirtualCluster
from repro.core.mesh_manager import CompileCache, DevicePool, MeshManager
from repro.core.types import RepairReport
from repro.data.pipeline import make_batch
from repro.models import api
from repro.optim import (
    OptState,
    adamw_init,
    adamw_update,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
)

PyTree = Any


@dataclass
class TrainerReport:
    step: int
    loss: float
    grad_norm: float
    active_shards: int
    grad_scale: float
    repair: RepairReport | None = None
    recompiled: bool = False
    step_seconds: float = 0.0
    metrics: dict = field(default_factory=dict)


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """(params, opt, batch, grad_scale) -> (params, opt, metrics); pure."""
    lr_fn = cosine_schedule(tc)

    @partial(jax.jit, static_argnums=(), donate_argnums=(0, 1))
    def train_step(params, opt: OptState, batch, grad_scale):
        def loss_fn(p):
            loss, metrics = api.train_loss(cfg, p, batch)
            return loss * grad_scale, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        updates, opt = adamw_update(grads, opt, params, tc, lr_fn(opt.step))
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt, metrics

    return train_step


class ResilientTrainer:
    """Data-parallel training loop with Legio fault resiliency."""

    def __init__(
        self,
        cfg: ModelConfig,
        tc: TrainConfig,
        cluster: VirtualCluster,
        *,
        per_shard_batch: int = 4,
        seq_len: int = 128,
        checkpointer: LegionCheckpointer | None = None,
    ):
        self.cfg, self.tc = cfg, tc
        self.cluster = cluster
        self.per_shard_batch = per_shard_batch
        self.seq_len = seq_len
        self.checkpointer = checkpointer
        if checkpointer is not None and cluster.checkpointer is None:
            # substituted ranks restore from the same per-legion store
            cluster.checkpointer = checkpointer
        # all fault plumbing rides the MPI facade: the session owns the
        # step boundary (spare delivery, splice re-expansion, ground-truth
        # injection) and the INJECTED-channel drain
        from repro.mpi import Session

        self.session = Session.adopt(cluster)
        self.pool = DevicePool(n_nodes=cluster.n_initial,
                               n_spares=cluster.spare_pool.capacity)
        self.mesh_manager = MeshManager(self.pool)
        self.compile_cache = CompileCache()
        self.train_step = make_train_step(cfg, tc)
        key = jax.random.PRNGKey(tc.seed)
        self.params = api.init_params(cfg, key)
        self.opt = adamw_init(self.params)
        self.step = 0
        self.history: list[TrainerReport] = []
        # live state rides the data plane's mesh: after every shrink or
        # regrow the surviving devices re-place params/opt in one measured
        # device_put pass (a no-op on the sim plane)
        self.session.register_sharded_state(
            "trainer.params", lambda: self.params,
            lambda p: setattr(self, "params", p))
        self.session.register_sharded_state(
            "trainer.opt.mu", lambda: self.opt.mu,
            lambda mu: setattr(self, "opt", self.opt._replace(mu=mu)))
        self.session.register_sharded_state(
            "trainer.opt.nu", lambda: self.opt.nu,
            lambda nu: setattr(self, "opt", self.opt._replace(nu=nu)))

    # -- batch assembly under the current plan --------------------------------------

    def _global_batch(self, step: int) -> tuple[dict, float]:
        cl = self.cluster
        shards: list[int] = sorted(
            s for a in cl.plan.assignments for s in a.shards)
        if not shards:
            raise RuntimeError("no surviving shards — cluster exhausted")
        parts = [
            make_batch(self.tc.seed, step, s, batch=self.per_shard_batch,
                       seq_len=self.seq_len, vocab_size=self.cfg.vocab_size)
            for s in shards
        ]
        batch = {k: jnp.concatenate([p[k] for p in parts], axis=0)
                 for k in parts[0]}
        # mean-over-present-shards is already the renormalized estimator;
        # grad_scale stays 1.0 for DROP (the mean denominator shrank with
        # the batch). It differs from 1 only for weighted schemes.
        return batch, 1.0

    # -- one resilient step -----------------------------------------------------------

    def run_step(self) -> TrainerReport:
        cl = self.cluster
        t0 = time.perf_counter()
        step = self.step

        # step boundary through the facade: the provisioner delivers
        # re-spawned spares and warmed-up non-blocking substitutes rejoin
        # before new shards are handed out (re-expansion = mesh change
        # too); ground-truth faults land and drain through the pipeline's
        # INJECTED channel — detect → notice → agree → plan → apply — so
        # the trainer repairs through the registered RecoveryStrategy, not
        # a side door. (charge=False: the trainer's clock is wall time.)
        boundary = self.session.boundary(step, observe_injected=True,
                                         charge=False)
        repair = None
        recompiled = bool(boundary.expansions)
        if boundary.actions:
            repair = boundary.actions[0].report
            recompiled = True  # mesh change forces re-lower unless cached

        batch, grad_scale = self._global_batch(step)
        params, opt, metrics = self.train_step(
            self.params, self.opt, batch, jnp.asarray(grad_scale, jnp.float32))
        self.params, self.opt = params, opt

        loss = float(metrics["loss"])
        if not np.isfinite(loss):
            raise FloatingPointError(f"non-finite loss at step {step}: {loss}")

        if self.checkpointer is not None and self.tc.checkpoint_every > 0 \
                and step > 0 and step % self.tc.checkpoint_every == 0:
            self.checkpointer.save(step, cl.topo, self._state_of, sync=False)

        report = TrainerReport(
            step=step,
            loss=loss,
            grad_norm=float(metrics.get("grad_norm", 0.0)),
            active_shards=cl.plan.active_shards,
            grad_scale=grad_scale,
            repair=repair,
            recompiled=recompiled,
            step_seconds=time.perf_counter() - t0,
            metrics={k: float(v) for k, v in metrics.items()
                     if np.ndim(v) == 0},
        )
        self.history.append(report)
        self.step += 1
        return report

    def _state_of(self, node: int) -> PyTree:
        """Member state shard for checkpointing.

        Data-parallel state is replicated, so every member's shard is the
        (params, opt, step) triple plus its shard assignment — a replacement
        node needs nothing from survivors beyond its own file (§VII).
        """
        return {
            "params": self.params,
            "opt": {"step": self.opt.step, "mu": self.opt.mu, "nu": self.opt.nu},
            "meta": {
                "step": jnp.asarray(self.step, jnp.int32),
                "shards": jnp.asarray(list(self.cluster.plan.shards_of(node))
                                      or [-1], jnp.int32),
            },
        }

    def run(self, n_steps: int) -> list[TrainerReport]:
        return [self.run_step() for _ in range(n_steps)]

    # -- restart-only-failed (used by tests/examples) -----------------------------------

    def restore_from(self, checkpointer: LegionCheckpointer,
                     legion: int, node: int) -> None:
        state = checkpointer.restore_failed_member(
            legion, node, template=None)
        self.params = _retree(self.params, state["params"])
        self.opt = OptState(
            step=jnp.asarray(state["opt"]["step"]),
            mu=_retree(self.opt.mu, state["opt"]["mu"]),
            nu=_retree(self.opt.nu, state["opt"]["nu"]),
        )
        self.step = int(np.asarray(state["meta"]["step"]))


def _walk(tree: PyTree, prefix: str = ""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, f"{prefix}{k}/")
    else:
        yield prefix[:-1], tree


def _pstr(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    return str(entry)


def _retree(template: PyTree, loaded: PyTree) -> PyTree:
    flat = {k: v for k, v in _walk(loaded)}
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: jnp.asarray(
            flat["/".join(_pstr(p) for p in path)], dtype=leaf.dtype
        ).reshape(leaf.shape),
        template,
    )
