"""Substitution recovery — warm spares spliced into failed legion slots.

Legio's native semantics are shrink-only (the paper's discard-and-continue),
which is right for embarrassingly parallel jobs but leaves capacity on the
floor for long campaigns. This module implements the "substitute" branch of
Ashraf et al.'s shrink-or-substitute trade-off on top of the same repair
seam:

  * :class:`SparePool` — warm standby nodes provisioned at cluster start
    (``LegioPolicy.spare_fraction`` / ``spare_nodes``). Spare ids are
    allocated *above* every initial node id, so a splice never steals a
    mastership from a survivor (the paper's lowest-rank master rule).
  * :class:`SubstituteEngine` — sibling of :class:`ShrinkEngine`. The comm
    teardown half of its plan is exactly the shrink plan (the failed
    process must leave every communicator it was in — Fig. 3); the splice
    half then includes the spare into the failed node's local_comm and
    restores its state. Topology invariants (a)–(c) hold afterwards because
    the legion count, POV ring, and home map are preserved by
    :meth:`LegionTopology.substitute`.
  * checkpoint-backed restoration — the spare adopts the *dead member's*
    shard via ``checkpoint.store.restore_member`` (restart-only-failed,
    §VII): survivors are never touched.

Modes (``LegioPolicy.recovery_mode``):
  * ``substitute``            — pool exhaustion raises
                                :class:`SparePoolExhausted` (the operator
                                asked for capacity-preserving recovery).
  * ``substitute_then_shrink``— exhaustion degrades to shrink for the
                                unfilled slots; the run continues degraded.

The non-blocking flavor (``nonblocking_substitution``) is orchestrated by
the executor: the fault step repairs by shrink (cheap, overlappable) and a
:class:`PendingSubstitution` re-expands the topology at the first step
boundary after the spare's warmup — repair overlapping useful work,
Bouteiller & Bosilca's implicit-actions argument at step granularity.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy
from repro.core.shrink import (
    ShrinkCostModel,
    ShrinkEngine,
    failures_by_legion,
    master_failed_in,
)
from repro.core.types import RepairReport, RepairStep

PyTree = Any


class SparePoolExhausted(RuntimeError):
    """Raised under recovery_mode="substitute" when no warm spare is left.

    ``partial_report`` carries a repair that already committed before the
    exhaustion was discovered (the non-blocking strategy lands the shrink
    first, so the error always leaves a consistent — shrunk — topology);
    ``VirtualCluster.repair`` records it before re-raising.
    """

    partial_report: "RepairReport | None" = None


@dataclass
class SparePool:
    """Warm standby nodes. ``available`` is FIFO: the longest-warm spare is
    spliced first."""

    capacity: int
    available: list[int] = field(default_factory=list)
    consumed: list[int] = field(default_factory=list)

    @staticmethod
    def provision(n_nodes: int, policy: LegioPolicy) -> "SparePool":
        """Pool for an ``n_nodes`` cluster; spare ids start at ``n_nodes``."""
        count = policy.spare_count(n_nodes)
        return SparePool(capacity=count,
                         available=[n_nodes + i for i in range(count)])

    def take(self) -> int | None:
        if not self.available:
            return None
        spare = self.available.pop(0)
        self.consumed.append(spare)
        return spare

    @property
    def exhausted(self) -> bool:
        return not self.available

    def __len__(self) -> int:
        return len(self.available)

    def require(self, needed: int, strict: bool) -> None:
        """Under strict (recovery_mode="substitute") semantics, refuse when
        the pool cannot cover ``needed`` failed slots. The blocking engine
        calls this before anything is mutated; the non-blocking strategy
        deliberately calls it AFTER its shrink has landed, so the error
        propagates from a consistent topology (the committed shrink rides
        along as ``SparePoolExhausted.partial_report``)."""
        if strict and needed > len(self.available):
            raise SparePoolExhausted(
                f"{needed} failed node(s) but only {len(self.available)} "
                f"warm spare(s) left (recovery_mode='substitute' does not "
                f"degrade; use 'substitute_then_shrink')")

    def restock(self, node: int) -> None:
        """Feed a freshly provisioned spare back into the pool (the
        SpareProvisioner's delivery path). FIFO order is preserved: re-spawned
        spares queue behind any originals still warm."""
        self.available.append(node)


@dataclass(frozen=True)
class UnfilledSlot:
    """A failed slot that was shrunk for lack of spares — remembered so the
    provisioner can heal it once replacement spares come up."""

    failed: int
    legion: int                    # home legion index (assignment is final)
    shards: tuple[int, ...] = ()   # the slot's shards at fault time


@dataclass
class SpareProvisioner:
    """Elastic re-spawn of consumed spares — the ``MPI_Comm_spawn`` analogue
    (ROADMAP item). A background pipeline stage polled at step boundaries:

      * **watermark** — when warm + in-flight spares drop below
        ``policy.spare_refill_watermark``, schedule replacements up to the
        pool's provisioned capacity;
      * **delay** — a scheduled spare becomes warm only after
        ``policy.spare_provision_delay_steps`` steps (node acquisition +
        boot is never free);
      * **churn cap** — ``policy.spare_churn_cap`` bounds the total number
        of re-spawned spares over the campaign (0 = unbounded).

    Spare ids keep growing monotonically above every id ever allocated, so
    a re-spawned spare can never demote a surviving master (the paper's
    lowest-rank master rule) — property-tested.
    """

    policy: LegioPolicy
    pool: SparePool
    next_id: int
    inflight: list[tuple[int, int]] = field(default_factory=list)  # (node, ready_step)
    spawned: int = 0               # total re-spawned over the campaign
    delivered: list[int] = field(default_factory=list)

    @staticmethod
    def for_pool(n_nodes: int, pool: SparePool,
                 policy: LegioPolicy) -> "SpareProvisioner":
        return SpareProvisioner(policy=policy, pool=pool,
                                next_id=n_nodes + pool.capacity)

    @property
    def enabled(self) -> bool:
        return self.policy.elastic_spares

    def _churn_budget(self) -> int:
        if self.policy.spare_churn_cap <= 0:
            return 10 ** 9
        return self.policy.spare_churn_cap - self.spawned

    def poll(self, step: int) -> list[int]:
        """Deliver due spares into the pool, then top up below-watermark
        capacity. Returns the node ids delivered this boundary."""
        if not self.enabled:
            return []
        ready = [n for n, rs in self.inflight if rs <= step]
        self.inflight = [(n, rs) for n, rs in self.inflight if rs > step]
        for node in ready:
            self.pool.restock(node)
            self.delivered.append(node)
        self.refill(step)
        return ready

    def refill(self, step: int) -> None:
        """Schedule replacements for below-watermark capacity. Also called
        after the backlog consumes freshly delivered spares, so replacement
        provisioning overlaps the healing splices' warmup instead of waiting
        a boundary."""
        if self.enabled:
            self._schedule(step)

    def _schedule(self, step: int) -> None:
        covered = len(self.pool.available) + len(self.inflight)
        if covered >= self.policy.spare_refill_watermark:
            return
        # never grow past the provisioned capacity: a watermark above
        # capacity triggers earlier, it does not raise the ceiling
        deficit = min(self.pool.capacity - covered, self._churn_budget())
        ready_step = step + self.policy.spare_provision_delay_steps
        for _ in range(max(deficit, 0)):
            self.inflight.append((self.next_id, ready_step))
            self.next_id += 1
            self.spawned += 1


@dataclass(frozen=True)
class SubstituteCostModel:
    """Substitution = the shrink teardown + an include of the spare into the
    surviving local comm + the checkpoint read for state restoration.
    The splice reuses S(x) (comm reconstruction is the same collective
    machinery ULFM's shrink pays for); the restore term models the
    restart-only-failed npz read, which overlaps repair in the non-blocking
    flavor and is charged only when it blocks."""

    shrink: ShrinkCostModel = field(default_factory=ShrinkCostModel)
    restore_seconds: float = 0.35      # one member shard read (§VII scale)

    def splice_cost(self, k: int) -> float:
        return self.shrink.s_of_x(k + 1)

    def substitution_cost(self, s: int, k: int, master_failed: bool,
                          *, blocking: bool = True) -> float:
        """Single failure in a k-legion: teardown + include of the spare
        into the k-1 survivors + (if blocking) the restore read."""
        base = self.shrink.hierarchical_cost(s, k, master_failed)
        return base + self.splice_cost(k - 1) + \
            (self.restore_seconds if blocking else 0.0)


@dataclass(frozen=True)
class PendingSubstitution:
    """A scheduled non-blocking splice: apply at the first step boundary
    with ``step >= ready_step``."""

    failed: int
    spare: int
    legion: int            # the failed node's home legion (assignment final)
    ready_step: int
    shards: tuple[int, ...] = ()   # the failed node's shards at fault time —
                                   # the splice returns exactly these


class SubstituteEngine:
    """Builds and applies substitution repair plans against a LegionTopology.

    Sibling of :class:`ShrinkEngine`: identical teardown plan, plus one
    ``substitute`` + ``restore`` stage per filled slot. Slots the pool
    cannot fill are shrunk (or, under strict mode, refused)."""

    def __init__(self, policy: LegioPolicy,
                 cost: SubstituteCostModel | None = None):
        self.policy = policy
        self.cost = cost or SubstituteCostModel()
        self._shrink = ShrinkEngine(policy, self.cost.shrink)

    # ---- plan construction -------------------------------------------------

    def plan(self, topo: LegionTopology, failed: set[int],
             substitutions: dict[int, int]) -> list[RepairStep]:
        """Teardown steps (the shrink plan) + splice steps per substitution."""
        steps = self._shrink.plan(topo, failed)
        for li, dead in sorted(failures_by_legion(topo, failed).items()):
            lg = next(l for l in topo.legions if l.index == li)
            # splice participants: the legion's survivors plus the spares
            # already spliced into it — the dead members are gone by then
            k_live = len(lg.members) - len(dead)
            spliced = 0
            for node in dead:
                spare = substitutions.get(node)
                if spare is None:
                    continue
                steps.append(RepairStep(
                    op="substitute", comm=f"local_{li}",
                    participants=(spare,),
                    cost_units=self.cost.splice_cost(k_live + spliced)))
                steps.append(RepairStep(
                    op="restore", comm=f"local_{li}",
                    participants=(spare,),
                    cost_units=self.cost.restore_seconds))
                spliced += 1
        return steps

    # ---- application -------------------------------------------------------

    def repair(self, topo: LegionTopology, failed: set[int], pool: SparePool,
               *, strict: bool | None = None) -> RepairReport:
        """Plan + mutate: splice spares into every failed slot the pool can
        cover, shrink the rest. ``strict`` (default: recovery_mode ==
        "substitute") raises :class:`SparePoolExhausted` instead of
        degrading."""
        if strict is None:
            strict = self.policy.recovery_mode == "substitute"
        t0 = time.perf_counter()
        present = [n for n in sorted(failed)
                   if n in topo.home and n in topo.nodes]
        pool.require(len(present), strict)
        substitutions: dict[int, int] = {}
        for node in present:
            spare = pool.take()
            if spare is None:
                break
            substitutions[node] = spare

        steps = self.plan(topo, failed, substitutions)
        master_failed = master_failed_in(topo, set(present), steps)
        hierarchical = topo.n_legions > 1

        unfilled = []
        for node in present:
            if node in substitutions:
                topo.substitute(node, substitutions[node])
            else:
                topo.remove(node)
                unfilled.append(node)
        topo.compact()

        wall = time.perf_counter() - t0
        mode = ("substitute" if not unfilled else "substitute_then_shrink")
        return RepairReport(
            trigger=tuple(sorted(failed)),
            hierarchical=hierarchical,
            master_failed=master_failed,
            steps=steps,
            model_cost=sum(st.cost_units for st in steps),
            wall_seconds=wall,
            survivors=topo.size,
            mode=mode,
            substitutions=tuple(sorted(substitutions.items())),
            unfilled=tuple(unfilled),
        )

    # ---- cost queries (benchmarks) -----------------------------------------

    def cost_substitute(self, s: int, k: int, master_failed: bool,
                        *, blocking: bool = True) -> float:
        return self.cost.substitution_cost(s, k, master_failed,
                                           blocking=blocking)

    def expected_repair_cost(self, s: int, k: int,
                             *, blocking: bool = True) -> float:
        """E[cost] under uniform failure probability, P(master) = 1/k."""
        p_master = 1.0 / max(k, 1)
        return (p_master * self.cost_substitute(s, k, True, blocking=blocking)
                + (1 - p_master)
                * self.cost_substitute(s, k, False, blocking=blocking))


def restore_for_substitute(checkpointer, legion: int, failed: int,
                           *, template: PyTree | None = None) -> PyTree | None:
    """Checkpoint-backed state restoration for a substituted rank: load the
    *dead member's* shard (restart-only-failed — the spare takes over the
    failed node's identity, data shards included). Returns None when no
    checkpoint covers the member yet (fresh run, or the legion was created
    after the last snapshot)."""
    if checkpointer is None:
        return None
    try:
        return checkpointer.restore_failed_member(legion, failed,
                                                  template=template)
    except (FileNotFoundError, KeyError):
        return None


@dataclass(frozen=True)
class RestoreOutcome:
    """What the restore ladder produced for one splice."""

    state: PyTree | None
    source: str              # "peer" | "checkpoint" | "none"
    cost_seconds: float      # simulated warm-up charge for the path taken


def restore_member_state(cluster, legion: int, failed: int, *,
                         template: PyTree | None = None) -> RestoreOutcome:
    """Peer-first restore ladder for a substituted rank (O(shard) fast path).

    1. Ask the dead member's surviving POV-ring buddy for the in-memory
       replica (``cluster.replicator``): a dict lookup plus one simulated
       cross-member transfer — O(shard), independent of model and cluster
       size — with the replica's checksums re-verified before use.
    2. On correlated loss (buddy dead too — a rack outage spanning adjacent
       legions), a missing replica, or a checksum mismatch, fall back to the
       O(model-size) store read (:func:`restore_for_substitute`).

    ``RestartRecord.source`` distinguishes the paths ("peer" vs
    "checkpoint"); ``cost_seconds`` is what the splice's restore stage
    should charge — the link-model transfer for a peer hit, the cost
    model's ``restore_seconds`` for a store read.
    """
    from repro.checkpoint.replicate import (
        ReplicaIntegrityError,
        ReplicaUnavailable,
    )
    from repro.core.cr import RestartRecord

    replicator = getattr(cluster, "replicator", None)
    if replicator is not None and replicator.enabled:
        try:
            state, served = replicator.restore(failed, cluster.topo,
                                               cluster.failed)
        except (ReplicaUnavailable, ReplicaIntegrityError):
            pass                     # fall through to the store
        else:
            if cluster.checkpointer is not None:
                cluster.checkpointer.restarts.append(RestartRecord(
                    node=failed, legion=legion, step=served.step,
                    source="peer"))
            return RestoreOutcome(state, "peer", served.transfer_seconds)
    state = restore_for_substitute(cluster.checkpointer, legion, failed,
                                   template=template)
    return RestoreOutcome(
        state, "checkpoint" if state is not None else "none",
        cluster.substitute.cost.restore_seconds)
