"""ChaosHarness — run workloads under correlated-failure campaigns and
judge them by invariants, not by survival.

A chaos campaign (:mod:`repro.core.faultmodel`) is pure data; this module
is the machinery that applies it to a live workload and scores the run.
Two drivers share one invariant suite:

  * **train** — a :class:`LegioExecutor` stepping an allreduce workload
    (the paper's training shape: the step-final collective is the fault
    trap);
  * **serve** — a :class:`~repro.serve.engine.ServeEngine` dispatching
    micro-batched requests (the at-least-once/exactly-once surface).

The pass/fail bar is the invariant checklist, evaluated during and after
the run (every ``InvariantCheck`` must hold):

  * **topology coherence** after every drain that repaired something:
    rings closed at every level, a unique master path from every node to
    the single root, member indices coherent;
  * **ledger conservation** on every registered comm:
    ``posted == delivered + discarded + pending``;
  * **one-terminal-action-per-fault**: no node is repaired twice across
    the whole campaign (partition convergence never double-repairs);
  * **exactly-once serving accounting** (serve driver): every submitted
    request id ends in exactly one of completed / parked / abandoned /
    still-pending, and completions are write-once;
  * **scenario-specific postconditions**: rack repairs stay inside their
    top-level subtree with zero healthy-subtree participants, a fenced
    partition's verdict is exactly the minority, a flapped node's stale
    return is refused by the heartbeat epoch guard, a cascade's repairs
    never spill past the primary's scope.

Recovery setups reuse the serving presets (shrink / substitute /
nonblocking / overlap / adaptive — ``repro.serve.engine.recovery_preset``),
so the chaos matrix and the serving benchmarks judge the same
configurations. Chaos clusters run with synthetic replica heartbeats
(``ShardReplicator.heartbeat_every``): even without a checkpointer, small
replica pushes ride the session ledger every other step, so the ledger
conservation invariant is exercised with replication traffic in flight
when a fault lands.
The overlap column (background revoke-then-repair) adds its own invariant:
**zero healthy-subtree sim-clock charge during a disjoint-scope repair** —
``ClusterClock.residual_seconds`` stays 0.0 for the whole campaign, i.e.
every overlapped repair window hid entirely behind the healthy subtrees'
own compute and nobody ever waited on a remote scope's recovery.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.executor import LegioExecutor, VirtualCluster
from repro.core.faultmodel import FaultCampaign, FaultModel
from repro.core.hierarchy import LegionTopology
from repro.core.policy import LegioPolicy
from repro.core.types import ChaosAction, FaultSource, NodeState, RecoveryAction

__all__ = ["ChaosHarness", "ChaosReport", "InvariantCheck",
           "check_topology_coherence"]

RECOVERIES = ("shrink", "substitute", "nonblocking", "overlap", "adaptive")

# synthetic latency fed for a SLOWDOWN target: the straggler detector's
# min_latency floor times the event factor — above the floor and far above
# the healthy median, below it for factor <= 1
_SLOW_BASE = 0.05


@dataclass(frozen=True)
class InvariantCheck:
    name: str
    ok: bool
    detail: str = ""


@dataclass
class ChaosReport:
    """One (scenario, workload, recovery) chaos run, scored by invariants."""

    scenario: str
    workload: str                        # train | serve
    recovery: str   # shrink | substitute | nonblocking | overlap | adaptive
    seed: int
    n_nodes: int
    checks: list[InvariantCheck] = field(default_factory=list)
    counts: dict = field(default_factory=dict)

    @property
    def passed(self) -> bool:
        return all(c.ok for c in self.checks)

    @property
    def failures(self) -> list[InvariantCheck]:
        return [c for c in self.checks if not c.ok]

    def to_dict(self) -> dict:
        return {
            "scenario": self.scenario, "workload": self.workload,
            "recovery": self.recovery, "seed": self.seed,
            "n_nodes": self.n_nodes, "passed": self.passed,
            "checks": [{"name": c.name, "ok": c.ok,
                        **({"detail": c.detail} if not c.ok else {})}
                       for c in self.checks],
            "counts": dict(self.counts),
        }

    def summary(self) -> str:
        verdict = "PASS" if self.passed else "FAIL"
        bad = "" if self.passed else \
            " [" + ", ".join(c.name for c in self.failures) + "]"
        return (f"[{verdict}] {self.scenario}/{self.workload}/"
                f"{self.recovery} n={self.n_nodes} "
                f"checks={len(self.checks)}{bad}")


def check_topology_coherence(topo: LegionTopology,
                             label: str = "topology_coherent"
                             ) -> InvariantCheck:
    """Rings closed at every level, unique master path to a single root,
    coherent member indices — the structural half of the paper's
    properties (a)–(c), checked on the live post-repair topology."""
    problems: list[str] = []
    nodes = topo.nodes
    if not nodes:
        return InvariantCheck(label, False, "topology is empty")
    root = min(nodes)
    if set(topo._by_member) != set(nodes):
        problems.append("_by_member index drifted from the member set")
    if not set(nodes) <= set(topo.home):
        problems.append("home map is missing members")
    for node in nodes:
        chain = topo.master_chain(node)
        if chain[-1] != root:
            problems.append(f"master chain of {node} ends at {chain[-1]}, "
                            f"not the root {root}")
            break
    for level in range(max(topo.depth - 1, 1)):
        idxs = [g.index for g in topo.groups(level)]
        if not idxs:
            continue
        cur, seen = idxs[0], []
        for _ in idxs:
            seen.append(cur)
            cur = topo.successor_at(level, cur).index
        if cur != idxs[0] or sorted(seen) != sorted(idxs):
            problems.append(f"successor ring open at level {level}")
        if any(topo.predecessor_at(level,
                                   topo.successor_at(level, gi).index).index
               != gi for gi in idxs):
            problems.append(f"pred/succ disagree at level {level}")
    for level in range(1, topo.depth):
        child_masters = sorted(g.members[0] for g in topo.groups(level - 1))
        members = sorted(m for g in topo.groups(level) for m in g.members)
        if child_masters != members:
            problems.append(f"level {level} membership is not the child "
                            f"masters")
    return InvariantCheck(label, not problems, "; ".join(problems[:3]))


class ChaosHarness:
    """Applies a :class:`FaultCampaign` to a live workload and scores it."""

    def __init__(self, policy: LegioPolicy | None = None, seed: int = 0):
        self.policy = policy or LegioPolicy()
        self.seed = seed
        self.model = FaultModel(self.policy, seed=seed)

    def _policy_for(self, recovery: str) -> LegioPolicy:
        from repro.serve.engine import recovery_preset
        return replace(self.policy, **recovery_preset(recovery))

    # -- campaign application ----------------------------------------------

    def _apply_chaos(self, campaign: FaultCampaign, cluster: VirtualCluster,
                     step: int, checks: list[InvariantCheck],
                     state: dict) -> None:
        """Apply this step's non-CRASH events (CRASH rides the injector)
        and sustain active slowdowns while their targets live. ``state``
        carries the cross-step bookkeeping: active slowdown factors and
        flap returns waiting for their repair."""
        slow = state.setdefault("slow", {})
        flaps = state.setdefault("flaps", [])
        for e in campaign.at(step):
            if e.action is ChaosAction.SUSPECT:
                cluster.pipeline.observe_suspicion(e.observers, e.nodes,
                                                   step=step)
            elif e.action is ChaosAction.SLOWDOWN:
                for n in e.nodes:
                    slow[n] = e.factor
            elif e.action is ChaosAction.FLAP_RETURN:
                flaps.extend(e.nodes)
        # a flap models "comes back after the repair already evicted it":
        # the stale return lands once the node is confirmed FAILED. Under
        # serving an idle victim may only be confirmed by the heartbeat
        # timeout several rounds later — the node keeps knocking until then
        for n in list(flaps):
            if cluster.detector.states.get(n) is NodeState.FAILED:
                self._apply_flap(cluster, n, checks)
                flaps.remove(n)
        for n in list(slow):
            if n in cluster.topo.nodes and n not in cluster.failed:
                cluster.straggler.observe(n, _SLOW_BASE * slow[n])
            else:
                del slow[n]          # soft-failed or repaired out: done

    @staticmethod
    def _apply_flap(cluster: VirtualCluster, node: int,
                    checks: list[InvariantCheck]) -> None:
        """A repaired-out node announces itself with its old identity: a
        stale beat plus a stale (epoch-less) re-registration. Both must
        bounce off the HeartbeatDetector's epoch guard."""
        det = cluster.detector
        now = cluster.clock.sim_seconds
        resurrected = det.register(node, now)           # stale: no epoch
        det.beat(node, now)                             # stale beat
        still_dead = (det.states.get(node) is NodeState.FAILED
                      and node not in cluster.topo.nodes)
        checks.append(InvariantCheck(
            "flap_stale_return_refused", (not resurrected) and still_dead,
            f"node {node}: register -> {resurrected}, "
            f"state {det.states.get(node)}"))

    # -- shared invariant suite --------------------------------------------

    @staticmethod
    def _one_terminal_action(actions: list[RecoveryAction]
                             ) -> InvariantCheck:
        seen: dict[int, int] = {}
        for a in actions:
            for n in a.verdict:
                seen[n] = seen.get(n, 0) + 1
        dup = sorted(n for n, c in seen.items() if c != 1)
        return InvariantCheck(
            "one_terminal_action_per_fault", not dup,
            f"nodes repaired more than once: {dup[:5]}")

    @staticmethod
    def _check_flaps_landed(campaign: FaultCampaign, state: dict,
                            checks: list[InvariantCheck]) -> None:
        """Every scheduled flap return must have been applied (victim got
        confirmed FAILED within the run) — a no-op for other scenarios."""
        if any(e.action is ChaosAction.FLAP_RETURN for e in campaign.events):
            leftover = state.get("flaps", [])
            checks.append(InvariantCheck(
                "flap_return_landed", not leftover,
                f"victims never confirmed failed, so the stale return was "
                f"never exercised: {leftover}"))

    @staticmethod
    def _ledgers_conserved(session) -> InvariantCheck:
        bad = [repr(c) for c in session._comms if not c.ledger.conserved()]
        return InvariantCheck(
            "message_ledgers_conserved", not bad,
            f"posted != delivered+discarded+pending on {bad[:2]}")

    @staticmethod
    def _overlap_checks(recovery: str, cluster: VirtualCluster,
                        actions: list[RecoveryAction]
                        ) -> list[InvariantCheck]:
        """Overlap-column invariants: every repair actually deferred its
        charge to a background window, and no healthy subtree was ever
        charged for a disjoint scope's repair (zero residual wait — the
        windows all hid behind the workload's own sim-clock progress)."""
        if recovery != "overlap":
            return []
        clock = cluster.clock
        blocking = [a for a in actions
                    if a.report is not None and not a.overlapped]
        return [
            InvariantCheck(
                "repairs_ran_overlapped", not blocking,
                f"{len(blocking)} repair(s) charged synchronously under "
                f"the overlap preset (steps "
                f"{sorted({a.step for a in blocking})[:4]})"),
            InvariantCheck(
                "healthy_subtree_clock_unaffected",
                clock.residual_seconds == 0.0,
                f"{clock.residual_seconds:.4f} sim-s of repair residual "
                f"charged to the clock during disjoint-scope repairs "
                f"(hidden={clock.hidden_seconds:.4f})"),
        ]

    def _scenario_checks(self, campaign: FaultCampaign,
                         actions: list[RecoveryAction],
                         cluster: VirtualCluster,
                         workload: str) -> list[InvariantCheck]:
        repaired = {n for a in actions for n in a.verdict}
        m, out = campaign.meta, []
        if campaign.scenario == "independent":
            out.append(InvariantCheck(
                "all_victims_repaired", set(m["victims"]) <= repaired,
                f"missing {sorted(set(m['victims']) - repaired)}"))
            out.append(InvariantCheck(
                "no_collateral_repairs", repaired <= set(m["victims"]),
                f"extra {sorted(repaired - set(m['victims']))}"))
        elif campaign.scenario == "rack_outage":
            rack_members = {n for r in m["racks"] for n in r["members"]}
            out.append(InvariantCheck(
                "racks_fully_repaired", rack_members == repaired,
                f"diff {sorted(rack_members ^ repaired)[:6]}"))
            if workload == "train":
                # the step-final collective makes every survivor notice at
                # once, so disjoint racks resolve in a single drain; under
                # serving, idle rack members surface later through the
                # heartbeat channel — one-drain is a train-only guarantee
                steps = {a.step for a in actions}
                out.append(InvariantCheck(
                    "racks_resolved_in_one_drain", len(steps) == 1,
                    f"drains at steps {sorted(steps)}"))
            # participants must stay inside the rack's own top-level
            # subtree — healthy subtrees contribute exactly zero
            sides = FaultModel._subtree_members(
                self.model._topo(campaign.n_nodes))
            outside = 0
            for a in actions:
                rack = next((r for r in m["racks"]
                             if set(a.verdict) <= set(r["members"])), None)
                if rack is None or a.scope is None:
                    continue
                # spares (ids >= n) spliced into the rack's own slots are
                # subtree members by assignment — only original nodes can
                # witness cross-subtree participation
                outside += len(
                    {p for p in a.scope.participants
                     if p < campaign.n_nodes} - set(sides[rack["subtree"]]))
            out.append(InvariantCheck(
                "healthy_subtree_participation_zero", outside == 0,
                f"{outside} participants outside the faulty subtree"))
            # concurrency is claimed per drain: scopes emitted at the same
            # step must have pairwise-disjoint participants (sequential
            # drains may legitimately reuse survivors)
            by_step: dict[int, list[set[int]]] = {}
            for a in actions:
                if a.scope is not None:
                    by_step.setdefault(a.step, []).append(
                        set(a.scope.participants))
            disjoint = all(
                not (parts[i] & parts[j])
                for parts in by_step.values()
                for i in range(len(parts)) for j in range(i + 1, len(parts)))
            out.append(InvariantCheck(
                "rack_scopes_disjoint_per_drain", disjoint,
                "same-drain scope participant sets overlap"))
        elif campaign.scenario == "network_partition":
            minority, majority = set(m["minority"]), set(m["majority"])
            if m["fenced"]:
                out.append(InvariantCheck(
                    "verdict_is_exactly_the_minority", repaired == minority,
                    f"diff {sorted(repaired ^ minority)[:6]}"))
            else:
                # unfenced: the agree stage's majority quorum resolves the
                # split — the minority is condemned exactly once, never the
                # other way around and never both sides
                out.append(InvariantCheck(
                    "minority_repaired_at_most_once",
                    all(sum(1 for a in actions if n in a.verdict) <= 1
                        for n in minority),
                    "a minority node appears in two terminal verdicts"))
            out.append(InvariantCheck(
                "majority_never_repaired", not (repaired & majority),
                f"majority nodes repaired: "
                f"{sorted(repaired & majority)[:6]}"))
        elif campaign.scenario == "transient_flap":
            victim = m["victim"]
            times = sum(1 for a in actions if victim in a.verdict)
            out.append(InvariantCheck(
                "victim_repaired_exactly_once", times == 1,
                f"victim {victim} repaired {times} times"))
            out.append(InvariantCheck(
                "victim_stays_out", victim not in cluster.topo.nodes,
                f"victim {victim} is back in the topology"))
            spliced = {s for r in cluster.repairs
                       for _, s in r.substitutions}
            out.append(InvariantCheck(
                "flap_identity_never_reused_as_spare",
                victim not in spliced,
                f"victim {victim} spliced back in as a spare"))
        elif campaign.scenario == "cascade":
            expected = {m["primary"]} | set(m["secondaries"])
            out.append(InvariantCheck(
                "primary_repaired", m["primary"] in repaired,
                f"primary {m['primary']} never repaired"))
            soft = any(FaultSource.STRAGGLER in a.sources for a in actions)
            out.append(InvariantCheck(
                "secondary_straggler_softfails_fired",
                soft or not m["secondaries"],
                "no STRAGGLER-sourced action despite slowdown targets"))
            out.append(InvariantCheck(
                "no_repairs_outside_primary_scope", repaired <= expected,
                f"extra {sorted(repaired - expected)[:6]}"))
        return out

    # -- drivers -------------------------------------------------------------

    def run_train(self, scenario: str, n_nodes: int,
                  recovery: str = "shrink", steps: int | None = None,
                  **knobs) -> ChaosReport:
        """Drive a training workload (allreduce each step) under the
        campaign; the step-final collective is the fault trap."""
        campaign = self.model.campaign(scenario, n_nodes, **knobs)
        pol = self._policy_for(recovery)
        cluster = VirtualCluster(n_nodes, policy=pol,
                                 injector=campaign.injector())
        # synthetic replica pushes every other step: the ledger conservation
        # invariant must hold with replication traffic in flight
        cluster.replicator.heartbeat_every = 2
        ex = LegioExecutor(cluster, work_fn=lambda node, shard, step: 1.0)
        checks: list[InvariantCheck] = []
        actions: list[RecoveryAction] = []
        cluster.pipeline.add_listener(actions.append)
        state: dict = {}
        horizon = steps if steps is not None else campaign.horizon + 6
        for step in range(horizon):
            self._apply_chaos(campaign, cluster, step, checks, state)
            report = ex.run_step(step)
            if report.actions:
                checks.append(check_topology_coherence(
                    cluster.topo, f"topology_coherent_step{step}"))
        checks.append(check_topology_coherence(cluster.topo))
        checks.append(self._one_terminal_action(actions))
        checks.append(self._ledgers_conserved(ex.session))
        self._check_flaps_landed(campaign, state, checks)
        checks.extend(self._scenario_checks(campaign, actions, cluster,
                                            "train"))
        checks.extend(self._overlap_checks(recovery, cluster, actions))
        return ChaosReport(
            scenario=scenario, workload="train", recovery=recovery,
            seed=self.seed, n_nodes=n_nodes, checks=checks,
            counts={
                "steps": horizon,
                "events": len(campaign.events),
                "actions": len(actions),
                "repaired": sorted({n for a in actions for n in a.verdict}),
                "repairs": len(cluster.repairs),
                "survivors": len(cluster.live_nodes),
                "sim_seconds": round(cluster.clock.sim_seconds, 6),
                "hidden_seconds": round(cluster.clock.hidden_seconds, 6),
                "residual_seconds": round(cluster.clock.residual_seconds, 6),
            })

    def run_serve(self, scenario: str, n_nodes: int,
                  recovery: str = "shrink", requests: int | None = None,
                  **knobs) -> ChaosReport:
        """Drive a serving workload under the campaign; the per-round
        result gather is the fault trap, and the exactly-once ledger is
        part of the pass bar."""
        import math as _math

        from repro.serve.engine import ServeEngine
        from repro.serve.traffic import Arrival

        campaign = self.model.campaign(scenario, n_nodes, **knobs)
        pol = self._policy_for(recovery)
        cluster = VirtualCluster(n_nodes, policy=pol,
                                 injector=campaign.injector())
        cluster.replicator.heartbeat_every = 2
        engine = ServeEngine(
            cluster, work_fn=lambda node, batch, step:
            {r.rid: r.rid for r in batch})
        total = requests if requests is not None else 3 * n_nodes
        checks: list[InvariantCheck] = []
        actions: list[RecoveryAction] = []
        cluster.pipeline.add_listener(actions.append)
        state: dict = {}
        # unlike training, serving has no all-hands collective: a victim
        # that dies with no dispatched batch only surfaces through the
        # heartbeat timeout, so the round loop must outlive it
        horizon = campaign.horizon + 4 + int(
            pol.heartbeat_timeout / pol.step_sim_seconds)
        per_round = max(1, total // horizon)
        submitted = 0
        for step in range(horizon):
            if submitted < total:
                batch = min(per_round, total - submitted)
                # alternate payload-less one-tick requests with multi-tick
                # decode-heavy ones, so decode-state migration is exercised
                # by every scenario x recovery cell, not just the benchmark
                engine.submit([
                    Arrival(user=i, slo_class="batch",
                            slo_seconds=_math.inf, prefill_ticks=1,
                            decode_ticks=3) if i % 2 else None
                    for i in range(batch)])
                submitted += batch
            self._apply_chaos(campaign, cluster, step, checks, state)
            report = engine.run_round(step)
            if report.actions:
                checks.append(check_topology_coherence(
                    cluster.topo, f"topology_coherent_step{step}"))
        # drain the backlog to a quiescent state, then account for every id
        drain = engine.serve(max_rounds=50 + 4 * horizon)
        checks.append(check_topology_coherence(cluster.topo))
        checks.append(self._one_terminal_action(actions))
        checks.append(self._ledgers_conserved(engine.session))
        accounted = (len(engine.completed) + len(engine.metrics.parked)
                     + len(engine.metrics.abandoned)
                     + len(engine.metrics.shed) + engine.pending)
        checks.append(InvariantCheck(
            "exactly_once_accounting", accounted == submitted,
            f"{accounted} accounted for, {submitted} submitted "
            f"(completed={len(engine.completed)}, "
            f"parked={len(engine.metrics.parked)}, "
            f"abandoned={len(engine.metrics.abandoned)}, "
            f"shed={len(engine.metrics.shed)}, "
            f"pending={engine.pending})"))
        # decode-state migration must never double-complete: one completion
        # record per client-visible id, migrated or not
        comp_rids = [r.rid for r in engine.metrics.completions]
        checks.append(InvariantCheck(
            "completions_unique", len(comp_rids) == len(set(comp_rids))
            and len(comp_rids) == len(engine.completed),
            f"{len(comp_rids)} completion records over "
            f"{len(set(comp_rids))} unique ids "
            f"({engine.metrics.migrations} migrations)"))
        self._check_flaps_landed(campaign, state, checks)
        checks.extend(self._scenario_checks(campaign, actions, cluster,
                                            "serve"))
        checks.extend(self._overlap_checks(recovery, cluster, actions))
        return ChaosReport(
            scenario=scenario, workload="serve", recovery=recovery,
            seed=self.seed, n_nodes=n_nodes, checks=checks,
            counts={
                "rounds": horizon + drain.rounds,
                "events": len(campaign.events),
                "actions": len(actions),
                "submitted": submitted,
                "completed": len(engine.completed),
                "requeues": engine.metrics.requeues,
                "duplicates_suppressed":
                    engine.metrics.duplicates_suppressed,
                "migrations": engine.metrics.migrations,
                "decode_ticks_preserved":
                    engine.metrics.decode_ticks_preserved,
                "survivors": len(cluster.live_nodes),
                "hidden_seconds": round(cluster.clock.hidden_seconds, 6),
                "residual_seconds": round(cluster.clock.residual_seconds, 6),
            })

    # -- the matrix ----------------------------------------------------------

    def run_matrix(self, n_nodes: int,
                   scenarios: tuple[str, ...] = FaultModel.SCENARIOS,
                   recoveries: tuple[str, ...] = RECOVERIES,
                   workloads: tuple[str, ...] = ("train", "serve"),
                   ) -> list[ChaosReport]:
        """Every (scenario × recovery × workload) cell — the benchmark's
        and CI's pass bar is ``all(r.passed for r in ...)``."""
        out = []
        for scenario in scenarios:
            for recovery in recoveries:
                if "train" in workloads:
                    out.append(self.run_train(scenario, n_nodes,
                                              recovery=recovery))
                if "serve" in workloads:
                    out.append(self.run_serve(scenario, n_nodes,
                                              recovery=recovery))
        return out
