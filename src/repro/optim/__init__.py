from repro.optim.adamw import (
    OptState,
    adamw_init,
    adamw_update,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.optim.compression import (
    decompress_int8,
    compress_int8,
    compress_topk,
    decompress_topk,
    make_compressor,
)

__all__ = [
    "OptState",
    "adamw_init",
    "adamw_update",
    "apply_updates",
    "clip_by_global_norm",
    "cosine_schedule",
    "global_norm",
    "compress_int8",
    "decompress_int8",
    "compress_topk",
    "decompress_topk",
    "make_compressor",
]
