"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Written against pytrees directly (no optax dependency in this container).
Moments are stored in ``state_dtype`` (fp32 default; grok-314B's config may
select bf16 ``v`` to fit HBM — see EXPERIMENTS.md §Dry-run).

The update is written to be GSPMD-friendly: every per-leaf op is elementwise,
so optimizer state inherits the parameter sharding and the update adds zero
collectives (only the global-norm clip contributes one scalar all-reduce,
fused by XLA with the gradient reduction).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

PyTree = Any


class OptState(NamedTuple):
    step: jax.Array   # () int32
    mu: PyTree        # first moment
    nu: PyTree        # second moment


def adamw_init(params: PyTree, *, state_dtype: str = "float32") -> OptState:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def cosine_schedule(tc: TrainConfig):
    """lr(step): linear warmup -> cosine decay to 10% of peak."""

    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = tc.learning_rate * s / max(tc.warmup_steps, 1)
        prog = jnp.clip((s - tc.warmup_steps) / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
        cos = tc.learning_rate * (0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < tc.warmup_steps, warm, cos)

    return lr


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads: PyTree,
    state: OptState,
    params: PyTree,
    tc: TrainConfig,
    lr: jax.Array,
) -> tuple[PyTree, OptState]:
    """Returns (updates, new_state); apply with ``apply_updates``."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - tc.beta1 ** t
    bc2 = 1.0 - tc.beta2 ** t

    def per_leaf(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = tc.beta1 * m.astype(jnp.float32) + (1.0 - tc.beta1) * gf
        v_new = tc.beta2 * v.astype(jnp.float32) + (1.0 - tc.beta2) * jnp.square(gf)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        upd = m_hat / (jnp.sqrt(v_hat) + tc.eps) + tc.weight_decay * p.astype(jnp.float32)
        return (-lr * upd).astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    out = [per_leaf(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    updates = treedef.unflatten([o[0] for o in out])
    mu = treedef.unflatten([o[1] for o in out])
    nu = treedef.unflatten([o[2] for o in out])
    return updates, OptState(step=step, mu=mu, nu=nu)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
