"""Gradient compression for cross-legion reduction (beyond-paper feature).

Legio's hierarchical topology makes the cross-legion (master-to-master)
all-reduce the long-haul hop — on a multi-pod TPU deployment it crosses DCI
links an order of magnitude slower than intra-pod ICI. Both schemes here are
error-feedback compressors: the compression residual is carried to the next
step so the compressed-SGD iterates stay within O(1) of the exact ones
(Karimireddy et al. 2019).

  int8  : per-tensor absmax scaling, 4x (bf16) / 2x (int16-free) volume cut.
  topk  : keep the top-k fraction of |g| entries (flattened), send values +
          int32 indices; volume ~ 2 * k * |g|.

Both are pure-JAX and shard-transparent: applied leaf-wise before the
cross-legion reduce, decompressed after.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class Int8Grad(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # () fp32 absmax / 127


class TopKGrad(NamedTuple):
    values: jax.Array   # (k,) fp32
    indices: jax.Array  # (k,) int32
    size: int           # original flattened size (static)


def compress_int8(g: jax.Array) -> Int8Grad:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return Int8Grad(q=q, scale=scale)


def decompress_int8(c: Int8Grad, dtype=jnp.float32) -> jax.Array:
    return (c.q.astype(jnp.float32) * c.scale).astype(dtype)


def compress_topk(g: jax.Array, fraction: float) -> TopKGrad:
    flat = g.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.size * fraction))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return TopKGrad(values=flat[idx], indices=idx.astype(jnp.int32), size=flat.size)


def decompress_topk(c: TopKGrad, shape, dtype=jnp.float32) -> jax.Array:
    out = jnp.zeros((c.size,), jnp.float32).at[c.indices].set(c.values)
    return out.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# numpy twins — the sim data plane's compressors. Bitwise-identical to the
# jax pair above for float32 inputs: absmax/clip/round (half-to-even) and the
# q*scale product are elementwise IEEE f32 ops, and the stable descending
# argsort matches lax.top_k's lowest-index-first tie-breaking. The parity is
# a tested invariant (tests/test_dataplane.py), not an accident — it is what
# lets the two data planes produce byte-identical collective results.
# ---------------------------------------------------------------------------

def compress_int8_np(g: np.ndarray) -> Int8Grad:
    gf = np.asarray(g, dtype=np.float32)
    scale = np.maximum(np.max(np.abs(gf)), np.float32(1e-12)) / np.float32(127.0)
    q = np.clip(np.round(gf / scale), -127, 127).astype(np.int8)
    return Int8Grad(q=q, scale=np.float32(scale))


def decompress_int8_np(c: Int8Grad, dtype=np.float32) -> np.ndarray:
    return (np.asarray(c.q, np.float32) * np.float32(c.scale)).astype(dtype)


def compress_topk_np(g: np.ndarray, fraction: float) -> TopKGrad:
    flat = np.asarray(g, dtype=np.float32).reshape(-1)
    k = max(1, int(flat.size * fraction))
    idx = np.argsort(-np.abs(flat), kind="stable")[:k].astype(np.int32)
    return TopKGrad(values=flat[idx], indices=idx, size=flat.size)


def decompress_topk_np(c: TopKGrad, shape, dtype=np.float32) -> np.ndarray:
    out = np.zeros((c.size,), np.float32)
    out[np.asarray(c.indices)] = np.asarray(c.values, np.float32)
    return out.reshape(shape).astype(dtype)


def compressed_bytes(g, scheme: str, fraction: float = 0.05) -> int:
    """Wire bytes after compression (used by the collective roofline model)."""
    n = g.size
    if scheme == "int8":
        return n + 4
    if scheme == "topk":
        k = max(1, int(n * fraction))
        return 8 * k
    return n * g.dtype.itemsize


def make_compressor(scheme: str, fraction: float = 0.05):
    """Returns (compress_tree, decompress_tree) closing over error feedback.

    compress(grads, residual) -> (payload, new_residual)
    decompress(payload, template) -> grads
    """
    if scheme == "none":
        def comp(grads, residual):
            return grads, residual
        def decomp(payload, template):
            return payload
        return comp, decomp

    if scheme == "int8":
        def comp(grads, residual):
            def one(g, r):
                gf = g.astype(jnp.float32) + (r if r is not None else 0.0)
                c = compress_int8(gf)
                return c, gf - decompress_int8(c)
            flat_g, tdef = jax.tree.flatten(grads)
            flat_r = jax.tree.leaves(residual) if residual is not None else [None] * len(flat_g)
            pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
            return tdef.unflatten([p[0] for p in pairs]), tdef.unflatten([p[1] for p in pairs])

        def decomp(payload, template):
            return jax.tree.map(
                lambda c, t: decompress_int8(c, t.dtype),
                payload, template,
                is_leaf=lambda x: isinstance(x, Int8Grad),
            )
        return comp, decomp

    if scheme == "topk":
        def comp(grads, residual):
            def one(g, r):
                gf = g.astype(jnp.float32) + (r if r is not None else 0.0)
                c = compress_topk(gf, fraction)
                return c, gf - decompress_topk(c, g.shape)
            flat_g, tdef = jax.tree.flatten(grads)
            flat_r = jax.tree.leaves(residual) if residual is not None else [None] * len(flat_g)
            pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
            return tdef.unflatten([p[0] for p in pairs]), tdef.unflatten([p[1] for p in pairs])

        def decomp(payload, template):
            return jax.tree.map(
                lambda c, t: decompress_topk(c, t.shape, t.dtype),
                payload, template,
                is_leaf=lambda x: isinstance(x, TopKGrad),
            )
        return comp, decomp

    raise ValueError(f"unknown compression scheme {scheme!r}")
