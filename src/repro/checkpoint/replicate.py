"""Ring-replicated in-memory shard checkpoints — O(shard) warm-spare restore.

The substitution path restores a spare from the on-disk store: one npz read
plus a manifest parse that is O(members) — an O(model-size) term sitting on
the critical warm-up path (``SubstituteCostModel.restore_seconds`` charges
it on every blocking splice). This module keeps a *second*, in-memory copy
of every member's host-snapshotted state shard on its POV-ring buddy
(``LegionTopology.buddy_of`` — the successor-legion pairing ``pov()``
already defines for masters, generalized to all members):

  * **push on every async checkpoint** — ``LegionCheckpointer.save`` hands
    the freshly host-snapshotted shard map to :meth:`ShardReplicator.push`;
    each shard is checksummed (the store's own ``_checksum``) and posted to
    its buddy as one point-to-point envelope on the world
    :class:`~repro.mpi.ledger.MessageLedger` — replication traffic rides
    the same fault-aware p2p as application messages, so a buddy dying
    mid-flight discards the envelope (and the replica) for free, and the
    ledger conservation invariant covers replication without new machinery;
  * **O(shard) restore** — ``restore_member_state`` (core.substitute) asks
    the surviving buddy first: a dict lookup plus one simulated
    cross-member transfer charged through :class:`LinkModel`
    (``alpha_cross + nbytes / beta_cross`` — the buddy lives in the
    successor legion, a cross-legion link), independent of cluster and
    model size. Checksums are re-verified on the stored arrays; a mismatch
    (or a dead buddy — correlated loss, e.g. a rack outage spanning
    adjacent legions) falls back to ``store.restore_member``;
  * **re-homing on topology mutations** — shrink/substitute/expand change
    the ring, so committed replicas are re-homed the way
    ``SpareProvisioner`` re-homes slots: lazily at the next boundary tick,
    one holder-to-new-buddy transfer per moved replica; replicas whose
    holder died are dropped (that is exactly the correlated-loss surface
    the store fallback exists for).

Everything here is simulation bookkeeping: the "network" is the ledger,
the "memory" is this object, and the costs are the alpha-beta link model —
consistent with how the rest of the runtime charges repair work.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.checkpoint.store import _checksum, _flatten, _to_numpy
from repro.core.collectives import LinkModel
from repro.core.hierarchy import LegionTopology

PyTree = Any

# Tag replication envelopes ride under on the world ledger — far above the
# small integers applications use, so replica traffic never matches an
# application recv.
REPLICA_TAG = 7701


class ReplicaUnavailable(LookupError):
    """No usable replica: never pushed, still in flight, or the buddy that
    held it is dead (correlated loss) — fall back to the store."""


class ReplicaIntegrityError(IOError):
    """A held replica failed its checksum re-verification — treat it as
    lost and fall back to the store, never splice corrupt state."""


@dataclass
class ReplicaRecord:
    """One member's replicated shard, as held by its ring buddy."""

    owner: int                       # the member whose state this is
    holder: int                      # the buddy holding the copy
    legion: int                      # owner's home legion at push time
    step: int                        # checkpoint step of the snapshot
    arrays: dict[str, np.ndarray]    # flattened host snapshot
    dtypes: dict[str, str]           # logical dtypes (bf16 round-trip)
    checksums: dict[str, str]        # per-leaf, store._checksum
    nbytes: int

    def verify(self) -> None:
        for key, arr in self.arrays.items():
            if _checksum(arr) != self.checksums[key]:
                raise ReplicaIntegrityError(
                    f"replica checksum mismatch for {key} "
                    f"(owner {self.owner}, holder {self.holder})")

    def as_tree(self) -> PyTree:
        """Rebuild the nested state dict from '/'-joined keys (the same
        shape ``store.restore_member`` returns for dict-of-dict trees)."""
        out: dict = {}
        for key, arr in self.arrays.items():
            parts = key.split("/")
            d = out
            for p in parts[:-1]:
                d = d.setdefault(p, {})
            d[parts[-1]] = np.array(arr)
        return out


@dataclass
class PeerRestore:
    """One restore served from a surviving buddy (the replicator's own log;
    ``LegionCheckpointer.restarts`` records the same event when a
    checkpointer is attached, with ``source="peer"``)."""

    node: int
    legion: int
    step: int
    holder: int
    nbytes: int
    transfer_seconds: float


@dataclass
class ShardReplicator:
    """In-memory buddy replicas of per-member state shards.

    One instance per :class:`VirtualCluster` (``cluster.replicator``).
    Pushes are posted as ledger envelopes and settle at the *next* session
    boundary — replication traffic is genuinely in flight across a step,
    and a holder that dies mid-flight loses the replica exactly as a dead
    receiver loses any p2p message. Without a session (standalone use in
    unit tests) pushes commit immediately.
    """

    link: LinkModel = field(default_factory=LinkModel)
    enabled: bool = True
    # synthetic heartbeat-shard cadence in steps (chaos campaigns have no
    # trainer state to snapshot but still need replication traffic in
    # flight); 0 disables synthetic pushes
    heartbeat_every: int = 0
    cluster: Any = None              # VirtualCluster backref (set on wiring)

    replicas: dict[int, ReplicaRecord] = field(default_factory=dict)
    inflight: list[tuple[Any, ReplicaRecord]] = field(default_factory=list)
    served: list[PeerRestore] = field(default_factory=list)

    # counters (benchmarks / tests read these)
    pushes: int = 0                  # envelopes posted (or direct commits)
    delivered: int = 0               # in-flight envelopes settled into store
    lost: int = 0                    # replicas dropped with their holder
    rehomed: int = 0                 # committed replicas moved to a new buddy
    corrupt: int = 0                 # checksum mismatches on restore
    bytes_replicated: int = 0
    sim_transfer_seconds: float = 0.0  # background traffic (never charged)

    # -- cost model -----------------------------------------------------------

    def transfer_seconds(self, nbytes: int) -> float:
        """One cross-member shard transfer: the buddy is in the successor
        legion, so the copy rides a cross-legion link."""
        return self.link.alpha_cross + nbytes / self.link.beta_cross

    # -- wiring ---------------------------------------------------------------

    def _ledger(self):
        session = getattr(self.cluster, "_mpi_session", None)
        return session.world.ledger if session is not None else None

    def _step(self) -> int:
        return getattr(self.cluster, "_step", 0)

    @staticmethod
    def _alive(node: int, topo: LegionTopology, failed: set[int]) -> bool:
        return node in topo.nodes and node not in failed

    # -- push (ride the ledger) ------------------------------------------------

    def _snapshot(self, owner: int, holder: int, legion: int, step: int,
                  tree: PyTree) -> ReplicaRecord:
        arrays: dict[str, np.ndarray] = {}
        dtypes: dict[str, str] = {}
        sums: dict[str, str] = {}
        nbytes = 0
        for key, leaf in _flatten(tree).items():
            arr, logical = _to_numpy(leaf)
            arr = np.array(arr)          # own host copy, detached from owner
            arrays[key] = arr
            dtypes[key] = logical
            sums[key] = _checksum(arr)
            nbytes += arr.nbytes
        return ReplicaRecord(owner=owner, holder=holder, legion=legion,
                             step=step, arrays=arrays, dtypes=dtypes,
                             checksums=sums, nbytes=nbytes)

    def push_map(self, step: int, topo: LegionTopology,
                 shards: dict[tuple[int, int], PyTree]) -> int:
        """Replicate an already host-snapshotted shard map ({(legion, node):
        tree}) — the async checkpoint path. Returns replicas posted."""
        if not self.enabled:
            return 0
        ledger = self._ledger()
        posted = 0
        for (legion, node), tree in sorted(shards.items()):
            buddy = topo.buddy_of(node) if node in topo.nodes else None
            if buddy is None:
                continue
            record = self._snapshot(node, buddy, legion, step, tree)
            self.pushes += 1
            self.bytes_replicated += record.nbytes
            self.sim_transfer_seconds += self.transfer_seconds(record.nbytes)
            posted += 1
            if ledger is None:
                self._commit(record)
            else:
                env = ledger.post(node, buddy, REPLICA_TAG,
                                  {"replica_of": node, "step": step,
                                   "nbytes": record.nbytes},
                                  self._step())
                self.inflight.append((env, record))
        return posted

    def push(self, step: int, topo: LegionTopology,
             state_of: Callable[[int], PyTree]) -> int:
        """Snapshot and replicate every live member's shard."""
        shards = {(lg.index, n): state_of(n)
                  for lg in topo.legions for n in lg.members}
        return self.push_map(step, topo, shards)

    def _commit(self, record: ReplicaRecord) -> None:
        self.replicas[record.owner] = record
        self.delivered += 1

    # -- boundary tick (settle / rehome / heartbeat) ---------------------------

    def tick(self, topo: LegionTopology, failed: set[int], step: int) -> None:
        """Run at every session boundary, before pending substitutions are
        polled — freshly settled replicas are visible to this boundary's
        splices."""
        if not self.enabled:
            return
        self._settle(topo, failed, step)
        self._rehome(topo, failed, step)
        if self.heartbeat_every > 0 and step % self.heartbeat_every == 0:
            self.push(step, topo, lambda n: {
                "hb": np.asarray([step, n], dtype=np.int64)})

    def _settle(self, topo: LegionTopology, failed: set[int],
                step: int) -> None:
        """Deliver last boundary's in-flight envelopes whose holder still
        lives; a dead holder's envelope is left for the session's terminal
        -action discard (its recv can never post) and the replica is lost.
        An envelope *from* a now-dead owner still delivers — the payload
        left the sender before the death (ledger semantics), which is what
        makes the freshest replica usable for the owner's own restore."""
        from repro.mpi.ledger import MsgState

        keep: list[tuple[Any, ReplicaRecord]] = []
        for env, record in self.inflight:
            if env.state is MsgState.DISCARDED:
                self.lost += 1
            elif self._alive(record.holder, topo, failed):
                ledger = self._ledger()
                if ledger is not None and env.state is MsgState.POSTED:
                    ledger.deliver(env, step)
                self._commit(record)
            elif env.state is MsgState.POSTED:
                # holder dead but its repair has not landed yet: keep the
                # envelope pending for the discard listener, drop the copy
                self.lost += 1
            else:
                keep.append((env, record))
        self.inflight = keep

    def _rehome(self, topo: LegionTopology, failed: set[int],
                step: int) -> None:
        """Topology mutations change the ring: drop replicas whose holder
        died, move replicas whose live holder is no longer the owner's
        buddy (one holder-to-new-buddy transfer each)."""
        for owner in list(self.replicas):
            record = self.replicas[owner]
            if not self._alive(record.holder, topo, failed):
                del self.replicas[owner]
                self.lost += 1
                continue
            if owner not in topo.nodes:
                continue             # owner gone: keep for a pending splice
            buddy = topo.buddy_of(owner)
            if buddy is None or buddy == record.holder:
                continue
            if not self._alive(buddy, topo, failed):
                continue             # new buddy not usable yet; retry later
            ledger = self._ledger()
            if ledger is not None:
                env = ledger.post(record.holder, buddy, REPLICA_TAG,
                                  {"replica_of": owner, "rehome": True,
                                   "nbytes": record.nbytes}, step)
                ledger.deliver(env, step)
            record.holder = buddy
            self.rehomed += 1
            self.sim_transfer_seconds += self.transfer_seconds(record.nbytes)

    # -- restore (the O(shard) path) -------------------------------------------

    def restore(self, owner: int, topo: LegionTopology, failed: set[int],
                *, verify: bool = True) -> tuple[PyTree, PeerRestore]:
        """Fetch ``owner``'s replica from its surviving holder.

        Raises :class:`ReplicaUnavailable` when no committed replica exists
        or the holder is dead (correlated loss), and
        :class:`ReplicaIntegrityError` when the copy fails checksum
        re-verification — both mean "fall back to the store"."""
        record = self.replicas.get(owner)
        if record is None:
            raise ReplicaUnavailable(f"no replica held for node {owner}")
        if not self._alive(record.holder, topo, failed):
            del self.replicas[owner]
            self.lost += 1
            raise ReplicaUnavailable(
                f"replica holder {record.holder} of node {owner} is dead "
                f"(correlated loss)")
        if verify:
            try:
                record.verify()
            except ReplicaIntegrityError:
                del self.replicas[owner]
                self.corrupt += 1
                raise
        restore = PeerRestore(
            node=owner, legion=record.legion, step=record.step,
            holder=record.holder, nbytes=record.nbytes,
            transfer_seconds=self.transfer_seconds(record.nbytes))
        self.served.append(restore)
        state = record.as_tree()
        del self.replicas[owner]     # consumed: the splice owns it now
        return state, restore

    def drop(self, owner: int) -> None:
        self.replicas.pop(owner, None)
