"""Sharded, per-legion checkpoint store (the §VII / MANA analogue).

Layout::

    <dir>/step_000120/
        manifest.json                 # step, tree structure, checksums, legion map
        legion_00/member_000.npz      # one file per (legion, member)
        legion_00/member_001.npz
        legion_01/member_000.npz
        ...

Properties the paper's design asks for:

  * **No global barrier**: each legion directory is self-contained and
    written independently (file ops run on the local_comm — paper §V
    "File operations"); the manifest is finalized by whoever finishes last
    (atomic rename, idempotent content).
  * **Restart-only-failed**: ``restore_member`` loads exactly one member's
    shard set; a replacement node never touches other members' files.
  * **Async**: ``AsyncCheckpointer`` snapshots device arrays to host
    (blocking only on the copy), then writes in a background thread —
    training continues during serialization.

Arrays are stored as npz with tree paths flattened to ``/``-joined keys.
bfloat16 has no numpy dtype, so bf16 leaves are bit-cast to uint16 and the
manifest records the logical dtype.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# pytree <-> flat dict
# ---------------------------------------------------------------------------

def _flatten(tree: PyTree) -> dict[str, jax.Array]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return entry.name
    return str(entry)


def _to_numpy(x) -> tuple[np.ndarray, str]:
    """Returns (storable array, logical dtype string)."""
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return arr.view(np.uint16), "bfloat16"
    return arr, str(arr.dtype)


def _from_numpy(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical == "bfloat16":
        return arr.view(jnp.bfloat16)
    return arr


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# manifest
# ---------------------------------------------------------------------------

@dataclass
class CheckpointManifest:
    step: int
    n_legions: int
    members: dict[str, list[int]]          # legion id -> member node ids
    files: dict[str, dict] = field(default_factory=dict)  # relpath -> {keys, dtypes, checksums}
    meta: dict = field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps({
            "step": self.step,
            "n_legions": self.n_legions,
            "members": self.members,
            "files": self.files,
            "meta": self.meta,
        }, indent=1, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "CheckpointManifest":
        d = json.loads(s)
        return CheckpointManifest(
            step=d["step"], n_legions=d["n_legions"], members=d["members"],
            files=d["files"], meta=d.get("meta", {}),
        )


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:06d}")


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and \
           os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------

def _write_npz_atomic(path: str, arrays: dict[str, np.ndarray]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **arrays)  # keeps the name: it already ends in .npz
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def save(
    directory: str,
    step: int,
    shards: dict[tuple[int, int], PyTree],
    *,
    meta: dict | None = None,
    verify: bool = True,
) -> CheckpointManifest:
    """shards: {(legion_id, node_id): state pytree} -> one npz per member."""
    sdir = _step_dir(directory, step)
    os.makedirs(sdir, exist_ok=True)
    members: dict[str, list[int]] = {}
    files: dict[str, dict] = {}
    for (legion, node), tree in sorted(shards.items()):
        members.setdefault(str(legion), []).append(node)
        rel = os.path.join(f"legion_{legion:02d}", f"member_{node:03d}.npz")
        flat = _flatten(tree)
        store: dict[str, np.ndarray] = {}
        dtypes: dict[str, str] = {}
        sums: dict[str, str] = {}
        for key, leaf in flat.items():
            arr, logical = _to_numpy(leaf)
            store[key] = arr
            dtypes[key] = logical
            if verify:
                sums[key] = _checksum(arr)
        _write_npz_atomic(os.path.join(sdir, rel), store)
        files[rel] = {"dtypes": dtypes, "checksums": sums}
    manifest = CheckpointManifest(
        step=step, n_legions=len(members), members=members, files=files,
        meta=meta or {},
    )
    tmp = os.path.join(sdir, ".manifest.tmp")
    with open(tmp, "w") as f:
        f.write(manifest.to_json())
    os.replace(tmp, os.path.join(sdir, "manifest.json"))
    return manifest


def _load_npz(path: str, info: dict, template: PyTree | None, verify: bool) -> PyTree:
    with np.load(path) as z:
        flat = {}
        for key in z.files:
            arr = z[key]
            if verify and info["checksums"]:
                got = _checksum(arr)
                want = info["checksums"].get(key)
                if want and got != want:
                    raise IOError(f"checksum mismatch for {key} in {path}")
            flat[key] = _from_numpy(arr, info["dtypes"][key])
    if template is None:
        # rebuild a nested dict from '/'-joined keys (only dict-of-dict trees)
        out: dict = {}
        for key, arr in flat.items():
            parts = key.split("/")
            d = out
            for p in parts[:-1]:
                d = d.setdefault(p, {})
            d[parts[-1]] = jnp.asarray(arr)
        return out
    tmpl_flat = _flatten(template)
    assert set(tmpl_flat) == set(flat), (
        f"checkpoint tree mismatch: {set(tmpl_flat) ^ set(flat)}")
    leaves = [jnp.asarray(flat[k]) for k in tmpl_flat]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(template), leaves)


def _read_manifest(sdir: str) -> CheckpointManifest:
    with open(os.path.join(sdir, "manifest.json")) as f:
        return CheckpointManifest.from_json(f.read())


def member_relpath(legion: int, node: int) -> str:
    return os.path.join(f"legion_{legion:02d}", f"member_{node:03d}.npz")


def restore_member(
    directory: str,
    step: int,
    legion: int,
    node: int,
    *,
    template: PyTree | None = None,
    verify: bool = True,
    manifest: CheckpointManifest | None = None,
) -> PyTree:
    """Load exactly one member's shard — the restart-only-failed path.

    ``manifest`` lets a caller that already parsed the step's manifest
    (``restore`` loops over every member) thread it through instead of
    re-opening and re-parsing ``manifest.json`` per member."""
    sdir = _step_dir(directory, step)
    if manifest is None:
        manifest = _read_manifest(sdir)
    rel = member_relpath(legion, node)
    if rel not in manifest.files:
        raise FileNotFoundError(f"no shard for legion={legion} node={node} at step {step}")
    return _load_npz(os.path.join(sdir, rel), manifest.files[rel], template, verify)


def restore(
    directory: str,
    step: int,
    *,
    template: PyTree | None = None,
    verify: bool = True,
) -> tuple[CheckpointManifest, dict[tuple[int, int], PyTree]]:
    manifest = _read_manifest(_step_dir(directory, step))
    shards = {}
    for legion_s, nodes in manifest.members.items():
        for node in nodes:
            legion = int(legion_s)
            shards[(legion, node)] = restore_member(
                directory, step, legion, node, template=template,
                verify=verify, manifest=manifest)
    return manifest, shards


# ---------------------------------------------------------------------------
# async writer
# ---------------------------------------------------------------------------

class AsyncCheckpointer:
    """Snapshot-to-host synchronously, serialize in a background thread.

    ``save_async`` returns as soon as leaves are fetched to host memory;
    the npz write + manifest rename happen off-thread. ``wait()`` drains
    pending writes (call before reading back or at shutdown).
    """

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._err: list[BaseException] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_shards, meta = item
            try:
                save(self.directory, step, host_shards, meta=meta)
                self._gc()
            except BaseException as e:  # surfaced on wait()
                self._err.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        # Retention counts manifest-complete steps only: a partial dir (no
        # manifest.json — a crashed write) must not consume a keep slot, and
        # it is swept outright. The write queue is serial, so any
        # manifest-less dir here is a dead leftover, never an in-flight save.
        complete, partial = [], []
        for name in os.listdir(self.directory):
            if not name.startswith("step_"):
                continue
            step = int(name.split("_")[1])
            if os.path.exists(os.path.join(self.directory, name,
                                           "manifest.json")):
                complete.append(step)
            else:
                partial.append(step)
        doomed = sorted(complete)[:-self.keep] if self.keep > 0 else []
        for s in doomed + partial:
            sdir = _step_dir(self.directory, s)
            for root, _, names in os.walk(sdir, topdown=False):
                for n in names:
                    os.unlink(os.path.join(root, n))
                if root != sdir:
                    os.rmdir(root)
            os.rmdir(sdir)

    def save_async(self, step: int, shards: dict[tuple[int, int], PyTree],
                   *, meta: dict | None = None) -> float:
        """Returns seconds spent blocking (device->host snapshot only)."""
        t0 = time.perf_counter()
        host = {
            key: jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
            for key, tree in shards.items()
        }
        self._q.put((step, host, meta))
        return time.perf_counter() - t0

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err.pop()

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
