from repro.checkpoint.store import (
    AsyncCheckpointer,
    CheckpointManifest,
    latest_step,
    restore,
    restore_member,
    save,
)
from repro.checkpoint.replicate import (
    REPLICA_TAG,
    PeerRestore,
    ReplicaIntegrityError,
    ReplicaRecord,
    ReplicaUnavailable,
    ShardReplicator,
)

__all__ = [
    "AsyncCheckpointer",
    "CheckpointManifest",
    "PeerRestore",
    "REPLICA_TAG",
    "ReplicaIntegrityError",
    "ReplicaRecord",
    "ReplicaUnavailable",
    "ShardReplicator",
    "latest_step",
    "restore",
    "restore_member",
    "save",
]
