from repro.checkpoint.store import (
    AsyncCheckpointer,
    CheckpointManifest,
    latest_step,
    restore,
    restore_member,
    save,
)

__all__ = [
    "AsyncCheckpointer",
    "CheckpointManifest",
    "latest_step",
    "restore",
    "restore_member",
    "save",
]
