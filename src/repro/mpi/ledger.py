"""Per-communicator message ledger — fault-aware point-to-point matching.

Rocco & Palermo's follow-up ("Fault-Aware Non-Collective Communication
Creation and Reparation in MPI") extends Legio's interposition to calls that
do *not* involve the whole communicator: point-to-point traffic must survive
a peer dying mid-flight without deadlocking the survivor. The ledger is the
simulated network buffer that makes that checkable:

  * every ``send`` posts an :class:`Envelope` (eager buffering — the paper's
    assumption that a completed send's payload has left the sender);
  * ``recv`` matches FIFO per (src, dst, tag) — MPI's non-overtaking rule;
  * when a repair removes a node, envelopes addressed *to* it are discarded
    (nobody will ever post the matching recv), while envelopes *from* it
    stay deliverable — the payload was already buffered when the sender
    died, exactly the discard-vs-deliver split the paper's Fig. 2 argues;
  * nothing is ever silently dropped: ``posted == delivered + discarded +
    pending`` at every instant (the conservation invariant
    tests/test_mpi.py fuzzes over random fault campaigns).

Each :class:`~repro.mpi.comm.Comm` owns one ledger; ``comm_dup`` creates a
fresh one — duplicated communicators are separate matching contexts, the
MPI semantics that makes libraries composable.
"""
from __future__ import annotations

import enum
from collections import deque
from dataclasses import dataclass, field


class MsgState(enum.Enum):
    POSTED = "posted"          # in the network buffer, not yet matched
    DELIVERED = "delivered"    # matched by exactly one recv
    DISCARDED = "discarded"    # destination died before posting the recv


@dataclass
class Envelope:
    """One in-flight point-to-point message."""

    seq: int                   # ledger-wide monotone id (posting order)
    src: int
    dst: int
    tag: int
    payload: object
    posted_step: int
    state: MsgState = MsgState.POSTED
    resolved_step: int | None = None


@dataclass
class MessageLedger:
    """FIFO-matching message store for one communicator context."""

    envelopes: list[Envelope] = field(default_factory=list)
    _queues: dict[tuple[int, int, int], deque] = field(default_factory=dict)
    _seq: int = 0

    # -- posting / matching --------------------------------------------------

    def post(self, src: int, dst: int, tag: int, payload: object,
             step: int) -> Envelope:
        env = Envelope(seq=self._seq, src=src, dst=dst, tag=tag,
                       payload=payload, posted_step=step)
        self._seq += 1
        self.envelopes.append(env)
        self._queues.setdefault((src, dst, tag), deque()).append(env)
        return env

    def match(self, dst: int, src: int, tag: int) -> Envelope | None:
        """Oldest POSTED envelope for (src -> dst, tag), without consuming
        it — MPI's non-overtaking order per (source, tag) channel."""
        q = self._queues.get((src, dst, tag))
        while q:
            if q[0].state is MsgState.POSTED:
                return q[0]
            q.popleft()                      # already resolved: drop lazily
        return None

    def deliver(self, env: Envelope, step: int) -> object:
        if env.state is not MsgState.POSTED:
            raise ValueError(
                f"envelope #{env.seq} already {env.state.value} — a message "
                f"is delivered at most once")
        env.state = MsgState.DELIVERED
        env.resolved_step = step
        q = self._queues.get((env.src, env.dst, env.tag))
        if q and q[0] is env:
            q.popleft()
        payload, env.payload = env.payload, None   # resolved envelopes keep
        return payload                             # accounting, not buffers

    # -- fault awareness -----------------------------------------------------

    def discard_to(self, dead: set[int], step: int) -> list[Envelope]:
        """Discard every POSTED envelope addressed *to* a dead node — its
        matching recv will never be posted. Envelopes *from* dead senders
        are left POSTED: the payload was buffered before the death and the
        surviving receiver still collects it."""
        out = []
        for env in self.envelopes:
            if env.state is MsgState.POSTED and env.dst in dead:
                env.state = MsgState.DISCARDED
                env.resolved_step = step
                env.payload = None                 # drop the buffer with it
                out.append(env)
        return out

    # -- accounting (the conservation invariant) -----------------------------

    @property
    def posted(self) -> int:
        return len(self.envelopes)

    @property
    def delivered(self) -> int:
        return sum(1 for e in self.envelopes if e.state is MsgState.DELIVERED)

    @property
    def discarded(self) -> int:
        return sum(1 for e in self.envelopes if e.state is MsgState.DISCARDED)

    @property
    def pending(self) -> int:
        return sum(1 for e in self.envelopes if e.state is MsgState.POSTED)

    def conserved(self) -> bool:
        """posted == delivered + discarded + pending — no loss, no dup."""
        return self.posted == self.delivered + self.discarded + self.pending
