"""Errors the MPI facade surfaces to applications.

The interposition layer's contract (paper §IV, applied at the call seam):
an application-visible error exists only when the *caller itself* depended
on the dead process — its op's root, or its point-to-point peer. Every
other fault is repaired behind the call and the op retried, so the caller
never sees it. :class:`PeerFailedError` is that one visible case, carrying
the paper's discard semantics: the op's result for this caller is
discarded, nothing was delivered, and the communicator has already been
repaired — the *next* call proceeds on the healed structure.
"""
from __future__ import annotations


class MPISessionError(RuntimeError):
    """Misuse of the session lifecycle (op after finalize, double init)."""


class PeerFailedError(RuntimeError):
    """The caller's root/peer was in the agreed verdict of this call.

    Raised *after* the repair has been applied: catching it and issuing the
    next call is always safe — the topology underneath is already healed.
    ``peers`` names the dead nodes the caller depended on; ``op`` the MPI
    call that surfaced them. ``discarded`` is True when an in-flight
    point-to-point payload was discarded with the peer (the paper's
    discard-and-continue outcome, never a deadlock).
    """

    def __init__(self, message: str, *, op: str = "",
                 peers: tuple[int, ...] = (), discarded: bool = False):
        super().__init__(message)
        self.op = op
        self.peers = tuple(peers)
        self.discarded = discarded


class RecvWouldDeadlockError(RuntimeError):
    """A ``recv`` found no matching message and the sender is *alive*.

    In the step-driven simulation a send must happen-before its recv; a
    recv that would block on a healthy peer is a program-order bug, not a
    fault — surfaced eagerly instead of hanging the driver loop. (A recv
    blocking on a *dead* peer is the fault case and raises
    :class:`PeerFailedError` after draining the pipeline.)
    """
