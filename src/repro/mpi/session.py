"""Session — init/finalize lifecycle for the transparent MPI facade.

A :class:`Session` owns (or adopts) one :class:`~repro.core.executor.
VirtualCluster` and hands out :class:`~repro.mpi.comm.Comm` objects — the
*only* API an application needs. The paper's "zero integration effort"
claim is this module's contract: an app writes an ordinary MPI-shaped loop
(``advance`` the fault-injection clock, compute, call collectives/p2p on a
comm) and every ULFM-analogue mechanism — detection, agreement, strategy
dispatch, topology repair, spare splicing — happens behind the calls.

Step boundaries (``boundary``/``deliver``/``inject``) are the executor's
phase-0 polls packaged once: elastic spare delivery, warmed-up substitute
re-expansion, ground-truth fault arrival, and the sim-clock tick. The
training executor, the serve engine, and standalone facade apps all drive
the same primitives, so their fault behavior cannot drift apart.

Sessions also run the facade-level fault listener: whenever the pipeline
applies a terminal repair, every registered comm's message ledger discards
the in-flight envelopes addressed to the dead nodes (fault-aware
point-to-point reparation — nothing waits on a recv that can never post).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable

from repro.core.executor import VirtualCluster
from repro.core.types import (
    FaultEvent,
    FaultSource,
    RecoveryAction,
    RepairReport,
    RepairScope,
)
from repro.mpi.comm import Comm
from repro.mpi.errors import MPISessionError

_ADOPTED_ATTR = "_mpi_session"


@dataclass(frozen=True)
class BoundaryReport:
    """What one step boundary did (the executor's phase 0, surfaced)."""

    step: int
    respawned: tuple[int, ...] = ()                 # provisioner deliveries
    expansions: tuple[RepairReport, ...] = ()       # non-blocking splices
    actions: tuple[RecoveryAction, ...] = ()        # INJECTED-channel drains
    injected: tuple[int, ...] = ()                  # ground-truth arrivals
    reconciled: tuple[RepairScope, ...] = ()        # overlap windows merged

    @property
    def expanded(self) -> tuple[tuple[int, int], ...]:
        """(failed, spare) pairs spliced at this boundary."""
        return tuple(s for r in self.expansions for s in r.substitutions)


class Session:
    """MPI_Init/MPI_Finalize analogue over the Legio runtime."""

    def __init__(self, nodes: "int | VirtualCluster", **cluster_kwargs):
        """``Session(16, policy=..., injector=...)`` builds a fresh
        VirtualCluster; ``Session(cluster)`` adopts an existing one (the
        executor/serve integration path — see :meth:`adopt`)."""
        if isinstance(nodes, VirtualCluster):
            if cluster_kwargs:
                raise TypeError(
                    "cluster kwargs only apply when Session builds the "
                    "cluster; adopt an existing one without them")
            self.cluster = nodes
        else:
            self.cluster = VirtualCluster(nodes, **cluster_kwargs)
        self._comms: list[Comm] = []
        self._actions: list[RecoveryAction] = []
        self._finalized = False
        self._step = 0
        setattr(self.cluster, _ADOPTED_ATTR, self)
        self.cluster.pipeline.add_listener(self._on_terminal_action)
        self.world = Comm(self, None, name="world")

    @classmethod
    def adopt(cls, cluster: VirtualCluster) -> "Session":
        """The session bound to ``cluster`` — created on first use, shared
        thereafter (executor and serve engine on one cluster must share the
        pipeline bookkeeping, not duplicate it)."""
        existing = getattr(cluster, _ADOPTED_ATTR, None)
        if isinstance(existing, Session) and existing.cluster is cluster:
            return existing
        return cls(cluster)

    # -- lifecycle -------------------------------------------------------------

    @property
    def initialized(self) -> bool:
        return not self._finalized

    def ensure_active(self) -> None:
        if self._finalized:
            raise MPISessionError(
                "session is finalized — no MPI call may follow "
                "MPI_Finalize")

    def finalize(self) -> None:
        """Idempotent MPI_Finalize: freeze the facade surface. The cluster
        itself stays readable (reports, metrics, topology post-mortems)."""
        self._finalized = True

    def __enter__(self) -> "Session":
        self.ensure_active()
        return self

    def __exit__(self, *exc) -> None:
        self.finalize()

    # -- step clock ------------------------------------------------------------

    @property
    def step(self) -> int:
        return self._step

    def deliver(self, step: int | None = None) -> BoundaryReport:
        """Boundary half 1: background repair windows the clock has passed
        reconcile (membership merges back — the deferred half of
        revoke-then-repair, always with zero residual here), then elastic
        re-spawned spares arrive and warmed-up non-blocking substitutes
        rejoin. (The serve engine runs this before dispatch and
        :meth:`inject` after — faults land mid-flight.)"""
        self.ensure_active()
        step = self._begin(step)
        cl = self.cluster
        reconciled = tuple(br.scope for br in cl.reconcile_repairs())
        respawned = cl.poll_provisioner(step)
        replicator = getattr(cl, "replicator", None)
        if replicator is not None:
            # settle in-flight replica pushes and re-home replicas whose
            # buddies changed — BEFORE the splices poll, so a replica that
            # arrived during the warmup window serves this boundary's
            # restores in O(shard)
            replicator.tick(cl.topo, cl.failed, step)
        expansions = cl.poll_substitutions(step)
        return BoundaryReport(step=step, respawned=tuple(respawned),
                              expansions=tuple(expansions),
                              reconciled=reconciled)

    def sync(self) -> tuple[RepairScope, ...]:
        """Force-finish every in-flight background repair window *now*,
        charging the unhidden remainder as residual wait — the explicit
        synchronization point (``Comm.barrier`` calls this; so does any
        rooted op whose root is busy repairing). Returns the merged
        scopes; a no-op when nothing is in flight."""
        self.ensure_active()
        return tuple(br.scope
                     for br in self.cluster.reconcile_repairs(force=True))

    def inject(self, step: int | None = None, *,
               charge: bool = True) -> tuple[int, ...]:
        """Boundary half 2: ground-truth faults due this step land and the
        sim clock ticks (what keeps the heartbeat channel live)."""
        self.ensure_active()
        step = self._step if step is None else step
        self._step = step
        events = self.cluster.inject(step)
        if charge:
            self.cluster.clock.charge(self.cluster.policy.step_sim_seconds)
        return tuple(e.node for e in events)

    def boundary(self, step: int | None = None, *,
                 observe_injected: bool = False,
                 charge: bool = True) -> BoundaryReport:
        """One full step boundary: deliver, then inject. With
        ``observe_injected`` the arrivals also feed the pipeline's INJECTED
        channel and drain immediately (the trainer's ground-truth path — a
        sim stand-in for fault notification arriving before any call)."""
        rep = self.deliver(step)
        injected = self.inject(rep.step, charge=charge)
        actions: tuple[RecoveryAction, ...] = ()
        if observe_injected:
            observed = {n for n in injected if n in self.cluster.topo.nodes}
            if observed:
                self.cluster.pipeline.observe(FaultEvent(
                    nodes=tuple(sorted(observed)), step=rep.step,
                    source=FaultSource.INJECTED))
            actions = tuple(self.cluster.pipeline.drain(
                rep.step, sources=(FaultSource.INJECTED,)))
        return BoundaryReport(step=rep.step, respawned=rep.respawned,
                              expansions=rep.expansions, actions=actions,
                              injected=injected, reconciled=rep.reconciled)

    def advance(self, step: int | None = None) -> BoundaryReport:
        """The standalone app's step tick: run the boundary at ``step``
        (default: one past the previous tick), beat every live node's
        heartbeat, and move the internal clock. A plain loop of
        ``advance() ; comm.<op>(...)`` is a complete resilient program."""
        rep = self.boundary(step)
        self.heartbeat()
        self._step = rep.step + 1
        return rep

    def _begin(self, step: int | None) -> int:
        """Start bookkeeping for a step: resolve the step index and clear
        the per-step action buffer consumers drain via take_actions()."""
        step = self._step if step is None else step
        self._step = step
        self._actions.clear()
        return step

    # -- data plane ------------------------------------------------------------

    @property
    def data_plane(self) -> str:
        """Which backend moves collective payloads ("sim" | "jax") — set
        via ``LegioPolicy.data_plane``, resolved by the cluster."""
        return self.cluster.dataplane.name

    def register_sharded_state(self, name: str,
                               getter: Callable[[], object],
                               setter: Callable[[object], None] | None = None
                               ) -> None:
        """Register live state (a pytree getter/setter pair) for
        post-repair redistribution: after every topology shrink or regrow
        the jax data plane rebuilds its mesh and re-places the tree through
        ``param_specs`` in one measured ``device_put`` pass (a no-op on the
        sim plane). Facade passthrough to the cluster — applications never
        touch the data plane directly."""
        self.cluster.register_sharded_state(name, getter, setter)

    # -- fault plumbing shared by every comm ------------------------------------

    def heartbeat(self) -> None:
        """Beat every live node (liveness is not throughput — idle nodes
        beat too)."""
        cl = self.cluster
        for n in cl.live_nodes:
            cl.detector.beat(n, cl.clock.sim_seconds)

    def poll(self, sources: Iterable[FaultSource],
             gate: Callable[[set[int]], None] | None = None
             ) -> list[RecoveryAction]:
        """Drain the given pipeline channels outside any call — the
        executor's straggler sweep and the no-collective heartbeat check."""
        self.ensure_active()
        actions = self.cluster.pipeline.drain(self._step, sources=sources,
                                              gate=gate)
        self._record(actions)
        return actions

    def _record(self, actions: Iterable[RecoveryAction]) -> None:
        self._actions.extend(actions)

    def take_actions(self) -> tuple[RecoveryAction, ...]:
        """Every terminal action recorded since the last boundary/take —
        what the step/round reports surface to the application."""
        out = tuple(self._actions)
        self._actions.clear()
        return out

    def _register(self, comm: Comm) -> None:
        self._comms.append(comm)

    def _unregister(self, comm: Comm) -> None:
        if comm in self._comms:
            self._comms.remove(comm)

    def _on_terminal_action(self, action: RecoveryAction) -> None:
        """Pipeline listener: a repair landed — discard every in-flight
        envelope addressed to the verdict (their recvs can never post)."""
        dead = set(action.verdict)
        for comm in self._comms:
            comm.ledger.discard_to(dead, self._step)

    def __repr__(self) -> str:
        state = "finalized" if self._finalized else "active"
        return (f"Session({state}, step={self._step}, "
                f"nodes={self.cluster.topo.size}, "
                f"comms={len(self._comms)})")
