"""Comm — the MPI-shaped communicator handle behind which Legio hides.

This is the paper's PMPI interposition seam made explicit: every call on a
:class:`Comm` runs the same transparent sandwich,

    1. **trap** — the simulated ``MPIX_ERR_PROC_FAILED`` analogue: before
       the schedule runs, the call checks the ground-truth failed set
       against the op's participants (ULFM surfaces the error code on the
       ranks that interacted with the dead process; our centralized sim
       sees it at the call);
    2. **drain** — the observation feeds :class:`~repro.core.pipeline.
       FaultPipeline` and the call drains the collective + heartbeat
       channels: detect → notice → agree → plan → apply, with the
       registered :class:`~repro.core.strategy.RecoveryStrategy` repairing
       the agreed verdict (Bouteiller & Bosilca's *implicit actions*:
       recovery as a side effect of an ordinary call);
    3. **retry** — the op re-runs against a *pinned*, epoch-stamped
       :class:`~repro.core.hierarchy.TopologyView` of the repaired
       structure (paper §IV: check after the op; if confirmed, repair and
       repeat the operation);

so the caller never sees a fault — unless the caller itself depended on the
dead node (its op's root, its point-to-point peer), in which case the call
raises a clean :class:`~repro.mpi.errors.PeerFailedError` *after* the
repair has landed: the paper's discard semantics, never a deadlock.

Point-to-point (``send``/``recv``/``sendrecv``) is new machinery relative
to the collective schedules: a per-comm :class:`~repro.mpi.ledger.
MessageLedger` with fault-aware matching (Rocco & Palermo's non-collective
follow-up) — a recv whose sender died mid-flight resolves to the discard
outcome instead of blocking forever, and messages buffered before the
death are still delivered exactly once.

PMPI-style tool layers keep working: :meth:`Comm.attach` registers an
interposer invoked with ``(op, view)`` on every call, before the schedule
runs — the executor uses it to validate its shard plan against the pinned
view; profilers can count calls without touching the app.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Iterable

import numpy as np

from repro.core.collectives import CollectiveResult, HierarchicalCollectives
from repro.core.hierarchy import make_topology
from repro.core.types import FaultSource, RecoveryAction
from repro.mpi.errors import PeerFailedError, RecvWouldDeadlockError
from repro.mpi.ledger import MessageLedger

if TYPE_CHECKING:
    from repro.mpi.session import Session

# channels every interposed call drains — the collective error it just
# trapped plus the heartbeat suspicions that accrued since the last call
CALL_SOURCES = (FaultSource.COLLECTIVE, FaultSource.HEARTBEAT)

# repair rounds per call before giving up; each round removes the agreed
# verdict from the topology, so two rounds settle any single-drain fault
_MAX_REPAIR_ROUNDS = 8


@dataclass
class InterpositionStats:
    """Per-comm bookkeeping the transparency-overhead benchmark reads.

    The paper's "negligible overhead" claim, made structural: on the
    fault-free path every call performs exactly one pipeline drain
    (``drains == calls``), zero repair rounds, and exactly the stages the
    direct schedule would run (``collective_stages`` matches).
    """

    calls: int = 0               # MPI-shaped ops issued on this comm
    drains: int = 0              # pipeline drains the interposition ran
    repair_rounds: int = 0       # rounds that trapped PROC_FAILED
    collective_stages: int = 0   # schedule stages actually executed
    sim_seconds: float = 0.0     # alpha-beta time charged through this comm

    def record_op(self, res: CollectiveResult) -> None:
        self.collective_stages += len(res.stages)
        self.sim_seconds += res.sim_seconds

    @property
    def drains_per_call(self) -> float:
        return self.drains / self.calls if self.calls else 0.0


class Comm:
    """One communicator handle. The world comm tracks the live topology
    (substitutes splice in transparently); ``comm_split``/``comm_dup``
    derive fixed-group comms that shrink as members die (non-collective
    creation per Rocco & Palermo — the subgroup never regrows)."""

    def __init__(self, session: "Session", group: Iterable[int] | None,
                 name: str = "world"):
        self.session = session
        self.name = name
        self._group = tuple(sorted(group)) if group is not None else None
        self.ledger = MessageLedger()
        self.stats = InterpositionStats()
        self._hooks: list[Callable] = []
        self._freed = False
        # sub-topology cache for fixed-group comms, keyed by world epoch +
        # surviving membership (rebuilt only when a repair changes either)
        self._sub_topo = None
        self._sub_key: tuple | None = None
        session._register(self)

    # -- MPI_Comm_rank / MPI_Comm_size ---------------------------------------

    @property
    def members(self) -> list[int]:
        """Current member node ids, ascending (== rank order). Repairs
        remove the dead; a node that died since the last boundary remains
        a member until a call's interposition repairs it out (exactly
        ULFM's window between death and MPIX_Comm_shrink)."""
        topo_nodes = self.session.cluster.topo.nodes
        if self._group is None:
            return topo_nodes
        alive = set(topo_nodes)
        return [n for n in self._group if n in alive]

    @property
    def size(self) -> int:
        return len(self.members)

    @property
    def ranks(self) -> list[int]:
        return list(range(self.size))

    def rank_of(self, node: int) -> int:
        """The node's rank in this comm (ascending node-id order)."""
        try:
            return self.members.index(node)
        except ValueError:
            raise KeyError(f"node {node} is not a live member of "
                           f"comm {self.name!r}") from None

    def __contains__(self, node: int) -> bool:
        return node in set(self.members)

    # -- PMPI tool layers ------------------------------------------------------

    def attach(self, hook: Callable[[str, object], None],
               *, key: str | None = None) -> None:
        """Register an interposer called with ``(op, pinned_view)`` before
        every schedule runs — the PMPI profiling-layer analogue. A non-None
        ``key`` makes the registration idempotent: re-attaching under the
        same key replaces the previous hook (the world comm is shared per
        cluster, so re-built consumers must not stack duplicates)."""
        if key is not None:
            self.detach(key)
        self._hooks.append((key, hook))

    def detach(self, key: str) -> None:
        """Remove the interposer registered under ``key`` (no-op if absent)."""
        self._hooks = [(k, h) for k, h in self._hooks if k != key]

    def free(self) -> None:
        """MPI_Comm_free: drop the ledger context and stop fault-listener
        delivery to this comm."""
        self._freed = True
        self.session._unregister(self)

    # -- the interposition core ------------------------------------------------

    def _dead_among(self, among: Iterable[int] | None) -> set[int]:
        cl = self.session.cluster
        present = set(cl.topo.nodes)
        scan = self.members if among is None else [n for n in among]
        return {n for n in scan if n in cl.failed and n in present}

    def _resolve(self, op: str, *, root: int | None = None,
                 peers: tuple[int, ...] = (),
                 among: Iterable[int] | None = None,
                 gate: Callable[[set[int]], None] | None = None,
                 ) -> list[RecoveryAction]:
        """Trap → drain → repair until the op's participants are clean.

        Returns every terminal action the drains produced (also recorded on
        the session for the step report). Raises :class:`PeerFailedError`
        if the caller's ``root`` or ``peers`` land in an agreed verdict —
        after the repair has been applied, so the next call is safe.
        """
        cl = self.session.cluster
        out: list[RecoveryAction] = []
        for _ in range(_MAX_REPAIR_ROUNDS):
            dead = self._dead_among(among)
            if dead:
                self.stats.repair_rounds += 1
                cl.pipeline.observe_collective(op, self.members, dead,
                                               root=root)
            actions = cl.pipeline.drain(self.session.step,
                                        sources=CALL_SOURCES, gate=gate)
            self.stats.drains += 1
            self.session._record(actions)
            out.extend(actions)
            verdict = {n for a in actions for n in a.verdict}
            failed_peer = ({root} if root is not None else set()) | set(peers)
            failed_peer &= verdict
            if failed_peer:
                raise PeerFailedError(
                    f"{op}: peer(s) {sorted(failed_peer)} failed and were "
                    f"repaired out of comm {self.name!r} — result discarded "
                    f"for this caller (paper §IV discard semantics)",
                    op=op, peers=tuple(sorted(failed_peer)))
            if not dead or not (dead & verdict):
                # clean — or the fault went unnoticed this call (the BNP:
                # no survivor observed it); the op proceeds and the
                # heartbeat channel confirms the silent death later
                return out
        raise RuntimeError(
            f"{op}: repair did not converge after {_MAX_REPAIR_ROUNDS} "
            f"rounds on comm {self.name!r}")

    def _busy(self) -> frozenset[int]:
        """Survivors occupied by an in-flight background repair window
        (empty outside overlap mode) — excluded from schedules and
        contribution sets until their window reconciles."""
        return frozenset(self.session.cluster.repairing_participants())

    def _schedule_topo(self, view, busy: frozenset[int] = frozenset()):
        """Structure the schedules run over: the pinned world view (its
        busy-restricted sub-view during an overlap window), or the derived
        sub-topology for a fixed-group comm.

        The fixed-group cache is keyed by epoch + the *effective* live
        membership, busy exclusions included. Keying on raw membership was
        the latent ordering hazard background repair exposes: a window
        opening or closing changes the schedule without bumping the epoch,
        so a (epoch, members) key would happily serve a stale sub-topology
        that still contains mid-repair participants — a half-applied group.
        """
        if self._group is None:
            return view.restrict(busy) if busy else view
        live = [n for n in self._group
                if n in view.node_set and n not in busy]
        key = (view.epoch, tuple(live))
        if self._sub_key != key:
            self._sub_topo = make_topology(
                live, self.session.cluster.policy)
            self._sub_key = key
        return self._sub_topo

    def _run(self, op: str, fn: Callable[[HierarchicalCollectives],
                                         CollectiveResult]
             ) -> CollectiveResult:
        """Run one schedule against a pinned view of the (repaired)
        structure and charge its alpha-beta time to the cluster clock.

        During a background repair window the schedule runs over the
        survivors *outside* the window (healthy subtrees progress on their
        pinned epoch — the revoke half of revoke-then-repair). If every
        member is busy there is no healthy subtree to make progress:
        the call synchronizes (force-finishing the windows, charging the
        residual) and runs full-membership."""
        cl = self.session.cluster
        busy = self._busy()
        if busy and not any(n not in busy for n in self.members):
            self.session.sync()
            busy = frozenset()
        with cl.topo.pinned() as view:
            for _key, hook in self._hooks:
                hook(op, view)
            res = fn(cl.collectives(self._schedule_topo(view, busy)))
        cl.clock.charge(res.sim_seconds)
        self.stats.record_op(res)
        return res

    def _call(self) -> None:
        self.session.ensure_active()
        if self._freed:
            # lifecycle misuse, not a fault: PeerFailedError's contract is
            # "catch and continue", which would turn a use-after-free into
            # a silent infinite skip
            from repro.mpi.errors import MPISessionError
            raise MPISessionError(
                f"comm {self.name!r} has been freed — no call may follow "
                f"MPI_Comm_free")
        self.stats.calls += 1

    def _effective_root(self, root: int) -> int:
        """The op's root if it survives, else the lowest surviving rank —
        the paper's lowest-rank master rule applied to op roots (the
        requested root's death was already surfaced as PeerFailedError in
        the call that repaired it; later calls re-home silently)."""
        members = self.members
        if not members:
            raise RuntimeError(f"comm {self.name!r} has no surviving member")
        return root if root in set(members) else members[0]

    # -- collectives (paper §V op classes, interposed) -------------------------

    def bcast(self, payload: "np.ndarray | dict[int, np.ndarray]", root: int,
              *, gate: Callable | None = None) -> CollectiveResult:
        """One-to-all. Root failure surfaces as PeerFailedError on the call
        that repairs it; every other fault is invisible. ``payload`` is the
        root's buffer — or, driver-side, a per-node buffer dict from which
        the (possibly re-homed) root's entry is taken after repair."""
        self._call()
        self._resolve("bcast", root=root, gate=gate)
        rt = self._effective_root(root)
        self._sync_if_busy(rt)
        if isinstance(payload, dict):
            payload = payload.get(rt, np.zeros(1))
        return self._run("bcast", lambda coll: coll.bcast(rt, payload))

    def reduce(self, contributions: dict[int, np.ndarray], root: int,
               op: Callable = np.add,
               *, gate: Callable | None = None) -> CollectiveResult:
        """All-to-one. Dead contributors are repaired out and simply do not
        contribute (discard-and-continue — the Monte-Carlo argument)."""
        self._call()
        self._resolve("reduce", root=root, gate=gate)
        rt = self._effective_root(root)
        self._sync_if_busy(rt)
        self._sync_if_no_healthy_contributor(contributions)
        return self._run("reduce", lambda coll: coll.reduce(
            rt, self._filter(contributions), op))

    def allreduce(self, contributions: dict[int, np.ndarray],
                  op: Callable = np.add,
                  *, gate: Callable | None = None) -> CollectiveResult:
        """All-to-all (reduce + bcast, §V). No root — never PeerFailedError."""
        self._call()
        self._resolve("allreduce", gate=gate)
        self._sync_if_no_healthy_contributor(contributions)
        return self._run("allreduce", lambda coll: coll.allreduce(
            self._filter(contributions), op))

    def barrier(self) -> CollectiveResult:
        """All-hands synchronization — the one collective that *cannot*
        exclude a repairing scope: in-flight background repair windows are
        force-finished first (their residual charged), exactly the
        "overlap is unsafe" escape hatch docs/recovery-modes.md names."""
        self._call()
        self._resolve("barrier")
        self.session.sync()
        return self._run("barrier", lambda coll: coll.barrier())

    def gather(self, contributions: dict[int, object] | None = None,
               *, among: Iterable[int] | None = None) -> dict[int, object]:
        """All-to-one result gather over arbitrary payloads (the serving
        result collection). Interposes faults among the op's participants
        (``among`` — e.g. the nodes actually dispatched this round) and
        returns the surviving contributions; lost participants' repairs
        have already run when this returns."""
        self._call()
        self._resolve("gather", among=among)
        alive = set(self.session.cluster.topo.nodes)
        out = {n: v for n, v in (contributions or {}).items() if n in alive}
        vals = list(out.values())
        if (len(vals) > 1 and all(isinstance(v, np.ndarray) for v in vals)
                and len({(v.shape, str(v.dtype)) for v in vals}) == 1):
            # uniform ndarray payloads ride the data plane (all_gather on
            # the jax backend; identity on sim) — mixed/object payloads
            # stay host-side
            gathered = self.session.cluster.dataplane.gather_arrays(vals)
            out = dict(zip(out.keys(), gathered))
        return out

    def _sync_if_busy(self, root: int) -> None:
        """A rooted op whose root sits inside a repairing scope cannot
        proceed degraded (the result must materialize *at the root*):
        force-finish the windows — the root's repair is waited out as
        residual, the documented overlap-unsafe case."""
        if root in self._busy():
            self.session.sync()

    def _sync_if_no_healthy_contributor(
            self, contributions: dict[int, np.ndarray]) -> None:
        """If the drain inside this very call opened a window that
        swallowed *every* surviving contributor (the torn scope was the
        whole contributing set), there is no healthy subtree to carry the
        op: synchronize — the same overlap-unsafe escape hatch as the
        all-busy-members guard — before the schedule topology is built,
        so the op then runs full-membership."""
        busy = self._busy()
        if not busy or not contributions:
            return
        alive = set(self.members) - busy
        if not any(n in alive for n in contributions):
            self.session.sync()

    def _filter(self, contributions: dict[int, np.ndarray]
                ) -> dict[int, np.ndarray]:
        alive = set(self.members) - self._busy()
        return {n: np.asarray(v) for n, v in contributions.items()
                if n in alive}

    # -- point-to-point (fault-aware non-collective layer) ---------------------

    def _check_endpoint(self, node: int, role: str) -> None:
        """A caller endpoint must be a live member — a dead *caller* is a
        driver bug (the simulation never runs code on a dead node). The
        membership list alone is not enough: a node dead since the last
        boundary stays in the topology until a call repairs it."""
        if node not in set(self.members) or node in self.session.cluster.failed:
            raise ValueError(
                f"{role} {node} is not a live member of comm {self.name!r}")

    def _known(self, node: int) -> bool:
        cl = self.session.cluster
        in_group = self._group is None or node in self._group
        return in_group and (node in cl.topo.home or node in cl.failed)

    def _require_peer_alive(self, op: str, caller: int, peer: int) -> None:
        """Trap the p2p PROC_FAILED analogue: if the peer is dead, drain
        the pipeline (repairing it out) and surface the discard outcome."""
        cl = self.session.cluster
        if not self._known(peer):
            raise ValueError(
                f"{op}: peer {peer} is not a member of comm {self.name!r}")
        if peer not in cl.failed:
            return
        self._resolve(op, among=(caller, peer))
        raise PeerFailedError(
            f"{op}: peer {peer} failed — in-flight traffic discarded, "
            f"communicator already repaired", op=op, peers=(peer,),
            discarded=True)

    def send(self, src: int, dst: int, payload: object, tag: int = 0) -> None:
        """Post a message ``src -> dst``. Send to a dead peer raises
        PeerFailedError (the sender *is* the peer's dependent); otherwise
        the payload enters the ledger's network buffer — delivery survives
        even the sender's later death (eager buffering)."""
        self._call()
        self._check_endpoint(src, "sender")
        self._require_peer_alive("p2p", src, dst)
        self.ledger.post(src, dst, tag, payload, self.session.step)
        self._charge_p2p(src, dst, payload)

    def recv(self, dst: int, src: int, tag: int = 0) -> object:
        """Match the oldest posted message ``src -> dst``. A message posted
        before the sender died is still delivered; a recv with nothing
        posted and a dead sender resolves to the discard outcome
        (PeerFailedError) instead of deadlocking — the non-collective
        reparation path."""
        self._call()
        self._check_endpoint(dst, "receiver")
        env = self.ledger.match(dst, src, tag)
        if env is not None:
            return self.ledger.deliver(env, self.session.step)
        self._require_peer_alive("p2p", dst, src)
        raise RecvWouldDeadlockError(
            f"recv: no message from live node {src} to {dst} (tag {tag}) — "
            f"in the step-driven simulation the send must happen first")

    def sendrecv(self, node: int, dst: int, payload: object, src: int,
                 tag: int = 0) -> object:
        """MPI_Sendrecv: post ``node -> dst``, then receive ``src -> node``.
        Either dead peer surfaces as PeerFailedError after its repair."""
        self.send(node, dst, payload, tag)
        return self.recv(node, src, tag)

    def probe(self, dst: int, src: int, tag: int = 0) -> bool:
        """MPI_Iprobe: is a matching message waiting? Never faults."""
        self.session.ensure_active()
        return self.ledger.match(dst, src, tag) is not None

    def _charge_p2p(self, src: int, dst: int, payload: object) -> None:
        cl = self.session.cluster
        arr = payload if isinstance(payload, np.ndarray) else None
        nbytes = arr.nbytes if arr is not None else 0
        try:
            cross = cl.topo.legion_of(src).index != cl.topo.legion_of(dst).index
        except KeyError:
            cross = True
        t = cl.link.tree_time(2, nbytes, cross=cross)
        cl.clock.charge(t)
        self.stats.sim_seconds += t

    # -- comm creators (paper §V: run on the ENTIRE communicator) --------------

    def comm_split(self, colors: dict[int, int]) -> dict[int, "Comm"]:
        """MPI_Comm_split, driver-side: ``colors`` maps member -> color;
        returns one fixed-group comm per color.

        Built from **surviving groups** (Rocco & Palermo's fault-aware
        non-collective creation): the drain inside the call repairs the
        structure eagerly, so the groups are read from post-repair
        membership — there is no whole-comm *blocking* repair-first
        precondition. Under background repair the drain merely opens a
        window (no clock charge) and the creator schedule runs over the
        survivors outside it; a busy-but-alive participant is still a
        member of the new comm (membership is structural, not a schedule
        property — it rejoins schedules when its window reconciles), and
        the repaired-out dead never appear. A split mid-window therefore
        observes the fully-applied post-repair group, never a torn one
        (the regression test in tests/test_mpi.py diffs this against the
        blocking path as oracle)."""
        self._call()
        self._resolve("comm_creator")
        self._run("comm_creator", lambda coll: coll.comm_create())
        members = set(self.members)
        groups: dict[int, list[int]] = {}
        for node, color in colors.items():
            if node in members and color >= 0:      # MPI_UNDEFINED analogue
                groups.setdefault(color, []).append(node)
        return {
            color: Comm(self.session, nodes,
                        name=f"{self.name}/split{color}")
            for color, nodes in sorted(groups.items())
        }

    def comm_dup(self) -> "Comm":
        """MPI_Comm_dup: same group, fresh message-matching context. Like
        :meth:`comm_split`, builds from the surviving post-repair group —
        non-blocking under an in-flight background repair window."""
        self._call()
        self._resolve("comm_creator")
        self._run("comm_creator", lambda coll: coll.comm_create())
        group = self.members if self._group is not None else None
        return Comm(self.session, group, name=f"{self.name}/dup")

    def __repr__(self) -> str:
        return (f"Comm({self.name!r}, size={self.size}, "
                f"calls={self.stats.calls})")
