"""repro.mpi — the transparent MPI session facade (PMPI-style interposition).

The paper's headline property is *transparency*: Legio lives behind the MPI
calls, so an embarrassingly parallel application needs zero integration
effort. This package is that seam for the simulated runtime — the only API
applications (and the rest of this repo: trainer, executor, serve engine,
launch drivers, examples) see:

  * :class:`Session` — MPI_Init/Finalize lifecycle over a VirtualCluster,
    step-boundary primitives (spare delivery, fault injection, clock), and
    the facade-level fault listener;
  * :class:`Comm` — the MPI-shaped surface (``bcast`` / ``reduce`` /
    ``allreduce`` / ``barrier`` / ``gather`` / ``send`` / ``recv`` /
    ``sendrecv`` / ``comm_split`` / ``comm_dup`` / rank / size) where every
    call traps the simulated ``MPIX_ERR_PROC_FAILED``, drains the
    FaultPipeline, applies the configured RecoveryStrategy, and retries on
    the repaired communicator — the caller sees a fault only when it *was*
    the dead node's dependent (root/peer), as a clean
    :class:`PeerFailedError` with discard semantics;
  * :class:`MessageLedger` — fault-aware point-to-point matching (Rocco &
    Palermo's non-collective follow-up): no message lost, none delivered
    twice, and no recv ever deadlocks on a dead peer.

See docs/api.md for the paper-style call-mapping table.
"""
from repro.mpi.comm import CALL_SOURCES, Comm, InterpositionStats
from repro.mpi.errors import (
    MPISessionError,
    PeerFailedError,
    RecvWouldDeadlockError,
)
from repro.mpi.ledger import Envelope, MessageLedger, MsgState
from repro.mpi.session import BoundaryReport, Session

__all__ = [
    "BoundaryReport", "CALL_SOURCES", "Comm", "Envelope",
    "InterpositionStats", "MPISessionError", "MessageLedger", "MsgState",
    "PeerFailedError", "RecvWouldDeadlockError", "Session",
]
