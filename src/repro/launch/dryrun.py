import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 pods × 256 chips, the
full-size model is lowered from ShapeDtypeStructs (no allocation), and the
compiled artifact yields the roofline terms (memory_analysis / cost_analysis
/ parsed collective bytes).

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import SHAPES, TrainConfig, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config, get_shape
from repro.dist.compat import use_mesh
from repro.launch import hlo_stats
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.steps import cell_shardings, input_specs, step_fn_for


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overrides: dict | None = None, verbose: bool = True) -> dict:
    """Lower+compile one cell; returns the JSON-able artifact record."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = get_shape(shape_name)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    specs = input_specs(cfg, shape)
    in_sh, out_sh = cell_shardings(cfg, shape, mesh, specs)
    fn = step_fn_for(cfg, shape, TrainConfig())

    donate = (0, 1) if shape.kind == "train" else \
             (1,) if shape.kind == "decode" else ()

    # jit+lower positionally: pjit rejects kwargs when in_shardings is given.
    args = tuple(specs.values())
    in_sh_tuple = tuple(in_sh[k] for k in specs)

    t0 = time.perf_counter()
    with use_mesh(mesh):
        jitted = jax.jit(fn, in_shardings=in_sh_tuple, out_shardings=out_sh,
                         donate_argnums=donate or None)
        lowered = jitted.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware re-analysis: XLA's cost_analysis counts while bodies once
    cost = hlo_stats.analyze(hlo, n_dev)
    coll = cost.coll

    flops = cost.flops
    bytes_accessed = cost.bytes
    terms = hlo_stats.roofline_terms(
        flops, bytes_accessed, coll.total_wire_bytes)
    mflops = hlo_stats.model_flops(cfg, shape)

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": describe(mesh),
        "multi_pod": multi_pod,
        "n_devices": n_dev,
        "step_kind": shape.kind,
        "skipped": False,
        "overrides": overrides or {},
        "lower_s": round(t1 - t0, 3),
        "compile_s": round(t2 - t1, 3),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes),
        },
        "cost_analysis": {
            "flops_per_device": flops,
            "bytes_accessed_per_device": bytes_accessed,
            "xla_flops_unscaled": float(xla_cost.get("flops", 0.0)),
            "xla_bytes_unscaled": float(xla_cost.get("bytes accessed", 0.0)),
        },
        "collectives": coll.to_json(),
        "model_flops_global": mflops,
        "model_flops_per_device": mflops / n_dev,
        "useful_flops_ratio": (mflops / n_dev) / flops if flops else 0.0,
        "roofline": terms,
    }
    if verbose:
        ma = record["memory_analysis"]
        print(f"  lower {record['lower_s']:.1f}s compile {record['compile_s']:.1f}s | "
              f"args {ma['argument_bytes']/2**30:.2f} GiB temp {ma['temp_bytes']/2**30:.2f} GiB "
              f"peak {ma['peak_bytes_per_device']/2**30:.2f} GiB/dev")
        print(f"  flops/dev {flops:.3e}  bytes/dev {bytes_accessed:.3e}  "
              f"wire/dev {coll.total_wire_bytes:.3e}  "
              f"counts {coll.counts}")
        print(f"  roofline: compute {terms['compute_s']*1e3:.2f} ms | "
              f"memory {terms['memory_s']*1e3:.2f} ms | "
              f"collective {terms['collective_s']*1e3:.2f} ms  "
              f"-> {terms['dominant']}-bound, "
              f"useful-FLOP ratio {record['useful_flops_ratio']:.2f}")
    return record


def cell_list(args) -> list[tuple[str, str]]:
    if args.all:
        cells = []
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
        return cells
    if not args.arch or not args.shape:
        print("need --arch and --shape (or --all)", file=sys.stderr)
        sys.exit(2)
    return [(args.arch, args.shape)]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true", help="every (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true",
                    help="(2,16,16) pod/data/model mesh instead of (16,16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun",
                    help="artifact directory (JSON per cell)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (repeatable), e.g. act_shard=batch_seq")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    meshes = [True, False] if args.both_meshes else [args.multi_pod]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    failures, n_ok, n_skip = [], 0, 0
    for arch, shape_name in cell_list(args):
        for mp in meshes:
            mesh_tag = "pod2" if mp else "pod1"
            name = f"{arch}_{shape_name}_{mesh_tag}"
            if args.tag:
                name += f"_{args.tag}"
            print(f"[dryrun] {name}")
            try:
                rec = run_cell(arch, shape_name, multi_pod=mp,
                               overrides=overrides or None)
            except Exception:
                traceback.print_exc()
                failures.append(name)
                continue
            (outdir / f"{name}.json").write_text(json.dumps(rec, indent=1))
            if rec.get("skipped"):
                n_skip += 1
                print(f"  SKIP: {rec['reason']}")
            else:
                n_ok += 1

    print(f"\n[dryrun] ok={n_ok} skipped={n_skip} failed={len(failures)}")
    for f in failures:
        print(f"  FAILED: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
