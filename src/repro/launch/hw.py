"""Target-hardware constants (TPU v5e) for roofline analysis.

The container is CPU-only; these numbers parameterize the roofline terms
derived from compiled HLO (see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float   # FLOP/s per chip
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link
    hbm_bytes: float         # HBM capacity per chip
    vmem_bytes: float


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16e9,
    vmem_bytes=128 * 2 ** 20,
)

DEFAULT_CHIP = TPU_V5E
