"""Step functions + abstract input specs for the dry-run and launchers.

Every (arch × shape) cell lowers exactly one of three step kinds:

  train    -> ``train_step(params, opt, batch)``   (fwd + bwd + AdamW)
  prefill  -> ``prefill_step(params, batch)``      (forward + cache build)
  decode   -> ``serve_step(params, cache, tokens)`` (one token, KV cache of
              seq_len — ``decode_*`` / ``long_*`` lower THIS, not train_step)

``input_specs`` returns ShapeDtypeStruct stand-ins for every input (params
and optimizer state included — the dry-run never allocates), keyed by the
step function's keyword names, so ``jit(step).lower(**input_specs(...))``
works directly.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec, TrainConfig
from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    param_specs,
)
from repro.models import api
from repro.optim import (
    adamw_init,
    adamw_update,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Abstract state (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_opt(cfg: ModelConfig, params: PyTree | None = None) -> PyTree:
    params = params if params is not None else abstract_params(cfg)
    return jax.eval_shape(adamw_init, params)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    # batch/max_len are shape-defining -> must stay static under eval_shape
    return jax.eval_shape(lambda: api.init_cache(cfg, batch, max_len))


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model inputs for a train/prefill step (tokens/labels/embeds)."""
    B, S = shape.global_batch, shape.seq_len
    batch: dict = {}
    if cfg.is_encoder_decoder:
        # stub audio frontend: precomputed frame embeddings
        batch["embeds"] = _sds((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = _sds((B, S), jnp.int32)
    elif cfg.frontend == "patch":
        # stub patch frontend: precomputed early-fusion embeddings
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    params = abstract_params(cfg)
    if shape.kind == "train":
        return {
            "params": params,
            "opt": abstract_opt(cfg, params),
            "batch": abstract_batch(cfg, shape),
        }
    if shape.kind == "prefill":
        return {"params": params, "batch": abstract_batch(cfg, shape)}
    # decode: one new token against a seq_len-deep cache
    return {
        "params": params,
        "cache": abstract_cache(cfg, shape.global_batch, shape.seq_len),
        "tokens": _sds((shape.global_batch, 1), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Step functions (pure; jitted by the caller with explicit shardings)
# ---------------------------------------------------------------------------

def train_step_fn(cfg: ModelConfig, tc: TrainConfig | None = None) -> Callable:
    tc = tc or TrainConfig()
    lr_fn = cosine_schedule(tc)

    def train_step(params, opt, batch):
        def loss_fn(p):
            return api.train_loss(cfg, p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        updates, opt = adamw_update(grads, opt, params, tc, lr_fn(opt.step))
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt, metrics

    return train_step


def prefill_step_fn(cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        kw = {}
        if "embeds" in _keys(cfg):
            kw["embeds"] = batch["embeds"]
        tokens = batch.get("tokens")
        if tokens is None:
            # patch-frontend prefill: positions come from embeds
            B, S = batch["embeds"].shape[0], batch["embeds"].shape[1]
            tokens = jnp.zeros((B, S), jnp.int32)
        logits, cache = api.prefill(cfg, params, tokens, max_len, **kw)
        return logits, cache

    return prefill_step


def _keys(cfg: ModelConfig) -> tuple[str, ...]:
    if cfg.is_encoder_decoder or cfg.frontend == "patch":
        return ("embeds",)
    return ()


def serve_step_fn(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens):
        return api.decode_step(cfg, params, cache, tokens)

    return serve_step


def step_fn_for(cfg: ModelConfig, shape: ShapeSpec,
                tc: TrainConfig | None = None) -> Callable:
    if shape.kind == "train":
        return train_step_fn(cfg, tc)
    if shape.kind == "prefill":
        return prefill_step_fn(cfg, shape.seq_len)
    return serve_step_fn(cfg)


# ---------------------------------------------------------------------------
# Shardings for jit(in_shardings=..., out_shardings=...)
# ---------------------------------------------------------------------------

def _named(mesh, spec_tree):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def cell_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh,
                   specs: dict) -> tuple[dict, Any]:
    """(in_shardings dict keyed like input_specs, out_shardings) for a cell."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    pspecs = param_specs(cfg, specs["params"], mesh)
    p_shard = _named(mesh, pspecs)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        o = specs["opt"]
        opt_shard = type(o)(
            step=repl,
            mu=_named(mesh, param_specs(cfg, o.mu, mesh)),
            nu=_named(mesh, param_specs(cfg, o.nu, mesh)),
        )
        b_shard = _named(
            mesh, batch_specs(cfg, mesh, specs["batch"], shape.global_batch))
        in_sh = {"params": p_shard, "opt": opt_shard, "batch": b_shard}
        # outputs: (params, opt, metrics) — ``repl`` is a pytree prefix that
        # broadcasts over every (scalar) metric leaf.
        out_sh = (p_shard, opt_shard, repl)
        return in_sh, out_sh

    if shape.kind == "prefill":
        b_shard = _named(
            mesh, batch_specs(cfg, mesh, specs["batch"], shape.global_batch))
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        c_shard = _named(
            mesh, cache_specs(cfg, mesh, cache, shape.global_batch))
        logits_sh = _logits_sharding(cfg, mesh, shape)
        return {"params": p_shard, "batch": b_shard}, (logits_sh, c_shard)

    # decode
    c_shard = _named(
        mesh, cache_specs(cfg, mesh, specs["cache"], shape.global_batch))
    t_shard = _named(
        mesh, batch_specs(cfg, mesh, {"tokens": specs["tokens"]},
                          shape.global_batch))["tokens"]
    logits_sh = _logits_sharding(cfg, mesh, shape)
    return ({"params": p_shard, "cache": c_shard, "tokens": t_shard},
            (logits_sh, c_shard))


def _logits_sharding(cfg: ModelConfig, mesh, shape: ShapeSpec):
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import _batch_dim_axes
    b = _batch_dim_axes(mesh, shape.global_batch)
    return NamedSharding(mesh, P(b, None, None))
