"""Resilient training driver (end-to-end example entry point).

Trains a model under the Legio runtime on a virtual cluster: injected node
failures are detected, agreed on, repaired (flat or hierarchical shrink), and
training continues with the survivors — no global restart.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --steps 50 \\
      --nodes 16 --fail 10:3 --fail 20:0 --legion-size 4

Full-size configs are exercised by the dry-run; this driver runs the smoke
config by default (CPU container) — pass --full on real hardware.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core import (
    RECOVERY_MODES,
    FaultInjector,
    LegionCheckpointer,
    LegioPolicy,
    ResilientTrainer,
    VirtualCluster,
)


def parse_failures(specs: list[str]) -> FaultInjector:
    pairs = []
    for s in specs:
        step, node = s.split(":")
        pairs.append((int(step), int(node)))
    return FaultInjector.at(pairs)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs real accelerators)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--per-shard-batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fail", action="append", default=[],
                    help="step:node fault injection (repeatable)")
    ap.add_argument("--legion-size", type=int, default=0,
                    help="k; 0 = optimal from Eq. 3")
    ap.add_argument("--flat", action="store_true",
                    help="flat shrink instead of hierarchical")
    ap.add_argument("--batch-policy", choices=["drop", "rebalance"],
                    default="drop")
    ap.add_argument("--root-policy", choices=["ignore", "stop"],
                    default="ignore")
    ap.add_argument("--spares", type=int, default=0,
                    help="standby nodes for elastic regrow")
    ap.add_argument("--recovery", choices=RECOVERY_MODES, default="shrink",
                    help="recovery mode; 'adaptive' scores shrink/substitute/"
                         "nonblocking per fault (CostModelStrategy)")
    ap.add_argument("--spare-fraction", type=float, default=0.0,
                    help="provision ceil(f*n) warm spares for substitution")
    ap.add_argument("--no-peer-replication", action="store_true",
                    help="disable POV-ring replica checkpoints (store-only "
                         "restores)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--data-plane", choices=["sim", "jax", "auto"],
                    default="sim",
                    help="what moves collective payloads: the numpy "
                         "simulator, real jax device collectives, or auto "
                         "(jax when >1 device is visible)")
    ap.add_argument("--json", action="store_true", help="JSON report to stdout")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    tc = TrainConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        legion_size=args.legion_size,
        batch_policy=args.batch_policy,
        root_failure_policy=args.root_policy,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
    )
    policy = LegioPolicy(
        legion_size=args.legion_size,
        hierarchical_threshold=10 ** 9 if args.flat else 12,
        batch_policy=args.batch_policy,
        root_failure_policy=args.root_policy,
        spare_nodes=args.spares,
        recovery_mode=args.recovery,
        spare_fraction=args.spare_fraction,
        peer_replication=not args.no_peer_replication,
        data_plane=args.data_plane,
    )
    cluster = VirtualCluster(
        args.nodes, policy=policy, injector=parse_failures(args.fail))
    ckpt = LegionCheckpointer(args.checkpoint_dir) if args.checkpoint_dir else None
    trainer = ResilientTrainer(
        cfg, tc, cluster, per_shard_batch=args.per_shard_batch,
        seq_len=args.seq_len, checkpointer=ckpt)

    print(f"[train] arch={cfg.name} nodes={args.nodes} "
          f"legions(k)={cluster.topo.k} steps={args.steps}")
    for _ in range(args.steps):
        r = trainer.run_step()
        line = (f"  step {r.step:4d} loss {r.loss:.4f} "
                f"shards {r.active_shards:3d} "
                f"{'REPAIR ' + r.repair.summary() if r.repair else ''}")
        print(line)

    losses = [r.loss for r in trainer.history]
    report = {
        "arch": cfg.name,
        "steps": args.steps,
        "first_loss": losses[0],
        "last_loss": losses[-1],
        "loss_decreased": losses[-1] < losses[0],
        "repairs": len(cluster.repairs),
        "survivors": len(cluster.live_nodes),
        "sim_seconds": cluster.clock.sim_seconds,
    }
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"{report['repairs']} repairs, {report['survivors']} survivors")
    if args.json:
        print(json.dumps(report))
    return 0


if __name__ == "__main__":
    sys.exit(main())
