"""Resilient batched-serving driver — model inference over ``repro.serve``.

The paper's target class — embarrassingly parallel work with no inter-worker
interaction until the final reduce — is exactly batched inference: every node
owns a slice of the request stream (prefill + decode), and the only
collective is the result gather. The serving subsystem (``repro.serve``)
owns routing, micro-batching, and fault recovery; this module supplies the
model-backed work function (prefill + greedy decode) and the CLI.

A fault mid-batch no longer loses the in-flight requests and no longer
blocks serving: the ServeEngine re-enqueues them through the FaultPipeline
listener (at-least-once, deduped to exactly-once) while healthy legions
keep dispatching — see docs/serving.md.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \\
      --requests 64 --nodes 8 --decode-tokens 8 --fail 2:3 \\
      --recovery nonblocking
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core import FaultInjector, LegioPolicy
from repro.models import api
from repro.mpi import Session
from repro.serve import RECOVERY_PRESETS, Request, ServeEngine, recovery_preset


class ResilientServer:
    """Model-backed serving: prefill + greedy decode per micro-batch, fault
    recovery delegated to :class:`repro.serve.ServeEngine` over the
    ``repro.mpi`` session facade — this driver contains zero fault code."""

    def __init__(self, cfg, cluster: "Session", *, prompt_len: int = 32,
                 decode_tokens: int = 8, batch_per_node: int = 4,
                 requeue: bool = True, window: int | None = None,
                 continuous: bool = True):
        self.cfg = cfg
        self.prompt_len = prompt_len
        self.decode_tokens = decode_tokens
        key = jax.random.PRNGKey(0)
        self.params = api.init_params(cfg, key)
        self._prefill = jax.jit(
            lambda p, t: api.prefill(cfg, p, t, prompt_len + decode_tokens))
        self._decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
        # tail batches change shape and recompile the jitted prefill/decode;
        # that wall-clock noise must not soft-fail healthy nodes as stragglers
        self.engine = ServeEngine(cluster, self._work_fn,
                                  microbatch=batch_per_node, requeue=requeue,
                                  window=window, continuous=continuous,
                                  observe_stragglers=False)

    @property
    def completed(self) -> dict[int, np.ndarray]:
        return self.engine.completed

    def _work_fn(self, node: int, batch: list[Request],
                 step: int) -> dict[int, np.ndarray]:
        rids = [r.rid for r in batch]
        result = self._work_batch(rids)
        return {rid: row for rid, row in zip(rids, result)}

    def _work_batch(self, request_ids: list[int]) -> np.ndarray:
        """Prefill + greedy-decode a batch of requests; returns token matrix."""
        B = len(request_ids)
        key = jax.random.PRNGKey(1234)
        tokens = jax.random.randint(
            key, (B, self.prompt_len), 0, self.cfg.vocab_size, jnp.int32)
        # deterministic per-request prompts (request id folds into row 0)
        tokens = tokens.at[:, 0].set(
            jnp.asarray(request_ids, jnp.int32) % self.cfg.vocab_size)
        logits, cache = self._prefill(self.params, tokens)
        out = []
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(self.decode_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return np.asarray(jnp.concatenate(out, axis=1))

    def run(self, n_requests: int) -> dict:
        self.engine.submit(n_requests)
        t0 = time.perf_counter()
        rep = self.engine.serve()
        wall = time.perf_counter() - t0
        m = rep.metrics_summary
        return {
            "completed": rep.completed,
            "abandoned": m["abandoned"],
            "shed": m["shed"],
            "unserved": self.engine.pending,
            "rounds": rep.rounds,
            "requeues": m["requeues"],
            "migrations": m["migrations"],
            "p50_latency_rounds": m["p50_latency_rounds"],
            "p99_latency_rounds": m["p99_latency_rounds"],
            "p99_latency_sim": m["p99_latency_sim"],
            "slo_attainment": m["slo_attainment"],
            "starved_rounds": m["starved_rounds"],
            "wall_seconds": wall,
            "survivors": rep.survivors,
            "repairs": rep.repairs,
            "throughput_rps": rep.completed / wall if wall > 0 else 0.0,
        }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--fail", action="append", default=[],
                    help="round:node fault injection (repeatable)")
    ap.add_argument("--recovery", choices=sorted(RECOVERY_PRESETS),
                    default="shrink", help="recovery strategy for faults")
    ap.add_argument("--no-requeue", action="store_true",
                    help="DROP failed nodes' requests instead of re-queueing")
    ap.add_argument("--window", type=int, default=None,
                    help="in-flight micro-batches per node (continuous "
                         "batching window; default policy.serve_window)")
    ap.add_argument("--lockstep", action="store_true",
                    help="use the lock-step barrier baseline instead of "
                         "continuous batching")
    ap.add_argument("--slo", type=float, default=0.0,
                    help="per-request SLO deadline in simulated seconds "
                         "(0 = no deadlines)")
    ap.add_argument("--admission", choices=("none", "shed", "park"),
                    default="none",
                    help="SLO-feasibility admission control at submit")
    ap.add_argument("--data-plane", choices=["sim", "jax", "auto"],
                    default="sim",
                    help="what moves collective payloads: the numpy "
                         "simulator, real jax device collectives, or auto "
                         "(jax when >1 device is visible)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    pairs = []
    for s in args.fail:
        step, node = s.split(":")
        pairs.append((int(step), int(node)))
    # batch size flows through the ResilientServer constructor (the engine's
    # explicit microbatch override); the policy only carries recovery setup
    policy = LegioPolicy(**recovery_preset(args.recovery),
                         serve_slo_seconds=args.slo,
                         serve_admission=args.admission,
                         data_plane=args.data_plane)
    session = Session(
        args.nodes, policy=policy, injector=FaultInjector.at(pairs))
    server = ResilientServer(
        cfg, session, prompt_len=args.prompt_len,
        decode_tokens=args.decode_tokens, batch_per_node=args.batch_per_node,
        requeue=not args.no_requeue, window=args.window,
        continuous=not args.lockstep)
    print(f"[serve] arch={cfg.name} nodes={args.nodes} "
          f"requests={args.requests} recovery={args.recovery} "
          f"mode={'lockstep' if args.lockstep else 'continuous'}")
    rep = server.run(args.requests)
    for k, v in rep.items():
        print(f"  {k}: {v if not isinstance(v, float) else round(v, 3)}")
    ok = rep["completed"] + rep["abandoned"] + rep["shed"] == args.requests
    print(f"[serve] {'OK' if ok else 'INCOMPLETE'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
