"""Resilient batched-serving driver.

The paper's target class — embarrassingly parallel work with no inter-worker
interaction until the final reduce — is exactly batched inference: every node
owns a slice of the request stream (prefill + decode), and the only
collective is the throughput/result aggregation. Failed nodes are discarded
and their in-flight requests are re-queued to survivors (the serving analogue
of batch REBALANCE; DROP simply abandons them, the paper's semantics).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \\
      --requests 64 --nodes 8 --decode-tokens 8 --fail 2:3
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core import FaultInjector, LegioPolicy, VirtualCluster
from repro.models import api


class ResilientServer:
    """Round-based request scheduler over the Legio virtual cluster."""

    def __init__(self, cfg, cluster: VirtualCluster, *, prompt_len: int = 32,
                 decode_tokens: int = 8, batch_per_node: int = 4,
                 requeue: bool = True):
        self.cfg = cfg
        self.cluster = cluster
        self.prompt_len = prompt_len
        self.decode_tokens = decode_tokens
        self.batch_per_node = batch_per_node
        self.requeue = requeue
        key = jax.random.PRNGKey(0)
        self.params = api.init_params(cfg, key)
        self._prefill = jax.jit(
            lambda p, t: api.prefill(cfg, p, t, prompt_len + decode_tokens))
        self._decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
        self.completed: dict[int, np.ndarray] = {}
        self.abandoned: list[int] = []

    def _work_batch(self, request_ids: list[int]) -> np.ndarray:
        """Prefill + greedy-decode a batch of requests; returns token matrix."""
        B = len(request_ids)
        key = jax.random.PRNGKey(1234)
        tokens = jax.random.randint(
            key, (B, self.prompt_len), 0, self.cfg.vocab_size, jnp.int32)
        # deterministic per-request prompts (request id folds into row 0)
        tokens = tokens.at[:, 0].set(
            jnp.asarray(request_ids, jnp.int32) % self.cfg.vocab_size)
        logits, cache = self._prefill(self.params, tokens)
        out = []
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(self.decode_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return np.asarray(jnp.concatenate(out, axis=1))

    def run(self, n_requests: int) -> dict:
        cl = self.cluster
        queue = list(range(n_requests))
        t0 = time.perf_counter()
        round_idx = 0
        while queue and cl.live_nodes:
            cl.inject(round_idx)
            live = cl.live_nodes
            if not live:
                break
            # EP distribution: consecutive request slices per node
            assignments: dict[int, list[int]] = {}
            for i, node in enumerate(live):
                take = queue[i * self.batch_per_node:(i + 1) * self.batch_per_node]
                if take:
                    assignments[node] = take
            n_assigned = sum(len(v) for v in assignments.values())
            queue = queue[n_assigned:]

            failed_now = {n for n in cl.topo.nodes if n in cl.failed}
            for node, reqs in assignments.items():
                if node in failed_now:
                    if self.requeue:
                        queue.extend(reqs)        # REBALANCE analogue
                    else:
                        self.abandoned.extend(reqs)  # DROP analogue
                    continue
                result = self._work_batch(reqs)
                for rid, row in zip(reqs, result):
                    self.completed[rid] = row
            if failed_now:
                cl.repair(failed_now)
            round_idx += 1
        wall = time.perf_counter() - t0
        return {
            "completed": len(self.completed),
            "abandoned": len(self.abandoned),
            "unserved": len(queue),
            "rounds": round_idx,
            "wall_seconds": wall,
            "survivors": len(cl.live_nodes),
            "repairs": len(cl.repairs),
            "throughput_rps": len(self.completed) / wall if wall > 0 else 0.0,
        }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--fail", action="append", default=[],
                    help="round:node fault injection (repeatable)")
    ap.add_argument("--no-requeue", action="store_true",
                    help="DROP failed nodes' requests instead of re-queueing")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    pairs = []
    for s in args.fail:
        step, node = s.split(":")
        pairs.append((int(step), int(node)))
    cluster = VirtualCluster(
        args.nodes, policy=LegioPolicy(), injector=FaultInjector.at(pairs))
    server = ResilientServer(
        cfg, cluster, prompt_len=args.prompt_len,
        decode_tokens=args.decode_tokens, batch_per_node=args.batch_per_node,
        requeue=not args.no_requeue)
    print(f"[serve] arch={cfg.name} nodes={args.nodes} requests={args.requests}")
    rep = server.run(args.requests)
    for k, v in rep.items():
        print(f"  {k}: {v if not isinstance(v, float) else round(v, 3)}")
    ok = rep["completed"] + rep["abandoned"] == args.requests
    print(f"[serve] {'OK' if ok else 'INCOMPLETE'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
