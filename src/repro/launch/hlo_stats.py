"""Loop-aware cost/collective analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` on the CPU backend counts each ``while`` body
ONCE, regardless of trip count — with scan-over-layers that undercounts a
95-layer model by ~95x and misses every FSDP all-gather inside the layer
scan. This module re-derives per-device costs from the HLO text itself:

  * computations are parsed into blocks; ``while`` ops multiply their body's
    cost by the trip count recovered from the loop condition (the
    ``constant(N)`` compared against the induction variable — exact for
    every ``jax.lax.scan``);
  * FLOPs: ``dot`` ops contribute 2 * prod(result dims) * prod(contracting
    dims); fusions recurse into their called computation (CPU wraps dots in
    kOutput fusions);
  * bytes: operand + result bytes of every top-level instruction in the
    post-fusion HLO — the same "every instruction round-trips HBM" model
    XLA's own bytes-accessed uses;
  * collectives: per-op operand bytes and a ring wire-bytes estimate
    (all-gather (g-1)x shard, all-reduce 2(g-1)/g, reduce-scatter /
    all-to-all (g-1)/g, collective-permute 1x), scaled by enclosing loop
    trip counts.

All numbers are per-device (the compiled module is the per-device SPMD
program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier", "domain",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\(([^)]*(?:\([^)]*\))?[^)]*)\)(.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _parse_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _parse_dims(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


@dataclass
class CollectiveStats:
    counts: dict[str, float] = field(default_factory=dict)
    operand_bytes: dict[str, float] = field(default_factory=dict)
    wire_bytes: dict[str, float] = field(default_factory=dict)

    def add(self, kind: str, count: float, op_bytes: float, wire: float) -> None:
        self.counts[kind] = self.counts.get(kind, 0) + count
        self.operand_bytes[kind] = self.operand_bytes.get(kind, 0) + op_bytes
        self.wire_bytes[kind] = self.wire_bytes.get(kind, 0) + wire

    def merge_scaled(self, other: "CollectiveStats", scale: float) -> None:
        for k in other.counts:
            self.add(k, other.counts[k] * scale,
                     other.operand_bytes[k] * scale,
                     other.wire_bytes[k] * scale)

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def to_json(self) -> dict:
        return {
            "counts": {k: round(v, 1) for k, v in self.counts.items()},
            "operand_bytes": {k: round(v) for k, v in self.operand_bytes.items()},
            "wire_bytes": {k: round(v) for k, v in self.wire_bytes.items()},
            "total_operand_bytes": round(self.total_operand_bytes),
            "total_wire_bytes": round(self.total_wire_bytes),
        }


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: CollectiveStats = field(default_factory=CollectiveStats)

    def add_scaled(self, other: "Cost", scale: float) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.coll.merge_scaled(other.coll, scale)


class HloModule:
    """Parsed computations of one HLO module."""

    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self.result_shape: dict[str, str] = {}
        self._parse(text)
        self._cost_memo: dict[str, Cost] = {}

    # -- parsing ---------------------------------------------------------

    def _parse(self, text: str) -> None:
        current: list[Instr] | None = None
        for raw in text.splitlines():
            hdr = _COMP_HDR_RE.match(raw)
            if hdr:
                name = hdr.group(2)
                current = []
                self.computations[name] = current
                if hdr.group(1):
                    self.entry = name
                continue
            if raw.startswith("}"):
                current = None
                continue
            if current is None:
                continue
            m = _INSTR_RE.match(raw)
            if not m:
                continue
            name, shape, opcode, operands, attrs = m.groups()
            ops = re.findall(r"%([\w.\-]+)", operands)
            instr = Instr(name, shape, opcode, ops, attrs, raw)
            current.append(instr)
            self.result_shape[name] = shape

    # -- helpers ---------------------------------------------------------

    def _operand_bytes(self, instr: Instr) -> int:
        return sum(shape_bytes(self.result_shape.get(o, "")) for o in instr.operands)

    def _attr_target(self, instr: Instr, key: str) -> str | None:
        m = re.search(key + r"=%?([\w.\-]+)", instr.attrs)
        return m.group(1) if m else None

    def trip_count(self, cond_name: str) -> int:
        """Largest integer constant in the loop condition — exact for scans
        (induction var counts 0..N with a `compare LT constant(N)`)."""
        best = 1
        for instr in self.computations.get(cond_name, []):
            for c in _CONST_RE.finditer(instr.line):
                best = max(best, int(c.group(1)))
        return best

    def _dot_flops(self, instr: Instr) -> float:
        res = _parse_dims(instr.shape)
        if not res:
            return 0.0
        _, rdims = res[0]
        out = 1
        for d in rdims:
            out *= d
        lhs_shape = self.result_shape.get(instr.operands[0], "") if instr.operands else ""
        lhs = _parse_dims(lhs_shape)
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
        if m and lhs:
            _, ldims = lhs[0]
            for idx in m.group(1).split(","):
                if idx:
                    k *= ldims[int(idx)]
        return 2.0 * out * k

    def _fusion_bytes(self, instr: Instr, callee: str | None) -> float:
        """HBM bytes of a fusion node. In-place DUS-rooted fusions (the scan
        carry update, KV-cache writes) only touch the updated region; slice-
        rooted fusions only the extracted region — not the whole buffer."""
        root = None
        if callee and self.computations.get(callee):
            root = self.computations[callee][-1]
        if root is not None:
            if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
                upd = shape_bytes(self.result_shape.get(root.operands[1], ""))
                return 2.0 * upd
            if root.opcode in ("dynamic-slice", "slice", "gather"):
                return 2.0 * shape_bytes(instr.shape)
        return float(self._operand_bytes(instr) + shape_bytes(instr.shape))

    def _group_size(self, instr: Instr, default: int) -> int:
        m = _GROUPS_RE.search(instr.line)
        if m:
            return len(m.group(1).split(","))
        m = _GROUPS_IOTA_RE.search(instr.line)
        if m:
            return int(m.group(2))
        return default

    @staticmethod
    def _wire_factor(op: str, g: int) -> float:
        if g <= 1:
            return 0.0
        if op == "all-gather":
            return float(g - 1)
        if op == "all-reduce":
            return 2.0 * (g - 1) / g
        if op in ("reduce-scatter", "all-to-all"):
            return float(g - 1) / g
        return 1.0

    # -- cost ------------------------------------------------------------

    def comp_cost(self, name: str, n_devices: int,
                  _fusion_flops_only: bool = False) -> Cost:
        memo_key = name + ("!f" if _fusion_flops_only else "")
        if memo_key in self._cost_memo:
            return self._cost_memo[memo_key]
        total = Cost()
        for instr in self.computations.get(name, []):
            op = instr.opcode
            if op in _FREE_OPS:
                continue
            if op == "dot" or op == "convolution":
                total.flops += self._dot_flops(instr)
                if not _fusion_flops_only:
                    total.bytes += self._operand_bytes(instr) + shape_bytes(instr.shape)
                continue
            if op == "fusion":
                callee = self._attr_target(instr, "calls")
                if callee:
                    total.flops += self.comp_cost(
                        callee, n_devices, _fusion_flops_only=True).flops
                if not _fusion_flops_only:
                    total.bytes += self._fusion_bytes(instr, callee)
                continue
            if op == "while":
                body = self._attr_target(instr, "body")
                cond = self._attr_target(instr, "condition")
                trips = self.trip_count(cond) if cond else 1
                if body:
                    total.add_scaled(self.comp_cost(body, n_devices), trips)
                continue
            if op in ("call", "async-start"):
                callee = self._attr_target(instr, "to_apply") or \
                    self._attr_target(instr, "calls")
                if callee:
                    total.add_scaled(self.comp_cost(callee, n_devices), 1.0)
                continue
            if op == "conditional":
                for branch in re.findall(r"branch_computations=\{([^}]*)\}",
                                         instr.attrs):
                    for callee in re.findall(r"%([\w.\-]+)", branch):
                        total.add_scaled(self.comp_cost(callee, n_devices), 1.0)
                continue
            base = None
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    base = c
                    break
            if base is not None:
                if op.endswith("-done"):
                    continue
                g = self._group_size(instr, n_devices)
                op_bytes = self._operand_bytes(instr)
                total.coll.add(base, 1.0, op_bytes,
                               op_bytes * self._wire_factor(base, g))
                total.bytes += op_bytes + shape_bytes(instr.shape)
                continue
            if _fusion_flops_only:
                continue
            # sliced/in-place access: only the touched region moves, not the
            # whole source buffer (XLA does DUS in place)
            if op in ("dynamic-slice", "slice", "gather"):
                total.bytes += 2 * shape_bytes(instr.shape)
                continue
            if op in ("dynamic-update-slice", "scatter"):
                upd = instr.operands[1] if len(instr.operands) > 1 else None
                upd_b = shape_bytes(self.result_shape.get(upd, "")) if upd else 0
                total.bytes += 2 * upd_b
                continue
            # everything else (copy, transpose, convert, sort, rng, ...)
            total.bytes += self._operand_bytes(instr) + shape_bytes(instr.shape)
        self._cost_memo[memo_key] = total
        return total

    def entry_cost(self, n_devices: int) -> Cost:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry, n_devices)


def analyze(hlo_text: str, n_devices: int) -> Cost:
    return HloModule(hlo_text).entry_cost(n_devices)


def contributors(hlo_text: str, n_devices: int, top: int = 30) -> list[dict]:
    """Per-instruction cost attribution (scaled by loop trips) — the §Perf
    profiling view: where do the bytes/flops/wire actually come from."""
    mod = HloModule(hlo_text)
    rows: list[dict] = []

    def visit(comp: str, scale: float) -> None:
        for instr in mod.computations.get(comp, []):
            op = instr.opcode
            if op in _FREE_OPS:
                continue
            if op == "while":
                body = mod._attr_target(instr, "body")
                cond = mod._attr_target(instr, "condition")
                trips = mod.trip_count(cond) if cond else 1
                if body:
                    visit(body, scale * trips)
                continue
            if op in ("call", "async-start"):
                callee = mod._attr_target(instr, "to_apply") or \
                    mod._attr_target(instr, "calls")
                if callee:
                    visit(callee, scale)
                continue
            one = Cost()
            # reuse the single-instruction logic by wrapping in a fake comp
            mod_single = [instr]
            saved = mod.computations.get("__single__")
            mod.computations["__single__"] = mod_single
            mod._cost_memo.pop("__single__", None)
            one = mod.comp_cost("__single__", n_devices)
            if saved is not None:
                mod.computations["__single__"] = saved
            if one.bytes or one.flops or one.coll.total_wire_bytes:
                rows.append({
                    "comp": comp,
                    "name": instr.name,
                    "opcode": op,
                    "shape": instr.shape[:60],
                    "scale": scale,
                    "bytes": one.bytes * scale,
                    "flops": one.flops * scale,
                    "wire": one.coll.total_wire_bytes * scale,
                    "meta": _metadata_op_name(instr.line),
                })

    visit(mod.entry, 1.0)
    rows.sort(key=lambda r: -(r["bytes"] + r["wire"] * 10))
    return rows[:top]


_META_RE = re.compile(r'op_name="([^"]*)"')


def _metadata_op_name(line: str) -> str:
    m = _META_RE.search(line)
    return m.group(1)[-80:] if m else ""


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
    *,
    chip=None,
) -> dict:
    from repro.launch.hw import DEFAULT_CHIP
    chip = chip or DEFAULT_CHIP
    compute_s = flops_per_device / chip.peak_flops_bf16
    memory_s = bytes_per_device / chip.hbm_bw
    collective_s = wire_bytes_per_device / chip.ici_bw
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom
    terms["step_lower_bound_s"] = bound
    terms["roofline_fraction"] = compute_s / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) — the 'useful' FLOPs yardstick."""
    n = cfg.active_params() if cfg.is_moe else cfg.total_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch
