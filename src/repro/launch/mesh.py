"""Production mesh definitions.

Axis conventions (shared with ``repro.dist.sharding``):

  single-pod : ("data", "model")          = (16, 16)   -> 256 chips
  multi-pod  : ("pod", "data", "model")   = (2, 16, 16) -> 512 chips

``model`` carries tensor parallelism; ``data`` (joined by ``pod`` in
multi-pod mode) carries batch data-parallelism and FSDP param sharding.
The Legio runtime shrinks along the data/pod axes only — a failed host takes
its ICI slice with it, so the model axis is never fractured by a fault
(see core/mesh_manager.py).

Everything here is a function, never a module-level constant: importing this
module must not touch jax device state (the dry-run sets
``--xla_force_host_platform_device_count=512`` before first jax init).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_named_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh with the standard axis types (tests / small dry-runs)."""
    return make_mesh(shape, axes)


def mesh_chips(mesh: Mesh) -> int:
    return mesh.devices.size


def describe(mesh: Mesh) -> str:
    dims = "x".join(str(s) for s in mesh.devices.shape)
    return f"{dims} ({','.join(mesh.axis_names)}) = {mesh.devices.size} chips"
