# Intentionally import-free: ``dryrun.py`` must set XLA_FLAGS before anything
# in this package (or jax) is imported. Import submodules explicitly.
