import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Kernel-adjusted roofline: what the Pallas flash-attention kernel buys.

The dry-run lowers the blocked-XLA attention (Pallas only lowers on TPU), so
its memory term includes the (block_q, block_k) score tensors round-tripping
HBM between the two attention matmuls. On TPU the flash kernel keeps those
blocks in VMEM (see kernels/flash_attention.py — ~1.4 MB working set), so
the honest TPU roofline subtracts the attention-interior traffic and keeps
only q/k/v/o.

This tool attributes per-instruction HBM bytes (loop-scaled) to the
attention interior via op_name metadata (the einsum labels 'bhqs'/'bqhd'
and the online-softmax ops between them) and reports both terms.

  PYTHONPATH=src python -m repro.launch.kernel_roofline --arch llama3.2-3b \
      --shape train_4k
"""
import argparse
import re
import sys

import jax

from repro.configs.base import TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_shape
from repro.dist.compat import use_mesh
from repro.launch import hlo_stats
from repro.launch.hw import DEFAULT_CHIP
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cell_shardings, input_specs, step_fn_for

def attention_interior_bytes(hlo: str, n_dev: int, block_q: int,
                             block_k: int) -> float:
    """HBM bytes of score-block-shaped tensors inside the attention scans.

    The flash kernel's VMEM residency removes exactly these: every
    (.., block_q, block_k)-shaped intermediate (scores, masks, exp, probs)
    between the two attention matmuls. q/k/v block streaming stays — the
    kernel re-reads KV per query block just like the XLA path.
    """
    mod = hlo_stats.HloModule(hlo)
    # computations that belong to the blocked-attention kv sweep
    attn_comps = {
        name for name, instrs in mod.computations.items()
        if any("bhqs" in i.line or "bhqd" in i.line for i in instrs)
    }
    sig = re.compile(rf"\[[\d,]*{block_q},{block_k}\]")
    total = 0.0
    for r in hlo_stats.contributors(hlo, n_dev, top=10 ** 6):
        if r["comp"] in attn_comps and sig.search(r["shape"]):
            total += r["bytes"]
    return total


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-3b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    mesh = make_production_mesh()
    specs = input_specs(cfg, shape)
    in_sh, out_sh = cell_shardings(cfg, shape, mesh, specs)
    fn = step_fn_for(cfg, shape, TrainConfig())
    with use_mesh(mesh):
        compiled = jax.jit(
            fn, in_shardings=tuple(in_sh[k] for k in specs),
            out_shardings=out_sh,
            donate_argnums=(0, 1) if shape.kind == "train" else None,
        ).lower(*specs.values()).compile()
    hlo = compiled.as_text()
    n_dev = mesh.devices.size
    cost = hlo_stats.analyze(hlo, n_dev)

    bq = min(cfg.attn_block_q, shape.seq_len)
    bk = min(cfg.attn_block_k, shape.seq_len)
    attn_bytes = attention_interior_bytes(hlo, n_dev, bq, bk)
    chip = DEFAULT_CHIP
    mem = cost.bytes / chip.hbm_bw
    mem_adj = (cost.bytes - attn_bytes) / chip.hbm_bw
    comp = cost.flops / chip.peak_flops_bf16
    coll = cost.coll.total_wire_bytes / chip.ici_bw

    print(f"[kernel-roofline] {args.arch} x {args.shape} (single-pod, per-device)")
    print(f"  attention-interior HBM traffic: {attn_bytes:.3e} B "
          f"({100 * attn_bytes / cost.bytes:.1f}% of all bytes)")
    print(f"  memory term   blocked-XLA : {mem * 1e3:10.1f} ms")
    print(f"  memory term   Pallas-flash: {mem_adj * 1e3:10.1f} ms "
          f"({mem / mem_adj:.2f}x)")
    print(f"  compute {comp * 1e3:.1f} ms | collective {coll * 1e3:.1f} ms")
    bound = max(comp, mem, coll)
    bound_adj = max(comp, mem_adj, coll)
    print(f"  step bound: {bound * 1e3:.1f} -> {bound_adj * 1e3:.1f} ms "
          f"({bound / bound_adj:.2f}x); roofline fraction "
          f"{comp / bound:.3f} -> {comp / bound_adj:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
