"""Version-tolerant mesh constructors.

The repo pins ``jax==0.4.37`` (see pyproject.toml) but several mesh APIs
changed shape across nearby releases: ``jax.make_mesh`` grew an
``axis_types`` kwarg, ``AbstractMesh`` switched from a shape-tuple pairs
signature to ``(shape, names)``, and ``jax.sharding.set_mesh`` replaced the
``with mesh:`` resource context. These wrappers accept the modern calling
convention and degrade to what the pinned version provides, so source and
tests have exactly one spelling.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import AbstractMesh, Mesh

try:  # jax >= 0.5 re-exports shard_map at top level
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> AbstractMesh:
    """``AbstractMesh`` carrying shape/axis_names without real devices."""
    try:
        return AbstractMesh(shape, axes)  # modern (shape, names) signature
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


@contextmanager
def use_mesh(mesh: Mesh):
    """``jax.sharding.set_mesh`` where it exists, else the legacy
    ``with mesh:`` resource-env context (equivalent for our usage: both make
    bare-PartitionSpec constraints resolvable inside jit)."""
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        with set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
