"""Sharding rules: name-based parameter specs + activation constraints.

Axis conventions (shared with ``repro.launch.mesh``): meshes carry a
``model`` axis (tensor parallelism) plus one or more batch-parallel axes
(``data``, optionally a leading ``pod``). The rules here are *name-based*:
every weight matrix in the model trees follows the Megatron pattern —
input-side projections are column-parallel (``(..., D, F)`` sharded
``("data", "model")``: FSDP over the reduction dim, tensor-parallel over
the output dim), output-side projections are row-parallel
(``(..., F, D)`` sharded ``("model", "data")``), embeddings are
vocab-parallel, and norms/biases/SSM scalars stay replicated.

Every public helper degrades to a no-op outside a mesh context (the CPU
test/trainer path runs unsharded; only the dry-run and real launches open a
``with mesh:`` scope), and every spec is passed through
:func:`sanitize_spec` so a dimension that does not divide its mesh axes is
silently replicated instead of failing to lower — jit argument shardings
need exact divisibility (constraints would pad).
"""
from __future__ import annotations

import math
import warnings
from typing import Any

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

# Megatron-style classification by leaf name (see module docstring).
_IN_MATS = frozenset({"wq", "wk", "wv", "w_in", "w_gate", "in_proj",
                      "we_in", "we_gate"})
_OUT_MATS = frozenset({"wo", "w_out", "out_proj", "we_out"})
_EMBEDS = frozenset({"embed", "unembed"})


# ---------------------------------------------------------------------------
# mesh context
# ---------------------------------------------------------------------------

def current_mesh():
    """The ambient physical mesh (from ``with mesh:``), or None."""
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def _axis_product(mesh, entry) -> int:
    sizes = dict(mesh.shape)
    axes = entry if isinstance(entry, tuple) else (entry,)
    return math.prod(sizes[a] for a in axes)


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------

# (param, dim, mesh axes) triples already warned about — replication is
# silent after the first occurrence so sweeps over many layers of the same
# shape do not flood the log
_replication_warned: set[tuple] = set()


def sanitize_spec(spec: P, shape: tuple[int, ...], mesh,
                  *, param: str | None = None) -> P:
    """Drop spec axes whose dim is not divisible by the mesh axes' product
    (jit argument shardings need exact divisibility); trim trailing Nones.

    Each dropped axis is reported once per (param, dim, axes) via
    ``warnings.warn`` — a silently replicated weight is a real capacity/
    throughput surprise and should be visible the first time it happens.
    """
    out: list = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        prod = _axis_product(mesh, entry)
        if shape[i] % prod == 0:
            out.append(entry)
        else:
            key = (param, i, entry)
            if key not in _replication_warned:
                _replication_warned.add(key)
                warnings.warn(
                    f"sanitize_spec: dim {i} of {param or 'array'} "
                    f"(size {shape[i]}) does not divide mesh axes "
                    f"{entry!r} (product {prod}); replicating that "
                    f"dimension instead of sharding it",
                    UserWarning, stacklevel=2)
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _batch_dim_axes(mesh, global_batch: int):
    """Mesh axes the batch dimension shards over: all non-model axes if the
    batch divides their product, dropping the leading (pod) axis first;
    None (replicated) when nothing divides."""
    names = [n for n in mesh.axis_names if n != "model"]
    sizes = dict(mesh.shape)
    while names:
        prod = math.prod(sizes[n] for n in names)
        if global_batch % prod == 0:
            return tuple(names) if len(names) > 1 else names[0]
        names.pop(0)
    return None


def _key_name(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return entry.name
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    return str(entry)


def _param_rule(name: str, ndim: int) -> tuple:
    if name in _IN_MATS and ndim >= 2:
        return (None,) * (ndim - 2) + ("data", "model")
    if name in _OUT_MATS and ndim >= 2:
        return (None,) * (ndim - 2) + ("model", "data")
    if name in _EMBEDS:
        return ("model",)
    if name == "conv_w" and ndim >= 1:
        return (None,) * (ndim - 1) + ("model",)
    return ()


def param_specs(cfg, params: PyTree, mesh) -> PyTree:
    """PartitionSpec tree for a parameter (or optimizer-moment) tree."""
    del cfg  # rules are name-based; cfg kept for signature stability

    def leaf_spec(path, leaf):
        spec = P(*_param_rule(_key_name(path[-1]), len(leaf.shape)))
        name = ".".join(_key_name(e) for e in path)
        return sanitize_spec(spec, leaf.shape, mesh, param=name)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_specs(cfg, mesh, batch: PyTree, global_batch: int) -> PyTree:
    """Batch arrays shard dim 0 over the non-model axes, rest replicated."""
    del cfg
    b = _batch_dim_axes(mesh, global_batch)

    def leaf_spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(b, *((None,) * (nd - 1)))

    return jax.tree.map(leaf_spec, batch)


def cache_specs(cfg, mesh, cache: PyTree, global_batch: int) -> PyTree:
    """Decode-cache specs: (L, B, ...) leaves shard batch on dim 1; the KV
    head dim (3) is tensor-parallel — see models/attention.py docstring."""
    del cfg
    b = _batch_dim_axes(mesh, global_batch)

    def leaf_spec(path, leaf):
        nd = len(leaf.shape)
        if nd < 2:
            return P()
        name = _key_name(path[-1])
        if name in ("k", "v") and nd == 5:
            spec = P(None, b, None, "model", None)
        else:
            spec = P(None, b, *((None,) * (nd - 2)))
        name = ".".join(_key_name(e) for e in path)
        return sanitize_spec(spec, leaf.shape, mesh, param=name)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


# ---------------------------------------------------------------------------
# in-model constraints (no-ops outside a mesh context)
# ---------------------------------------------------------------------------

def _constrain(x: jax.Array, spec: P, mesh) -> jax.Array:
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, sanitize_spec(spec, x.shape, mesh)))


def shard_activations(x: jax.Array, mode: str = "batch") -> jax.Array:
    """Constrain an activation: dim 0 batch-parallel; under ``batch_seq``
    (sequence parallelism) dim 1 additionally shards over ``model``."""
    mesh = current_mesh()
    if mesh is None or mode == "none":
        return x
    b = _batch_dim_axes(mesh, x.shape[0])
    seq = "model" if (mode == "batch_seq" and x.ndim >= 3) else None
    return _constrain(x, P(b, seq, *((None,) * (x.ndim - 2))), mesh)


def shard_heads(x: jax.Array, mode: str = "batch", head_axis: int = 2) -> jax.Array:
    """Constrain a heads-major (or FFN-intermediate) tensor: dim 0
    batch-parallel, ``head_axis`` tensor-parallel over ``model``."""
    mesh = current_mesh()
    if mesh is None or mode == "none":
        return x
    spec: list = [None] * x.ndim
    spec[0] = _batch_dim_axes(mesh, x.shape[0])
    spec[head_axis] = "model"
    return _constrain(x, P(*spec), mesh)


def gather_fsdp(tree: PyTree, mode: str = "batch") -> PyTree:
    """Re-constrain a weight tree with the FSDP (``data``) axis removed —
    GSPMD emits the all-gather; tensor-parallel (``model``) axes stay."""
    mesh = current_mesh()
    if mesh is None or mode == "none":
        return tree

    def gather(path, leaf):
        rule = _param_rule(_key_name(path[-1]), leaf.ndim)
        spec = P(*[None if e == "data" else e for e in rule])
        return _constrain(leaf, spec, mesh)

    return jax.tree_util.tree_map_with_path(gather, tree)
