"""Distribution layer: sharding rules shared by models, launchers, dry-run."""
from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    gather_fsdp,
    param_specs,
    sanitize_spec,
    shard_activations,
    shard_heads,
)

__all__ = [
    "batch_specs", "cache_specs", "gather_fsdp", "param_specs",
    "sanitize_spec", "shard_activations", "shard_heads",
]
