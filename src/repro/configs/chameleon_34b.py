"""Chameleon-34B [arXiv:2405.09818; unverified]: 48L, d=8192, 64H GQA(kv=8),
d_ff=22016, vocab 65536 (early-fusion: text + VQ image tokens share the
vocabulary). The VQ image tokenizer is a STUB — input_specs() provides
precomputed token ids / patch embeddings per the assignment."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    tie_embeddings=False,
    activation="silu",
    frontend="patch",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="chameleon-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=192, vocab_size=512, attn_block_q=16, attn_block_k=16,
        xent_chunk=16, remat="none",
    )
