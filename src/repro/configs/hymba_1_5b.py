"""Hymba-1.5B [arXiv:2411.13676; hf]: 32L, d=1600, 25H GQA(kv=5) attention heads
in PARALLEL with mamba heads per layer, d_ff=5504, ssm_state=16, vocab 32001."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    hybrid_attn_window=1024,
    tie_embeddings=True,
    activation="silu",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="hymba-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, ssm_state=8, ssm_head_dim=16,
        ssm_chunk=16, hybrid_attn_window=16, attn_block_q=16, attn_block_k=16,
        xent_chunk=16, remat="none",
    )
