"""Gemma-7B [arXiv:2403.08295; hf]: 28L, d=3072, 16H (kv=16, i.e. MHA on 7b),
head_dim=256, d_ff=24576 GeGLU, vocab 256000, tied + scaled embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    tie_embeddings=True,
    scale_embeddings=True,
    activation="geglu",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="gemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=512, attn_block_q=16, attn_block_k=16,
        xent_chunk=16, remat="none",
    )
