"""Mamba2-130M [arXiv:2405.21060; unverified]: 24L, d=768, attention-free SSD
(state-space duality), state N=128, expand 2, head_dim 64, vocab 50280."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    conv_kernel=4,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-smoke", n_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, xent_chunk=16, remat="none",
    )
