"""Architecture registry: --arch <id> resolution for launchers/tests/benchmarks."""
from __future__ import annotations

import importlib
from typing import Callable

from repro.configs.base import (
    ALL_SHAPES,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    shape_applicable,
)

# arch id -> module name under repro.configs
_ARCH_MODULES: dict[str, str] = {
    "mixtral-8x22b": "mixtral_8x22b",
    "grok-1-314b": "grok_1_314b",
    "chameleon-34b": "chameleon_34b",
    "deepseek-67b": "deepseek_67b",
    "starcoder2-7b": "starcoder2_7b",
    "gemma-7b": "gemma_7b",
    "llama3.2-3b": "llama3_2_3b",
    "mamba2-130m": "mamba2_130m",
    "whisper-tiny": "whisper_tiny",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_IDS: tuple[str, ...] = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {', '.join(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def iter_cells(include_skipped: bool = False):
    """Yield (arch, shape, applicable, reason) for the assigned 10x4 grid."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            ok, reason = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch, shape, ok, reason
