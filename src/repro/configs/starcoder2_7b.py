"""StarCoder2-7B [arXiv:2402.19173; hf]: 32L, d=4608, 36H GQA(kv=4),
d_ff=18432 (non-gated GELU MLP), vocab 49152, RoPE, sliding window 4096."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    sliding_window=4096,
    rope_theta=1e5,
    tie_embeddings=True,
    activation="gelu",      # starcoder2 uses a plain (non-gated) GELU MLP
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=512, sliding_window=16,
        attn_block_q=16, attn_block_k=16, xent_chunk=16, remat="none",
    )
