"""Mixtral 8x22B [arXiv:2401.04088; hf]: 56L, d=6144, 48H GQA(kv=8), d_ff=16384,
vocab 32768, MoE 8 experts top-2, sliding-window attention."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1e6,
    tie_embeddings=False,
    activation="silu",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, n_experts=4, experts_per_token=2,
        sliding_window=16, moe_group_size=64, attn_block_q=16, attn_block_k=16,
        xent_chunk=16, remat="none",
    )
