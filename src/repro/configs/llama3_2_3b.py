"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-3B; unverified]: 28L, d=3072,
24H GQA(kv=8), d_ff=8192, vocab 128256, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=5e5,
    tie_embeddings=True,
    activation="silu",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="llama3.2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, attn_block_q=16, attn_block_k=16,
        xent_chunk=16, remat="none",
    )
