"""Whisper-tiny [arXiv:2212.04356; unverified]: enc-dec, 4+4L, d=384, 6H,
d_ff=1536, vocab 51865. Conv audio frontend is a STUB — input_specs() provides
precomputed 1500-frame encoder embeddings per the assignment."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq_len=1500,
    rope_theta=0.0,             # whisper uses learned/sinusoidal positions, not RoPE
    tie_embeddings=True,
    activation="gelu",
    frontend="audio",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="whisper-smoke", n_layers=2, n_encoder_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        encoder_seq_len=32, attn_block_q=16, attn_block_k=16, xent_chunk=16,
        remat="none",
    )
