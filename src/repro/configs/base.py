"""Model/run configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`. The config
is a frozen dataclass so it can be used as a static argument to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    Families:
      dense   -- standard decoder-only transformer (llama-style)
      moe     -- decoder-only transformer with mixture-of-experts FFN
      ssm     -- attention-free state-space model (Mamba-2 / SSD)
      hybrid  -- parallel attention + SSM heads per layer (hymba-style)
      encdec  -- encoder-decoder transformer (whisper-style)
      vlm     -- early-fusion VLM; the backbone is a dense transformer and the
                 image frontend is a stub (precomputed patch embeddings)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = full attention
    attn_logit_softcap: float = 0.0  # 0 = disabled (grok uses 30.0)
    attn_block_q: int = 512          # blocked-attention query tile
    attn_block_k: int = 1024         # blocked-attention key tile
    causal_block_skip: bool = True   # skip fully-masked KV blocks (perf lever)

    # --- mlp ---
    activation: str = "silu"         # silu -> SwiGLU, geglu -> GeGLU, gelu -> plain GELU

    # --- embeddings ---
    tie_embeddings: bool = True
    scale_embeddings: bool = False   # gemma multiplies embeddings by sqrt(d_model)

    # --- moe ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 4096       # tokens per dispatch group
    moe_aux_loss_weight: float = 0.01
    router_z_loss_weight: float = 0.001

    # --- ssm (Mamba-2 / SSD) ---
    ssm_state: int = 0               # N: state dimension per head
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_head_dim: int = 64           # P
    ssm_ngroups: int = 1             # B/C groups
    ssm_chunk: int = 256             # SSD chunk length
    conv_kernel: int = 4

    # --- hybrid (hymba) ---
    hybrid_attn_window: int = 1024   # SWA used by the attention branch
    meta_tokens: int = 0             # hymba learnable prefix tokens (0 = off)

    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper: 30s of audio at 50 Hz

    # --- modality frontend stubs ---
    frontend: str = "none"           # none | audio | patch

    # --- numerics / training ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: str = "full"              # none | full | dots
    scan_block: int = 0              # >0: two-level layer scan (remat over blocks)
    logits_softcap: float = 0.0
    use_pallas: bool = False         # pallas kernels (TPU); False = blocked-jnp path
    act_shard: str = "batch"         # none | batch | batch_seq (sequence parallelism)
    fsdp_gather: str = "layer"       # layer (ZeRO-3: re-gather per layer/pass)
                                     # | step (ZeRO-2: gather stacked weights once)

    # --- loss ---
    xent_chunk: int = 512            # sequence chunk for cross-entropy (bounds logits memory)
    z_loss_weight: float = 1e-4

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived quantities ----
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def gated_mlp(self) -> bool:
        return self.activation in ("silu", "geglu")

    # ---- parameter counting (used by tests + roofline MODEL_FLOPS) ----
    def attn_params(self) -> int:
        d = self.d_model
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def mlp_params_per_expert(self) -> int:
        mats = 3 if self.gated_mlp() else 2
        return mats * self.d_model * self.d_ff

    def ssm_params_per_layer(self) -> int:
        if self.ssm_state == 0:
            return 0
        d, di, n, h = self.d_model, self.d_inner, self.ssm_state, self.ssm_nheads
        g = self.ssm_ngroups
        in_proj = d * (2 * di + 2 * g * n + h)      # z, x, B, C, dt
        conv = (self.conv_kernel + 1) * (di + 2 * g * n)   # conv_w + conv_b
        out_proj = di * d
        extras = 3 * h + di                          # A_log, dt_bias, D, norm
        return in_proj + conv + out_proj + extras

    def params_per_layer(self) -> int:
        d = self.d_model
        norms = 2 * d
        if self.family == "ssm":
            return self.ssm_params_per_layer() + d
        ffn = self.mlp_params_per_expert()
        if self.is_moe:
            ffn = self.n_experts * ffn + self.d_model * self.n_experts
        attn = self.attn_params()
        if self.family == "hybrid":
            # ssd_norm is already inside ssm_params_per_layer()
            return attn + self.ssm_params_per_layer() + ffn + norms
        return attn + ffn + norms

    def embed_params(self) -> int:
        e = self.vocab_size * self.d_model
        return e if self.tie_embeddings else 2 * e

    def total_params(self) -> int:
        n = self.n_layers * self.params_per_layer() + self.embed_params() + self.d_model
        if self.is_encoder_decoder:
            # encoder layers use plain self-attn + mlp; decoder adds cross-attn
            enc = self.n_encoder_layers * (self.attn_params() + self.mlp_params_per_expert() + 2 * self.d_model)
            dec_cross = self.n_layers * (self.attn_params() + self.d_model)
            n += enc + dec_cross + self.d_model    # + enc_norm
        return n

    def active_params(self) -> int:
        """Params touched per token (MoE uses experts_per_token of n_experts)."""
        if not self.is_moe:
            return self.total_params()
        d = self.d_model
        ffn_active = self.experts_per_token * self.mlp_params_per_expert()
        per_layer = self.attn_params() + ffn_active + 2 * d + d * self.n_experts
        return self.n_layers * per_layer + self.embed_params() + d


@dataclass(frozen=True)
class ShapeSpec:
    """An assigned (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES: Mapping[str, ShapeSpec] = {s.name: s for s in ALL_SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention; see DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k":
        subquad = (
            cfg.family in ("ssm", "hybrid")
            or (cfg.sliding_window > 0 and cfg.sliding_window < shape.seq_len)
        )
        if not subquad:
            return False, "full-attention arch: 524k-token decode is quadratic; skipped per assignment"
    if cfg.is_encoder_decoder and shape.kind == "decode" and shape.seq_len > 32768:
        return False, "enc-dec decoder window bounded by encoder context"
    return True, ""


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / loop hyperparameters."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    # distributed-optimization knobs (beyond-paper)
    grad_compression: str = "none"   # none | int8 | topk
    topk_fraction: float = 0.05
    # legio knobs (the paper's two knobs + policies)
    legion_size: int = 0             # k; 0 = auto (Eq. 3)
    hierarchical_threshold: int = 12 # use hierarchy when cluster size > threshold (paper: s>11)
    root_failure_policy: str = "ignore"  # ignore | stop   (paper §IV)
    batch_policy: str = "drop"       # drop | rebalance
    straggler_threshold: float = 3.0 # x median step time; 0 = off
    checkpoint_every: int = 0        # steps; 0 = off
    checkpoint_dir: str = ""
