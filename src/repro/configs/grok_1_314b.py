"""Grok-1 314B [hf:xai-org/grok-1; unverified]: 64L, d=6144, 48H GQA(kv=8),
d_ff=32768, vocab 131072, MoE 8 experts top-2, attention logit softcap 30."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    experts_per_token=2,
    attn_logit_softcap=30.0,
    logits_softcap=30.0,
    tie_embeddings=True,
    activation="geglu",      # grok-1 MoE MLP is gated GeLU (linear/linear_v/linear_1)
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="grok-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=256, n_experts=4, experts_per_token=2,
        moe_group_size=64, attn_block_q=16, attn_block_k=16, xent_chunk=16,
        remat="none",
    )
