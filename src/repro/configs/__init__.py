from repro.configs.base import (
    ALL_SHAPES,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    TrainConfig,
    shape_applicable,
)
from repro.configs.registry import (
    ARCH_IDS,
    get_config,
    get_shape,
    get_smoke_config,
    iter_cells,
)

__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "TrainConfig",
    "get_config",
    "get_shape",
    "get_smoke_config",
    "iter_cells",
    "shape_applicable",
]
