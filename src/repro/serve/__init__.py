"""repro.serve — fault-resilient request serving (the Legio shape, served).

Legio's target class is embarrassingly parallel work where failed nodes are
discarded and the survivors keep going; a request-serving fleet is exactly
that shape. This package promotes serving to a first-class subsystem over
the same recovery stack training uses:

  * :class:`RequestRouter` (router) — shards the request stream across
    legions via the topology masters, least-loaded first, and re-homes
    queues when a repair changes the ring;
  * :class:`LegionQueue` / :class:`Request` (queue) — per-legion FIFO work
    queues; redelivered requests go to the front;
  * :class:`MicroBatcher` (batcher) — per-node batches sized by
    ``LegioPolicy.serve_microbatch``;
  * :class:`ServeEngine` (engine) — the round loop: dispatch against a
    pinned TopologyView, let faults land mid-flight, drain the
    FaultPipeline, and re-enqueue every verdict node's in-flight requests
    through a pipeline listener;
  * :class:`ServeMetrics` (metrics) — round-latency percentiles, goodput,
    and per-legion stall accounting.

Invariants the tests assert (tests/test_serve.py):

  * **at-least-once re-enqueue** — a request on a failed node is always
    redelivered (or explicitly parked/abandoned), never silently lost;
  * **exactly-once completion** — the dedup guard collapses redeliveries,
    so the client observes one completion per request id;
  * **no stall on healthy legions** — serving overlaps repair; a healthy
    legion with pending work dispatches every round.
"""
from repro.serve.batcher import MicroBatcher
from repro.serve.engine import (
    RECOVERY_PRESETS,
    RoundReport,
    ServeEngine,
    ServeReport,
    recovery_preset,
)
from repro.serve.metrics import CompletionRecord, ServeMetrics
from repro.serve.queue import LegionQueue, Request
from repro.serve.router import RequestRouter

__all__ = [
    "CompletionRecord", "LegionQueue", "MicroBatcher", "RECOVERY_PRESETS",
    "Request", "RequestRouter", "RoundReport", "ServeEngine", "ServeMetrics",
    "ServeReport", "recovery_preset",
]
