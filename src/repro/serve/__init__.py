"""repro.serve — fault-resilient request serving (the Legio shape, served).

Legio's target class is embarrassingly parallel work where failed nodes are
discarded and the survivors keep going; a request-serving fleet is exactly
that shape. This package promotes serving to a first-class subsystem over
the same recovery stack training uses:

  * :class:`RequestRouter` (router) — shards the request stream across
    legions via the topology masters, least-loaded first with fully
    deterministic tie-breaks, and re-homes queues when a repair changes
    the ring;
  * :class:`LegionQueue` / :class:`Request` (queue) — per-legion work
    queues; FIFO until deadlines appear, then slack-ordered; requests
    carry their prefill/decode service spec and phase progress;
  * :class:`MicroBatcher` (batcher) — per-slot batches sized by
    ``LegioPolicy.serve_microbatch``, deadline-aware composition;
  * :class:`ServeEngine` (engine) — continuous batching: per-legion
    in-flight windows admit new micro-batches the moment a slot frees,
    independent of other legions' progress or in-flight repairs; a
    prefill/decode phase split with separate cost accounting; SLO-keyed
    admission control; and decode-state migration off dead nodes through
    the FaultPipeline listener path. The lock-step barrier loop survives
    as the measurable baseline (``continuous=False``);
  * :class:`TrafficGenerator` (traffic) — seeded open-loop Poisson
    arrivals with diurnal/burst profiles and per-request SLO classes over
    a millions-strong simulated user population;
  * :class:`ServeMetrics` (metrics) — latency percentiles in rounds and
    simulated-clock seconds, goodput, SLO attainment, per-phase ticks,
    and starvation accounting.

Invariants the tests assert (tests/test_serve.py + the chaos harness):

  * **at-least-once re-enqueue** — a request on a failed node is always
    redelivered (or explicitly parked/abandoned/shed), never silently
    lost;
  * **exactly-once completion** — the dedup guard collapses redeliveries
    (including migrated decode states), so the client observes one
    completion per request id;
  * **no stall on healthy legions** — serving overlaps repair; a healthy
    legion with backlog and a free window slot admits every round.
"""
from repro.serve.batcher import MicroBatcher
from repro.serve.engine import (
    RECOVERY_PRESETS,
    RoundReport,
    ServeEngine,
    ServeReport,
    recovery_preset,
)
from repro.serve.metrics import CompletionRecord, ServeMetrics
from repro.serve.queue import LegionQueue, Request
from repro.serve.router import RequestRouter
from repro.serve.traffic import (
    DEFAULT_SLO_CLASSES,
    Arrival,
    Burst,
    SLOClass,
    TrafficGenerator,
)

__all__ = [
    "Arrival", "Burst", "CompletionRecord", "DEFAULT_SLO_CLASSES",
    "LegionQueue", "MicroBatcher", "RECOVERY_PRESETS", "Request",
    "RequestRouter", "RoundReport", "SLOClass", "ServeEngine",
    "ServeMetrics", "ServeReport", "TrafficGenerator", "recovery_preset",
]
