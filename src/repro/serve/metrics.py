"""Serving metrics — latency percentiles, goodput, and stall accounting.

Latency is measured in *rounds* (simulated step-latency), not wall seconds:
the number a client would observe is deterministic given the campaign, so
tests and benchmarks can assert on it structurally instead of flaking on
loaded runners. Per-legion dispatch counters expose the non-blocking
claim directly: a healthy legion's dispatch trace has no zero while a
repair is in flight elsewhere.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class CompletionRecord:
    rid: int
    enqueue_step: int
    complete_step: int
    attempts: int
    legion: int
    node: int

    @property
    def latency_rounds(self) -> int:
        return self.complete_step - self.enqueue_step


@dataclass
class ServeMetrics:
    completions: list[CompletionRecord] = field(default_factory=list)
    requeues: int = 0                    # redeliveries (at-least-once cost)
    duplicates_suppressed: int = 0       # dedup guard hits
    parked: list[int] = field(default_factory=list)   # hit serve_max_attempts
    abandoned: list[int] = field(default_factory=list)  # DROP policy losses
    # per-round dispatch counts: step -> {legion: n_requests_dispatched}
    dispatch_trace: dict[int, dict[int, int]] = field(default_factory=dict)

    # -- recording -----------------------------------------------------------

    def record_dispatch(self, step: int, legion: int, n: int) -> None:
        row = self.dispatch_trace.setdefault(step, {})
        row[legion] = row.get(legion, 0) + n

    def record_completion(self, rec: CompletionRecord) -> None:
        self.completions.append(rec)

    # -- aggregates ----------------------------------------------------------

    def latency_percentile(self, p: float,
                           legions: set[int] | None = None) -> float:
        """p-th percentile of round-latency, optionally restricted to
        requests completed by the given legions (nearest-rank method)."""
        lat = sorted(r.latency_rounds for r in self.completions
                     if legions is None or r.legion in legions)
        if not lat:
            return 0.0
        rank = min(len(lat) - 1, max(0, int(round(p / 100.0 * len(lat))) - 1))
        return float(lat[rank])

    def goodput(self, rounds: int) -> float:
        """Completed requests per round over the campaign."""
        return len(self.completions) / rounds if rounds else 0.0

    def stalled_rounds(self, legion: int, first: int, last: int) -> int:
        """Rounds in [first, last] where ``legion`` dispatched nothing.
        Zero for a healthy legion with pending work — the non-blocking
        acceptance criterion."""
        return sum(1 for step in range(first, last + 1)
                   if self.dispatch_trace.get(step, {}).get(legion, 0) == 0)

    def summary(self, rounds: int) -> dict:
        return {
            "completed": len(self.completions),
            "requeues": self.requeues,
            "duplicates_suppressed": self.duplicates_suppressed,
            "parked": len(self.parked),
            "abandoned": len(self.abandoned),
            "p50_latency_rounds": self.latency_percentile(50),
            "p99_latency_rounds": self.latency_percentile(99),
            "max_attempts_seen": max((r.attempts for r in self.completions),
                                     default=0),
            "goodput_rps": self.goodput(rounds),
        }
