"""Serving metrics — latency percentiles, goodput, SLO attainment, and
per-phase cost accounting.

Latency is recorded twice per completion: in *rounds* (the legacy unit)
and in *simulated-clock seconds* (``arrival_sim`` → ``complete_sim``, the
cluster's deterministic clock). The sim-seconds numbers are what the
load-curve benchmark asserts on — they are byte-identical across runs
given a seeded campaign, per the repo's structural-benchmark convention —
while wall time (``time.perf_counter``) is kept alongside per round for
human inspection only, never for pass/fail.

The continuous-batching engine also feeds:

  * **phase accounting** — every prefill tick and decode tick lands in
    ``phase_ticks`` separately, so the prefill/decode cost split is a
    first-class number (and decode-state migration shows up directly as
    decode ticks *not* re-spent);
  * **admission outcomes** — ``shed`` (rejected at the door by SLO
    feasibility) next to the delivery ledger's ``parked``/``abandoned``;
  * **starvation** — a round where a legion had backlog *and* free window
    slots yet admitted nothing. Zero for healthy legions is the
    no-stall acceptance bar (``stalled_rounds`` keeps the legacy
    dispatch-trace view: with multi-tick service a busy window
    legitimately admits nothing, which is not a stall).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class CompletionRecord:
    rid: int
    enqueue_step: int
    complete_step: int
    attempts: int
    legion: int
    node: int
    arrival_sim: float = 0.0
    complete_sim: float = 0.0
    slo_class: str = "standard"
    deadline_sim: float = math.inf
    migrated: bool = False         # decode progress survived a node death

    @property
    def latency_rounds(self) -> int:
        return self.complete_step - self.enqueue_step

    @property
    def latency_sim(self) -> float:
        return self.complete_sim - self.arrival_sim

    @property
    def met_slo(self) -> bool:
        return self.complete_sim <= self.deadline_sim


@dataclass
class ServeMetrics:
    completions: list[CompletionRecord] = field(default_factory=list)
    requeues: int = 0                    # redeliveries (at-least-once cost)
    duplicates_suppressed: int = 0       # dedup guard hits
    parked: list[int] = field(default_factory=list)   # hit serve_max_attempts
    abandoned: list[int] = field(default_factory=list)  # DROP policy losses
    shed: list[int] = field(default_factory=list)     # admission rejections
    migrations: int = 0                  # decode states moved off dead nodes
    decode_ticks_preserved: int = 0      # decode work migration did not redo
    # per-phase cost split (ticks of step_sim_seconds each)
    phase_ticks: dict[str, int] = field(
        default_factory=lambda: {"prefill": 0, "decode": 0})
    # per-round dispatch counts: step -> {legion: n_requests_dispatched}
    dispatch_trace: dict[int, dict[int, int]] = field(default_factory=dict)
    # backlog + free capacity but nothing admitted: step -> [legions]
    starvation_trace: dict[int, list[int]] = field(default_factory=dict)
    # per-round duration, sim seconds and wall seconds side by side
    round_seconds: dict[int, dict[str, float]] = field(default_factory=dict)

    # -- recording -----------------------------------------------------------

    def record_dispatch(self, step: int, legion: int, n: int) -> None:
        row = self.dispatch_trace.setdefault(step, {})
        row[legion] = row.get(legion, 0) + n

    def record_starved(self, step: int, legion: int) -> None:
        self.starvation_trace.setdefault(step, []).append(legion)

    def record_round(self, step: int, sim: float, wall: float) -> None:
        self.round_seconds[step] = {"sim": sim, "wall": wall}

    def record_completion(self, rec: CompletionRecord) -> None:
        self.completions.append(rec)

    def record_phase_tick(self, phase: str, n: int = 1) -> None:
        self.phase_ticks[phase] += n

    # -- aggregates ----------------------------------------------------------

    def latency_percentile(self, p: float,
                           legions: set[int] | None = None,
                           unit: str = "rounds") -> float:
        """p-th percentile of completion latency (nearest-rank method),
        optionally restricted to requests completed by the given legions.
        ``unit`` is "rounds" (legacy) or "sim" (simulated-clock seconds —
        the deterministic number the benchmarks assert on)."""
        if unit not in ("rounds", "sim"):
            raise ValueError(f"unit must be 'rounds' or 'sim', got {unit!r}")
        lat = sorted(
            (r.latency_rounds if unit == "rounds" else r.latency_sim)
            for r in self.completions
            if legions is None or r.legion in legions)
        if not lat:
            return 0.0
        rank = min(len(lat) - 1, max(0, int(round(p / 100.0 * len(lat))) - 1))
        return float(lat[rank])

    def goodput(self, rounds: int) -> float:
        """Completed requests per round over the campaign."""
        return len(self.completions) / rounds if rounds else 0.0

    def goodput_sim(self, sim_seconds: float) -> float:
        """Completed requests per simulated second — the number that stays
        comparable when round durations differ (lock-step rounds stretch
        to their slowest in-flight batch)."""
        return len(self.completions) / sim_seconds if sim_seconds else 0.0

    def slo_attainment(self) -> float:
        """Fraction of completions that met their deadline (deadline-less
        requests count as met)."""
        if not self.completions:
            return 1.0
        return sum(1 for r in self.completions if r.met_slo) \
            / len(self.completions)

    def stalled_rounds(self, legion: int, first: int, last: int) -> int:
        """Rounds in [first, last] where ``legion`` dispatched nothing.
        Zero for a healthy legion with pending work and single-tick
        service — with multi-tick service prefer :meth:`starved_rounds`,
        which only counts rounds where free capacity went unused."""
        return sum(1 for step in range(first, last + 1)
                   if self.dispatch_trace.get(step, {}).get(legion, 0) == 0)

    def starved_rounds(self, legion: int | None = None) -> int:
        """Rounds where a legion (or any, with ``None``) had backlog and a
        free window slot yet admitted nothing — the continuous-batching
        no-stall acceptance metric; must be zero for healthy legions."""
        return sum(
            1 for legions in self.starvation_trace.values()
            for lg in legions if legion is None or lg == legion)

    def summary(self, rounds: int) -> dict:
        return {
            "completed": len(self.completions),
            "requeues": self.requeues,
            "duplicates_suppressed": self.duplicates_suppressed,
            "parked": len(self.parked),
            "abandoned": len(self.abandoned),
            "shed": len(self.shed),
            "migrations": self.migrations,
            "decode_ticks_preserved": self.decode_ticks_preserved,
            "prefill_ticks": self.phase_ticks["prefill"],
            "decode_ticks": self.phase_ticks["decode"],
            "p50_latency_rounds": self.latency_percentile(50),
            "p99_latency_rounds": self.latency_percentile(99),
            "p50_latency_sim": self.latency_percentile(50, unit="sim"),
            "p99_latency_sim": self.latency_percentile(99, unit="sim"),
            "p999_latency_sim": self.latency_percentile(99.9, unit="sim"),
            "slo_attainment": round(self.slo_attainment(), 4),
            "starved_rounds": self.starved_rounds(),
            "max_attempts_seen": max((r.attempts for r in self.completions),
                                     default=0),
            "goodput_rps": self.goodput(rounds),
        }
