"""ServeEngine — fault-resilient request serving over the Legio runtime.

The serving analogue of :class:`LegioExecutor.run_step`: one *round* is the
step-boundary seam, and everything the executor owns for training shards
the engine owns for requests. Per round:

  1. boundary — the SpareProvisioner delivers re-spawned spares and
     warmed-up non-blocking substitutes rejoin (same polls as training);
  2. dispatch — the :class:`RequestRouter` reconciles its queues against a
     *pinned* ``TopologyView`` snapshot and the :class:`MicroBatcher` forms
     per-node batches (``LegioPolicy.serve_microbatch``), recording every
     dispatched request id in the in-flight registry;
  3. faults land — injected ground truth arrives *after* dispatch, so a
     dying node takes its in-flight batch with it (the failure mode the
     old synchronous loop turned into lost requests);
  4. execute — healthy nodes complete their batches (dedup guard: a request
     id completes at most once from the client's view); the result-gather
     surfaces PROC_FAILED for dead dispatched nodes into the pipeline's
     collective channel;
  5. drain — the result gather is one interposed call on the MPI facade
     (``repro.mpi.Comm.gather``): it traps the lost nodes' PROC_FAILED,
     runs detect → notice → agree → plan → apply, and returns only after
     the repair landed; the engine's pipeline listener re-enqueues every
     verdict node's in-flight requests (front of the least-loaded surviving
     legion's queue). Healthy legions dispatched in step 2 and keep
     dispatching next round — repair never barriers serving (non-blocking
     substitute path).

Invariants (asserted by tests/test_serve.py):

  * **at-least-once** — a request is never lost: it is in exactly one of
    {a legion queue, a node's in-flight set, the completed map,
    metrics.parked, metrics.abandoned} at every round boundary;
  * **exactly-once completion** — the dedup guard keys on the request id;
    redeliveries of an already-completed request are suppressed, so the
    client observes exactly one completion per id;
  * **no stall on healthy legions** — a legion with pending work and live
    members dispatches every round, including rounds where another
    legion's repair is in flight.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.executor import VirtualCluster
from repro.core.types import FaultSource, RecoveryAction
from repro.mpi import Session
from repro.serve.batcher import MicroBatcher
from repro.serve.metrics import CompletionRecord, ServeMetrics
from repro.serve.queue import Request
from repro.serve.router import RequestRouter

# work_fn(node, batch, step) -> {rid: result}
WorkFn = Callable[[int, list[Request], int], dict[int, Any]]

RECOVERY_PRESETS = ("shrink", "substitute", "nonblocking")


def recovery_preset(name: str, *, spare_fraction: float = 0.25) -> dict:
    """Canonical ``LegioPolicy`` overrides for the serving recovery setups —
    the CLI (launch/serve.py), the benchmark (serve_latency), and the tests
    share this single source instead of drifting copies."""
    presets = {
        "shrink": dict(recovery_mode="shrink"),
        "substitute": dict(recovery_mode="substitute_then_shrink",
                           spare_fraction=spare_fraction),
        "nonblocking": dict(recovery_mode="substitute_then_shrink",
                            spare_fraction=spare_fraction,
                            nonblocking_substitution=True),
    }
    return presets[name]


@dataclass
class RoundReport:
    """One serving round, surfaced the way StepReport surfaces a step."""

    step: int
    dispatched: dict[int, int]               # node -> batch size
    completed_now: int
    requeued_now: int
    actions: tuple[RecoveryAction, ...] = ()
    respawned: tuple[int, ...] = ()
    expanded: tuple[tuple[int, int], ...] = ()
    backlog: int = 0
    inflight: int = 0
    wall_seconds: float = 0.0


@dataclass
class ServeReport:
    """Campaign summary returned by :meth:`ServeEngine.serve`."""

    rounds: int
    submitted: int
    completed: int
    metrics_summary: dict = field(default_factory=dict)
    survivors: int = 0
    repairs: int = 0


class ServeEngine:
    """Routes, batches, executes, and redelivers requests transparently."""

    def __init__(
        self,
        cluster: "VirtualCluster | Session",
        work_fn: WorkFn,
        *,
        microbatch: int | None = None,
        requeue: bool = True,
        observe_stragglers: bool = True,
    ):
        # all fault plumbing goes through the MPI facade; a driver may hand
        # in its Session directly (launch/serve.py) or a bare cluster
        if isinstance(cluster, Session):
            self.session = cluster
            cluster = cluster.cluster
        else:
            self.session = Session.adopt(cluster)
        self._comm = self.session.world
        self.cluster = cluster
        self.work_fn = work_fn
        self.requeue = requeue
        # wall-clock work latency feeds the straggler detector only when the
        # caller says it is trustworthy — a work_fn that jit-compiles on
        # batch-shape changes (launch/serve.py) would soft-fail healthy
        # nodes on compile noise
        self.observe_stragglers = observe_stragglers
        self.router = RequestRouter()
        self.batcher = MicroBatcher(
            microbatch or cluster.policy.serve_microbatch)
        self.metrics = ServeMetrics()
        self.completed: dict[int, Any] = {}      # rid -> result (write-once)
        self._inflight: dict[int, list[Request]] = {}   # node -> batch
        self._next_rid = 0
        self._submitted = 0
        self.round_count = 0
        cluster.pipeline.add_listener(self._on_recovery_action)

    # -- client surface ------------------------------------------------------

    def submit(self, payloads: list[Any] | int) -> list[int]:
        """Enqueue new requests (payloads, or a count of payload-less ones).
        Returns the assigned request ids."""
        if isinstance(payloads, int):
            payloads = [None] * payloads
        reqs = []
        for payload in payloads:
            reqs.append(Request(rid=self._next_rid, payload=payload,
                                enqueue_step=self.round_count))
            self._next_rid += 1
        self._submitted += len(reqs)
        self.router.submit(reqs, self.cluster.topo.view())
        return [r.rid for r in reqs]

    @property
    def pending(self) -> int:
        return self.router.backlog + sum(
            len(b) for b in self._inflight.values())

    # -- fault plumbing ------------------------------------------------------

    def _on_recovery_action(self, action: RecoveryAction) -> None:
        """Pipeline listener: the repair for ``action.verdict`` has been
        applied — re-enqueue every verdict node's in-flight requests.
        One topology snapshot covers the whole action (the repair already
        landed; nothing mutates between redeliveries)."""
        view = None
        for node in action.verdict:
            batch = self._inflight.pop(node, [])
            if batch and view is None:
                view = self.cluster.topo.view()
            for req in batch:
                self._redeliver(req, view)

    def _redeliver(self, req: Request, view=None) -> None:
        if req.rid in self.completed:
            # completed on a previous delivery — the dedup guard keeps the
            # at-least-once redelivery invisible to the client
            self.metrics.duplicates_suppressed += 1
            return
        if not self.requeue:
            self.metrics.abandoned.append(req.rid)      # DROP semantics
            return
        cap = self.cluster.policy.serve_max_attempts
        if cap and req.attempts >= cap:
            self.metrics.parked.append(req.rid)
            return
        self.metrics.requeues += 1
        self.router.requeue(
            req, view if view is not None else self.cluster.topo.view())

    def _complete(self, req: Request, result: Any, step: int,
                  node: int) -> None:
        if req.rid in self.completed:
            self.metrics.duplicates_suppressed += 1
            return
        self.completed[req.rid] = result
        self.metrics.record_completion(CompletionRecord(
            rid=req.rid, enqueue_step=req.enqueue_step, complete_step=step,
            attempts=req.attempts, legion=req.legion if req.legion is not None
            else -1, node=node))

    # -- one serving round ---------------------------------------------------

    def run_round(self, step: int | None = None) -> RoundReport:
        cl = self.cluster
        step = self.round_count if step is None else step
        t_start = time.perf_counter()

        # 1. boundary: elastic refills + warmed-up substitutes rejoin
        boundary = self.session.deliver(step)

        # 2. dispatch against a pinned snapshot — a repair can neither run
        #    nor tear the structure while batches are being formed
        dispatched_sizes: dict[int, int] = {}
        with cl.topo.pinned() as tv:
            self.router.reconcile(tv)
            for lg in tv.legions:
                members = [n for n in lg.members if n not in cl.failed]
                if not members:
                    continue
                queue = self.router.queue_of(lg.index)
                for node, batch in self.batcher.form(queue, members).items():
                    for req in batch:
                        req.attempts += 1
                    self._inflight[node] = batch
                    dispatched_sizes[node] = len(batch)
                    self.metrics.record_dispatch(step, lg.index, len(batch))

        # 3. faults land mid-flight; the sim clock ticks
        self.session.inject(step)

        # 4. execute — healthy nodes complete, dead ones lose their batch
        completed_before = len(self.completed)
        for node in cl.live_nodes:
            cl.detector.beat(node, cl.clock.sim_seconds)
        dropped_view = None
        for node in [n for n in self._inflight if n not in cl.failed]:
            batch = self._inflight.pop(node)
            t0 = time.perf_counter()
            results = self.work_fn(node, batch, step)
            if self.observe_stragglers:
                cl.straggler.observe(node, time.perf_counter() - t0)
            for req in batch:
                if req.rid in results:
                    self._complete(req, results[req.rid], step, node)
                else:
                    # the work_fn dropped this id (partial result) — that
                    # is a delivery failure, not a completion: redeliver,
                    # never record a completion the client didn't get
                    if dropped_view is None:
                        dropped_view = cl.topo.view()
                    self._redeliver(req, dropped_view)
        # 5. the result gather, as one interposed facade call: the lost
        #    nodes' PROC_FAILED is trapped among the dispatched set, the
        #    crash channels drain, and the pipeline listener re-enqueues
        #    verdict nodes' batches before the call returns
        requeues_before = self.metrics.requeues
        self._comm.gather(among=set(self._inflight))
        self.session.poll((FaultSource.STRAGGLER,))
        actions = list(self.session.take_actions())
        # safety net: a dead node whose loss produced no verdict this round
        # (e.g. no surviving observer) still must not strand its batch —
        # redeliver now; the heartbeat channel will confirm the node later
        stranded_view = None
        for node in [n for n in list(self._inflight) if n in cl.failed]:
            batch = self._inflight.pop(node)
            if batch and stranded_view is None:
                stranded_view = cl.topo.view()
            for req in batch:
                self._redeliver(req, stranded_view)

        self.round_count = step + 1
        return RoundReport(
            step=step,
            dispatched=dispatched_sizes,
            completed_now=len(self.completed) - completed_before,
            requeued_now=self.metrics.requeues - requeues_before,
            actions=tuple(actions),
            respawned=boundary.respawned,
            expanded=boundary.expanded,
            backlog=self.router.backlog,
            inflight=sum(len(b) for b in self._inflight.values()),
            wall_seconds=time.perf_counter() - t_start,
        )

    # -- campaign ------------------------------------------------------------

    def serve(self, max_rounds: int = 10_000) -> ServeReport:
        """Run rounds until every submitted request is completed (or parked/
        abandoned), the cluster dies, or ``max_rounds`` is hit."""
        reports: list[RoundReport] = []
        while self.pending and self.cluster.live_nodes \
                and len(reports) < max_rounds:
            reports.append(self.run_round())
        return ServeReport(
            rounds=len(reports),
            submitted=self._submitted,
            completed=len(self.completed),
            metrics_summary=self.metrics.summary(max(len(reports), 1)),
            survivors=len(self.cluster.live_nodes),
            repairs=len(self.cluster.repairs),
        )
