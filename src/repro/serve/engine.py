"""ServeEngine — continuous-batching, fault-resilient request serving over
the Legio runtime.

The serving analogue of :class:`LegioExecutor.run_step`, rebuilt around
continuous batching: the global lock-step round barrier is gone. Each call
to :meth:`run_round` advances the cluster one simulated *tick*, and within
a tick every legion runs its own admission loop — a node admits a fresh
micro-batch into any free slot of its in-flight *window*
(``LegioPolicy.serve_window``) the moment a previous batch completes,
independent of every other legion's progress and of in-flight repairs.
One slow batch no longer gates global throughput; it occupies exactly one
slot on one node while everything else keeps flowing.

Per tick:

  1. boundary — the SpareProvisioner delivers re-spawned spares and
     warmed-up non-blocking substitutes rejoin (same polls as training);
  2. admit — against a *pinned* ``TopologyView``, every legion fills its
     members' free window slots from its :class:`LegionQueue`. Batch
     composition is deadline-aware: once SLOs are present the queue yields
     by slack (earliest-deadline-first over remaining service), not FIFO;
  3. faults land — injected ground truth arrives *after* admission, so a
     dying node takes its in-flight window with it; the sim clock ticks;
  4. execute — every busy live node advances each in-flight request one
     phase tick (prefill first, then decode — accounted separately in
     :class:`ServeMetrics`); requests whose ticks run out complete through
     ``work_fn`` (dedup guard: a request id completes at most once);
  5. drain — the result gather is one interposed call on the MPI facade
     (``repro.mpi.Comm.gather``) among the busy nodes: it traps the lost
     nodes' PROC_FAILED, runs detect → notice → agree → plan → apply, and
     the engine's pipeline listener *migrates* every verdict node's
     in-flight requests — a request that died mid-decode keeps its decode
     progress (the KV cache moves with it) and re-enters a queue with only
     the remaining ticks to serve, instead of restarting from prefill.

Admission control (``LegioPolicy.serve_admission``) guards the door: when
a request's SLO deadline is already infeasible against its target legion's
backlog and live capacity, it is shed (or parked) *before* it enters a
queue — backpressure applies before queues blow past deadline
feasibility, never after.

The lock-step loop survives as the measurable baseline
(``ServeEngine(..., continuous=False)``): one batch per node per round,
and the round's simulated duration stretches to the slowest in-flight
batch — the synchronous-drain cost the load-curve benchmark quantifies.

Invariants (asserted by tests/test_serve.py and the chaos harness):

  * **at-least-once** — a request is never lost: it is in exactly one of
    {a legion queue, a node's in-flight window, the completed map,
    metrics.parked, metrics.abandoned, metrics.shed} at every tick
    boundary;
  * **exactly-once completion** — the dedup guard keys on the request id;
    redeliveries (and migrated decode states) of an already-completed
    request are suppressed, so the client observes exactly one completion
    per id;
  * **no stall on healthy legions** — a legion with backlog and a free
    window slot admits every tick, including ticks where another legion's
    repair is in flight (``ServeMetrics.starved_rounds() == 0``).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core.executor import VirtualCluster
from repro.core.types import FaultSource, RecoveryAction
from repro.mpi import Session
from repro.serve.batcher import MicroBatcher
from repro.serve.metrics import CompletionRecord, ServeMetrics
from repro.serve.queue import LegionQueue, Request
from repro.serve.router import RequestRouter
from repro.serve.traffic import Arrival

# work_fn(node, batch, step) -> {rid: result}
WorkFn = Callable[[int, list[Request], int], dict[int, Any]]

RECOVERY_PRESETS = ("shrink", "substitute", "nonblocking", "overlap",
                    "adaptive")


def recovery_preset(name: str, *, spare_fraction: float = 0.25) -> dict:
    """Canonical ``LegioPolicy`` overrides for the serving recovery setups —
    the CLI (launch/serve.py), the benchmark (serve_latency), and the tests
    share this single source instead of drifting copies. ``overlap`` is
    shrink with background (revoke-then-repair) windows: a torn scope's
    repair happens concurrently on the sim clock while healthy legions
    keep serving — continuous batching never parks their slots on a
    remote scope's repair. ``adaptive`` scores shrink / substitute /
    nonblocking per fault from the live cost models (CostModelStrategy)
    and keeps background windows available to whichever mode wins."""
    presets = {
        "shrink": dict(recovery_mode="shrink"),
        "substitute": dict(recovery_mode="substitute_then_shrink",
                           spare_fraction=spare_fraction),
        "nonblocking": dict(recovery_mode="substitute_then_shrink",
                            spare_fraction=spare_fraction,
                            nonblocking_substitution=True),
        "overlap": dict(recovery_mode="shrink", repair_overlap=True),
        "adaptive": dict(recovery_mode="adaptive",
                         spare_fraction=spare_fraction,
                         repair_overlap=True),
    }
    return presets[name]


@dataclass
class _Slot:
    """One in-flight micro-batch occupying one window slot of a node."""

    requests: list[Request]


@dataclass
class RoundReport:
    """One serving tick, surfaced the way StepReport surfaces a step."""

    step: int
    dispatched: dict[int, int]               # node -> requests admitted
    completed_now: int
    requeued_now: int
    actions: tuple[RecoveryAction, ...] = ()
    respawned: tuple[int, ...] = ()
    expanded: tuple[tuple[int, int], ...] = ()
    backlog: int = 0
    inflight: int = 0
    sim_seconds: float = 0.0                 # deterministic round duration
    wall_seconds: float = 0.0                # perf_counter, humans only


@dataclass
class ServeReport:
    """Campaign summary returned by :meth:`ServeEngine.serve`."""

    rounds: int
    submitted: int
    completed: int
    metrics_summary: dict = field(default_factory=dict)
    survivors: int = 0
    repairs: int = 0


class ServeEngine:
    """Routes, admits, batches, executes, and redelivers transparently."""

    def __init__(
        self,
        cluster: "VirtualCluster | Session",
        work_fn: WorkFn,
        *,
        microbatch: int | None = None,
        window: int | None = None,
        continuous: bool = True,
        requeue: bool = True,
        observe_stragglers: bool = True,
    ):
        # all fault plumbing goes through the MPI facade; a driver may hand
        # in its Session directly (launch/serve.py) or a bare cluster
        if isinstance(cluster, Session):
            self.session = cluster
            cluster = cluster.cluster
        else:
            self.session = Session.adopt(cluster)
        self._comm = self.session.world
        self.cluster = cluster
        self.work_fn = work_fn
        self.requeue = requeue
        self.continuous = continuous
        # lock-step is the one-batch-per-node barrier baseline: the window
        # is meaningless there, the whole cluster drains before re-dispatch
        self.window = max(window or cluster.policy.serve_window, 1) \
            if continuous else 1
        # wall-clock work latency feeds the straggler detector only when the
        # caller says it is trustworthy — a work_fn that jit-compiles on
        # batch-shape changes (launch/serve.py) would soft-fail healthy
        # nodes on compile noise
        self.observe_stragglers = observe_stragglers
        self.router = RequestRouter()
        self.batcher = MicroBatcher(
            microbatch or cluster.policy.serve_microbatch)
        self.metrics = ServeMetrics()
        self.completed: dict[int, Any] = {}      # rid -> result (write-once)
        self._slots: dict[int, list[_Slot]] = {}  # node -> in-flight window
        self._next_rid = 0
        self._submitted = 0
        self.round_count = 0
        cluster.pipeline.add_listener(self._on_recovery_action)

    # -- client surface ------------------------------------------------------

    def submit(self, payloads: "list[Any] | int") -> list[int]:
        """Enqueue new requests — a count of payload-less ones, arbitrary
        payloads, or :class:`~repro.serve.traffic.Arrival` specs (which
        carry service shape and SLO class). Admission control runs here:
        a request whose deadline is already infeasible is shed or parked
        at the door, never queued. Returns the assigned request ids —
        including shed ones (their outcome is in the metrics ledger)."""
        if isinstance(payloads, int):
            payloads = [None] * payloads
        cl = self.cluster
        now = cl.clock.sim_seconds
        default_slo = cl.policy.serve_slo_seconds
        rids = []
        reqs: list[Request] = []
        for item in payloads:
            req = Request(rid=self._next_rid, enqueue_step=self.round_count,
                          arrival_sim=now)
            if isinstance(item, Arrival):
                req.payload = item.payload
                req.user = item.user
                req.slo_class = item.slo_class
                req.prefill_ticks = item.prefill_ticks
                req.decode_ticks = item.decode_ticks
                if math.isfinite(item.slo_seconds) and item.slo_seconds > 0:
                    req.deadline_sim = now + item.slo_seconds
            else:
                req.payload = item
                if default_slo > 0:
                    req.deadline_sim = now + default_slo
            self._next_rid += 1
            self._submitted += 1
            rids.append(req.rid)
            reqs.append(req)
        self.router.reconcile(cl.topo.view())
        for req in reqs:
            self._admit_to_queue(req, now)
        return rids

    @property
    def pending(self) -> int:
        return self.router.backlog + sum(
            len(s.requests) for slots in self._slots.values() for s in slots)

    @property
    def _inflight(self) -> dict[int, list[Request]]:
        """node -> every request in its in-flight window (flattened).
        Kept as the accounting surface the invariant tests walk."""
        return {node: [r for s in slots for r in s.requests]
                for node, slots in self._slots.items() if slots}

    # -- admission control ---------------------------------------------------

    def _admit_to_queue(self, req: Request, now: float) -> bool:
        """Route one request, or shed/park it when its deadline is already
        infeasible against the target legion's backlog and live capacity.
        Returns True when the request entered a queue."""
        mode = self.cluster.policy.serve_admission
        if mode == "none" or not math.isfinite(req.deadline_sim):
            self.router.route(req)
            return True
        tick = self.cluster.policy.step_sim_seconds
        target = self.router.peek()
        wait = self._estimated_wait(target, tick)
        service = req.service_ticks_remaining * tick
        slack = self.cluster.policy.serve_admission_slack
        if now + wait + service + slack <= req.deadline_sim:
            self.router.route(req)
            return True
        ledger = self.metrics.shed if mode == "shed" else self.metrics.parked
        ledger.append(req.rid)
        return False

    def _estimated_wait(self, target: LegionQueue, tick: float) -> float:
        """Sim-seconds of queueing ahead of a new arrival on ``target``:
        the queued service ticks divided by the legion's live concurrency
        (members × window × microbatch requests advance per tick)."""
        cl = self.cluster
        members = next(
            (lg.members for lg in cl.topo.legions
             if lg.index == target.legion), [])
        live = sum(1 for n in members if n not in cl.failed)
        capacity = max(live * self.window * self.batcher.microbatch, 1)
        return target.pending_ticks / capacity * tick

    # -- fault plumbing ------------------------------------------------------

    def _on_recovery_action(self, action: RecoveryAction) -> None:
        """Pipeline listener: the repair for ``action.verdict`` has been
        applied — migrate every verdict node's in-flight requests. One
        topology snapshot covers the whole action (the repair already
        landed; nothing mutates between redeliveries)."""
        view = None
        for node in action.verdict:
            batch = self._pop_node(node)
            if batch and view is None:
                view = self.cluster.topo.view()
            for req in batch:
                self._redeliver(req, view, migrate=True)

    def _pop_node(self, node: int) -> list[Request]:
        """Remove and return every in-flight request of ``node``."""
        return [r for s in self._slots.pop(node, []) for r in s.requests]

    def _migrate(self, req: Request) -> None:
        """Decode-state migration: a request whose node died mid-decode
        keeps its decode progress (the KV cache travels to wherever it is
        redelivered); one that died mid-prefill has nothing to migrate and
        restarts. ``serve_migrate_decode=False`` restarts everything —
        the restart-from-prefill baseline the benchmark compares against."""
        preserved = (self.cluster.policy.serve_migrate_decode
                     and req.prefill_done >= req.prefill_ticks)
        if not preserved:
            req.prefill_done = 0
            req.decode_done = 0
            return
        req.migrations += 1
        self.metrics.migrations += 1
        self.metrics.decode_ticks_preserved += req.decode_done

    def _redeliver(self, req: Request, view=None, *,
                   migrate: bool = False) -> None:
        if req.rid in self.completed:
            # completed on a previous delivery — the dedup guard keeps the
            # at-least-once redelivery invisible to the client
            self.metrics.duplicates_suppressed += 1
            return
        if migrate:
            self._migrate(req)
        if not self.requeue:
            self.metrics.abandoned.append(req.rid)      # DROP semantics
            return
        cap = self.cluster.policy.serve_max_attempts
        if cap and req.attempts >= cap:
            self.metrics.parked.append(req.rid)
            return
        self.metrics.requeues += 1
        self.router.requeue(
            req, view if view is not None else self.cluster.topo.view())

    def _complete(self, req: Request, result: Any, step: int,
                  node: int) -> None:
        if req.rid in self.completed:
            self.metrics.duplicates_suppressed += 1
            return
        self.completed[req.rid] = result
        self.metrics.record_completion(CompletionRecord(
            rid=req.rid, enqueue_step=req.enqueue_step, complete_step=step,
            attempts=req.attempts, legion=req.legion if req.legion is not None
            else -1, node=node, arrival_sim=req.arrival_sim,
            complete_sim=self.cluster.clock.sim_seconds,
            slo_class=req.slo_class, deadline_sim=req.deadline_sim,
            migrated=req.migrations > 0))

    # -- one serving tick ----------------------------------------------------

    def run_round(self, step: int | None = None) -> RoundReport:
        cl = self.cluster
        step = self.round_count if step is None else step
        t_start = time.perf_counter()
        sim_start = cl.clock.sim_seconds

        # 1. boundary: elastic refills + warmed-up substitutes rejoin
        boundary = self.session.deliver(step)

        # 2. admit against a pinned snapshot — a repair can neither run
        #    nor tear the structure while windows are being filled
        dispatched_sizes = self._admit_phase(step)

        # 3. faults land mid-flight; the sim clock ticks
        self.session.inject(step)

        # 4. execute — live busy nodes advance/complete, dead ones keep
        #    their windows until the drain migrates them
        completed_before = len(self.completed)
        if self.continuous:
            self._tick_phase(step)
        else:
            self._lockstep_phase(step)
        for node in cl.live_nodes:
            cl.detector.beat(node, cl.clock.sim_seconds)

        # 5. the result gather, as one interposed facade call: the lost
        #    nodes' PROC_FAILED is trapped among the busy set, the crash
        #    channels drain, and the pipeline listener migrates verdict
        #    nodes' windows before the call returns
        requeues_before = self.metrics.requeues
        self._comm.gather(among=set(self._slots))
        self.session.poll((FaultSource.STRAGGLER,))
        actions = list(self.session.take_actions())
        # safety net: a dead node whose loss produced no verdict this round
        # (e.g. no surviving observer) still must not strand its window —
        # redeliver now; the heartbeat channel will confirm the node later
        stranded_view = None
        for node in [n for n in list(self._slots) if n in cl.failed]:
            batch = self._pop_node(node)
            if batch and stranded_view is None:
                stranded_view = cl.topo.view()
            for req in batch:
                self._redeliver(req, stranded_view, migrate=True)

        self.round_count = step + 1
        sim_elapsed = cl.clock.sim_seconds - sim_start
        wall = time.perf_counter() - t_start
        self.metrics.record_round(step, sim_elapsed, wall)
        return RoundReport(
            step=step,
            dispatched=dispatched_sizes,
            completed_now=len(self.completed) - completed_before,
            requeued_now=self.metrics.requeues - requeues_before,
            actions=tuple(actions),
            respawned=boundary.respawned,
            expanded=boundary.expanded,
            backlog=self.router.backlog,
            inflight=sum(len(b) for b in self._inflight.values()),
            sim_seconds=sim_elapsed,
            wall_seconds=wall,
        )

    # -- phases --------------------------------------------------------------

    def _admit_phase(self, step: int) -> dict[int, int]:
        """Fill every legion's free window slots from its queue — each
        legion independently, so one legion's depth (or repair) never gates
        another's admission. Returns node -> requests admitted."""
        cl = self.cluster
        now = cl.clock.sim_seconds
        tick = cl.policy.step_sim_seconds
        dispatched: dict[int, int] = {}
        busy = cl.repairing_participants()
        with cl.topo.pinned() as tv:
            self.router.reconcile(tv)
            for lg in tv.legions:
                # a member busy in a background repair window serves
                # nothing this round — but only ITS slots pause: healthy
                # legions (and this legion's other members) admit freely,
                # never parked on a remote scope's repair
                members = [n for n in lg.members
                           if n not in cl.failed and n not in busy]
                if not members:
                    continue
                queue = self.router.queue_of(lg.index)
                backlog_before = len(queue)
                free_slots = 0
                admitted = 0
                # fill one slot per member per pass, so admission spreads
                # across the legion instead of saturating the first member
                # (with window=1 this is exactly one batch per member, in
                # member order — the legacy dispatch)
                for _ in range(self.window):
                    for node in members:
                        if len(self._slots.get(node, [])) >= self.window:
                            continue
                        free_slots += 1
                        batch = self.batcher.form_one(
                            queue, now=now, tick_seconds=tick)
                        if not batch:
                            continue
                        for req in batch:
                            req.attempts += 1
                        self._slots.setdefault(node, []).append(
                            _Slot(requests=batch))
                        dispatched[node] = dispatched.get(node, 0) \
                            + len(batch)
                        admitted += len(batch)
                        self.metrics.record_dispatch(
                            step, lg.index, len(batch))
                if backlog_before and free_slots and not admitted:
                    self.metrics.record_starved(step, lg.index)
        return dispatched

    def _advance(self, req: Request) -> None:
        """One phase tick: prefill until done, then decode — each phase
        accounted separately."""
        if req.prefill_done < req.prefill_ticks:
            req.prefill_done += 1
            self.metrics.record_phase_tick("prefill")
        elif req.decode_done < req.decode_ticks:
            req.decode_done += 1
            self.metrics.record_phase_tick("decode")

    def _finish(self, node: int, ready: list[Request], step: int) -> None:
        """Requests whose service ticks ran out complete through work_fn;
        an id the work_fn drops is a delivery failure — it redelivers with
        its progress reset (the result never materialized), never records
        a completion the client didn't get."""
        cl = self.cluster
        t0 = time.perf_counter()
        results = self.work_fn(node, ready, step)
        if self.observe_stragglers:
            cl.straggler.observe(node, time.perf_counter() - t0)
        dropped_view = None
        for req in ready:
            if req.rid in results:
                self._complete(req, results[req.rid], step, node)
            else:
                req.prefill_done = 0
                req.decode_done = 0
                if dropped_view is None:
                    dropped_view = cl.topo.view()
                self._redeliver(req, dropped_view)

    def _tick_phase(self, step: int) -> None:
        """Continuous execution: every busy live node advances each of its
        in-flight requests one phase tick; finished requests complete and
        free their slot for next tick's admission."""
        cl = self.cluster
        busy = cl.repairing_participants()
        for node in sorted(self._slots):
            if node in cl.failed:
                continue        # dead mid-flight: the drain migrates it
            if node in busy:
                continue        # repairing: its batches stall, not migrate
            ready: list[Request] = []
            kept: list[_Slot] = []
            for slot in self._slots[node]:
                remaining = []
                for req in slot.requests:
                    if req.service_ticks_remaining > 0:
                        self._advance(req)
                    if req.service_ticks_remaining == 0:
                        ready.append(req)
                    else:
                        remaining.append(req)
                slot.requests = remaining
                if remaining:
                    kept.append(slot)
            if kept:
                self._slots[node] = kept
            else:
                del self._slots[node]
            if ready:
                self._finish(node, ready, step)

    def _lockstep_phase(self, step: int) -> None:
        """The barrier baseline: every in-flight batch runs to completion
        inside this round, and the round's simulated duration stretches to
        the slowest batch anywhere in the cluster — including one riding a
        node that just died (the survivors waited out the timeout). No
        partial progress exists at the fault, so a victim's requests
        restart from prefill; decode-state migration is a
        continuous-batching capability."""
        cl = self.cluster
        if cl.background:
            # a round barrier is all-hands: background repair windows
            # cannot ride through it — force-finish, charging the residual
            self.session.sync()
        max_ticks = max(
            (r.service_ticks_remaining
             for slots in self._slots.values()
             for s in slots for r in s.requests), default=0)
        if max_ticks > 1:
            # inject() already charged one tick; the barrier pays the rest
            cl.clock.charge((max_ticks - 1) * cl.policy.step_sim_seconds)
        for node in [n for n in sorted(self._slots) if n not in cl.failed]:
            batch = self._pop_node(node)
            for req in batch:
                self.metrics.record_phase_tick(
                    "prefill", req.prefill_ticks - req.prefill_done)
                self.metrics.record_phase_tick(
                    "decode", req.decode_ticks - req.decode_done)
                req.prefill_done = req.prefill_ticks
                req.decode_done = req.decode_ticks
            self._finish(node, batch, step)

    # -- campaign ------------------------------------------------------------

    def serve(self, max_rounds: int = 10_000) -> ServeReport:
        """Run rounds until every submitted request is completed (or parked/
        abandoned/shed), the cluster dies, or ``max_rounds`` is hit."""
        reports: list[RoundReport] = []
        while self.pending and self.cluster.live_nodes \
                and len(reports) < max_rounds:
            reports.append(self.run_round())
        return ServeReport(
            rounds=len(reports),
            submitted=self._submitted,
            completed=len(self.completed),
            metrics_summary=self.metrics.summary(max(len(reports), 1)),
            survivors=len(self.cluster.live_nodes),
            repairs=len(self.cluster.repairs),
        )
