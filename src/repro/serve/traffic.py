"""Open-loop traffic generation — seeded Poisson arrivals with diurnal and
burst profiles, per-request SLO classes, and a user population in the
millions.

"Open loop" means arrivals do not wait for the system: the generator emits
whatever the rate function says for a simulated-time window, regardless of
how deep the queues already are. That is the load model under which
admission control and continuous batching earn their keep — a lock-step
engine whose rounds stretch to the slowest in-flight batch accumulates
proportionally more arrivals per round, which is exactly the tail-latency
blowup the load-curve benchmark measures.

Everything is driven by one ``numpy`` Generator seeded at construction:
the same seed and the same sequence of :meth:`arrivals` windows produce a
byte-identical request stream, so benchmarks and the dispatch-determinism
property test can compare whole traces across runs.

    gen = TrafficGenerator(rate=40.0, seed=7, bursts=(Burst(20.0, 30.0, 3.0),))
    while serving:
        engine.submit(gen.arrivals(t_prev, t_now))   # sim-time window
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import numpy as np

__all__ = ["Arrival", "Burst", "SLOClass", "TrafficGenerator",
           "DEFAULT_SLO_CLASSES"]


@dataclass(frozen=True)
class SLOClass:
    """One request class: its deadline, traffic share, and service shape."""

    name: str
    slo_seconds: float              # deadline from arrival; inf = no SLO
    share: float                    # fraction of traffic
    decode_ticks: tuple[int, int]   # inclusive [lo, hi] decode length range
    prefill_ticks: int = 1


# interactive traffic is short and tight; batch is long and deadline-less —
# the spread is what makes slack scheduling and the phase split observable
DEFAULT_SLO_CLASSES = (
    SLOClass("interactive", slo_seconds=12.0, share=0.50,
             decode_ticks=(1, 2)),
    SLOClass("standard", slo_seconds=40.0, share=0.35,
             decode_ticks=(2, 6)),
    SLOClass("batch", slo_seconds=math.inf, share=0.15,
             decode_ticks=(8, 16)),
)


@dataclass(frozen=True)
class Burst:
    """A transient rate spike: multiply the base rate inside [start, end)."""

    start: float
    end: float
    multiplier: float


@dataclass(frozen=True)
class Arrival:
    """One generated request, ready for ``ServeEngine.submit``."""

    user: int
    slo_class: str
    slo_seconds: float
    prefill_ticks: int
    decode_ticks: int
    payload: Any = None


class TrafficGenerator:
    """Seeded open-loop arrival process over a simulated-seconds clock.

    ``rate`` is the mean arrivals per simulated second; the instantaneous
    rate is modulated by a diurnal sinusoid (``diurnal_amplitude`` around
    the mean, period ``diurnal_period`` seconds) and any active
    :class:`Burst` windows. Each arrival draws a user id from a
    ``n_users``-sized population (default two million simulated users) and
    an :class:`SLOClass` by traffic share, then a decode length uniform in
    the class's range.
    """

    def __init__(self, rate: float, *, seed: int = 0,
                 n_users: int = 2_000_000,
                 diurnal_amplitude: float = 0.0,
                 diurnal_period: float = 1440.0,
                 bursts: tuple[Burst, ...] = (),
                 classes: tuple[SLOClass, ...] = DEFAULT_SLO_CLASSES):
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        if not 0.0 <= diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1)")
        if abs(sum(c.share for c in classes) - 1.0) > 1e-6:
            raise ValueError("SLO class shares must sum to 1")
        self.rate = rate
        self.n_users = n_users
        self.diurnal_amplitude = diurnal_amplitude
        self.diurnal_period = diurnal_period
        self.bursts = tuple(bursts)
        self.classes = tuple(classes)
        self._shares = np.asarray([c.share for c in classes], dtype=float)
        self._shares = self._shares / self._shares.sum()
        self._rng = np.random.default_rng(seed)
        self.generated = 0

    # -- the rate function ---------------------------------------------------

    def rate_at(self, t: float) -> float:
        """Instantaneous arrivals-per-second at simulated time ``t``."""
        r = self.rate * (1.0 + self.diurnal_amplitude
                         * math.sin(2.0 * math.pi * t / self.diurnal_period))
        for b in self.bursts:
            if b.start <= t < b.end:
                r *= b.multiplier
        return r

    # -- generation ----------------------------------------------------------

    def arrivals(self, t0: float, t1: float) -> list[Arrival]:
        """All arrivals in the window (t0, t1] — Poisson with the window's
        midpoint rate as intensity. Call with consecutive windows to walk
        the whole campaign deterministically."""
        if t1 <= t0:
            return []
        lam = self.rate_at((t0 + t1) / 2.0) * (t1 - t0)
        n = int(self._rng.poisson(lam))
        if n == 0:
            return []
        users = self._rng.integers(0, self.n_users, size=n)
        picks = self._rng.choice(len(self.classes), size=n, p=self._shares)
        out = []
        for user, ci in zip(users, picks):
            cls = self.classes[int(ci)]
            lo, hi = cls.decode_ticks
            decode = int(self._rng.integers(lo, hi + 1))
            out.append(Arrival(
                user=int(user), slo_class=cls.name,
                slo_seconds=cls.slo_seconds,
                prefill_ticks=cls.prefill_ticks, decode_ticks=decode))
        self.generated += n
        return out
