"""RequestRouter — shards the request stream across legions via the
top-level masters.

Routing reads the topology the same way everything else in the runtime
does: through an epoch-stamped :class:`TopologyView` snapshot. Selection is
hierarchical, mirroring how traffic actually flows through the N-level
tree: a request first picks the least-loaded *top-level subtree* (a child
group of the root comm — the comms the top-level masters front), then the
least-loaded live legion inside it. For depth <= 2 every legion hangs off
the root directly, so this degenerates to the classic least-loaded-legion
policy unchanged. After a repair changes a ring, :meth:`reconcile` re-homes
the queues of legions that left it, so no request is ever stranded on a
structure that no longer exists.

Selection is fully deterministic: equal loads tie-break on the lowest
subtree index, then the lowest legion index, and never on dict iteration
order — two engines fed the same seeded request stream produce
byte-identical dispatch traces (property-tested in tests/test_serve.py).
"""
from __future__ import annotations

from repro.serve.queue import LegionQueue, Request


class RequestRouter:
    """Least-loaded sharding of requests over top-level subtrees, then the
    live legions within."""

    def __init__(self):
        self.queues: dict[int, LegionQueue] = {}
        self.rerouted: int = 0          # requests re-homed by reconcile()
        # legion index -> top-level subtree index (root comm child), from
        # the last reconciled snapshot
        self._subtree: dict[int, int] = {}

    # -- topology tracking ---------------------------------------------------

    def _live_legions(self, view) -> list[int]:
        return [lg.index for lg in view.legions if lg.members]

    def reconcile(self, view) -> list[Request]:
        """Sync queues with a topology snapshot. Queues for legions that
        left the ring are drained and their requests resubmitted; returns
        the re-homed requests (metrics count them)."""
        live = set(self._live_legions(view))
        self._subtree = {idx: view.subtree_of(idx) for idx in live}
        orphans: list[Request] = []
        for idx in [i for i in self.queues if i not in live]:
            orphans.extend(self.queues.pop(idx).drain())
        for idx in live:
            self.queues.setdefault(idx, LegionQueue(legion=idx))
        if orphans:
            self.rerouted += len(orphans)
            for req in orphans:
                self.route(req, front=True)
        return orphans

    # -- selection -----------------------------------------------------------

    def peek(self) -> LegionQueue:
        """The queue the *next* routed request would land in, without
        placing anything — admission control estimates feasibility against
        this target. Ties break (load, index) at both stages."""
        if not self.queues:
            raise RuntimeError("no live legions to route to")
        # stage 1: least-loaded top-level subtree (ties: lowest subtree idx)
        load: dict[int, int] = {}
        for idx, q in self.queues.items():
            sub = self._subtree.get(idx, idx)
            load[sub] = load.get(sub, 0) + len(q)
        best_sub = min(load, key=lambda s: (load[s], s))
        # stage 2: least-loaded legion inside it (ties: lowest legion idx)
        return min(
            (q for idx, q in self.queues.items()
             if self._subtree.get(idx, idx) == best_sub),
            key=lambda q: (len(q), q.legion))

    def route(self, req: Request, *, front: bool = False) -> LegionQueue:
        """Place one request on the current least-loaded target."""
        target = self.peek()
        (target.push_front if front else target.push)(req)
        return target

    # -- submission ----------------------------------------------------------

    def submit(self, requests: list[Request], view) -> None:
        """Shard new requests across the live legions, least-loaded first."""
        self.reconcile(view)
        for req in requests:
            self.route(req)

    def requeue(self, req: Request, view) -> None:
        """Redeliver a request whose node died mid-batch: front of the
        least-loaded *surviving* legion's queue (its old legion may be the
        one that just shrank — reconcile first)."""
        self.reconcile(view)
        self.route(req, front=True)

    # -- views ---------------------------------------------------------------

    @property
    def backlog(self) -> int:
        return sum(len(q) for q in self.queues.values())

    def queue_of(self, legion: int) -> LegionQueue:
        return self.queues[legion]
