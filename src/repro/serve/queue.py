"""Per-legion work queues — the unit of request ownership.

A request belongs to exactly one legion queue at a time (or to a node's
in-flight window, or to the completed map — never two of these at once; the
engine's accounting test walks every round asserting it). Queues are FIFO
with two exceptions:

  * a re-enqueued request (its node died mid-batch) goes to the *front*,
    so redelivery latency does not compound the fault latency;
  * when any queued request carries a deadline, :meth:`pop_batch` selects
    by SLO slack (earliest-deadline-first over remaining service) instead
    of pure arrival order — ties keep queue order, so the schedule is
    deterministic and deadline-less requests stay FIFO among themselves.

Requests also carry their continuous-batching service spec: a prefill
phase (``prefill_ticks``) followed by a decode phase (``decode_ticks``),
each advanced one simulated tick at a time by the engine. Progress
(``prefill_done``/``decode_done``) travels *with* the request, which is
what makes decode-state migration possible — a request whose node died
mid-decode re-enters a queue with its decode progress intact and only the
remaining ticks left to serve.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable


@dataclass
class Request:
    """One client request flowing through the serve subsystem.

    ``rid`` is the client-visible identity the dedup guard keys on;
    ``attempts`` counts deliveries (1 = never touched a failed node).
    The default service spec (1 prefill tick, 0 decode ticks) completes in
    the round it is dispatched — the pre-continuous-batching behavior.
    """

    rid: int
    payload: Any = None
    enqueue_step: int = 0
    attempts: int = 0
    legion: int | None = None      # current owning legion (router-assigned)
    # service spec (ticks of LegioPolicy.step_sim_seconds each)
    prefill_ticks: int = 1
    decode_ticks: int = 0
    # SLO surface (admission control + slack scheduling read these)
    slo_class: str = "standard"
    deadline_sim: float = math.inf
    user: int = -1
    arrival_sim: float = 0.0
    # phase progress — migrates across redeliveries when the node dies
    # mid-decode (serve_migrate_decode)
    prefill_done: int = 0
    decode_done: int = 0
    migrations: int = 0

    @property
    def service_ticks_remaining(self) -> int:
        return (self.prefill_ticks - self.prefill_done) \
            + (self.decode_ticks - self.decode_done)

    def slack(self, now: float, tick_seconds: float) -> float:
        """Seconds to spare if served immediately; infinite without an SLO."""
        return self.deadline_sim - now \
            - self.service_ticks_remaining * tick_seconds


@dataclass
class LegionQueue:
    """Request queue owned by one legion: FIFO, front-push redelivery, and
    slack-ordered batch forming once deadlines are present."""

    legion: int
    _q: deque = field(default_factory=deque)
    _deadlined: int = 0         # queued requests carrying a finite deadline
    _ticks: int = 0             # queued service ticks (admission feasibility)

    def push(self, req: Request) -> None:
        req.legion = self.legion
        self._q.append(req)
        self._account(req, +1)

    def push_front(self, req: Request) -> None:
        """Redelivery path: re-enqueued requests skip the line."""
        req.legion = self.legion
        self._q.appendleft(req)
        self._account(req, +1)

    def _account(self, req: Request, sign: int) -> None:
        if math.isfinite(req.deadline_sim):
            self._deadlined += sign
        self._ticks += sign * req.service_ticks_remaining

    @property
    def has_deadlines(self) -> bool:
        return self._deadlined > 0

    @property
    def pending_ticks(self) -> int:
        """Total service ticks queued — the admission-control backlog."""
        return self._ticks

    def pop_batch(self, n: int,
                  key: "Callable[[Request], float] | None" = None
                  ) -> list[Request]:
        """Take up to ``n`` requests. FIFO without ``key``; with ``key``
        (SLO slack), the ``n`` smallest-key requests leave first — ties
        keep queue order, so front-pushed redeliveries retain priority
        among equals and the schedule is byte-identical across runs."""
        if key is not None and len(self._q) > 1:
            order = sorted(range(len(self._q)),
                           key=lambda i: (key(self._q[i]), i))[:n]
            take = [self._q[i] for i in order]
            picked = set(order)
            self._q = deque(r for i, r in enumerate(self._q)
                            if i not in picked)
        else:
            take = []
            while self._q and len(take) < n:
                take.append(self._q.popleft())
        for req in take:
            self._account(req, -1)
        return take

    def drain(self) -> list[Request]:
        """Empty the queue (legion left the ring — requests re-route)."""
        out = list(self._q)
        self._q.clear()
        self._deadlined = 0
        self._ticks = 0
        return out

    def __len__(self) -> int:
        return len(self._q)
