"""Per-legion work queues — the unit of request ownership.

A request belongs to exactly one legion queue at a time (or to a node's
in-flight set, or to the completed map — never two of these at once; the
engine's accounting test walks every round asserting it). Queues are FIFO
with one exception: a re-enqueued request (its node died mid-batch) goes to
the *front*, so redelivery latency does not compound the fault latency.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any


@dataclass
class Request:
    """One client request flowing through the serve subsystem.

    ``rid`` is the client-visible identity the dedup guard keys on;
    ``attempts`` counts deliveries (1 = never touched a failed node).
    """

    rid: int
    payload: Any = None
    enqueue_step: int = 0
    attempts: int = 0
    legion: int | None = None      # current owning legion (router-assigned)


@dataclass
class LegionQueue:
    """FIFO request queue owned by one legion."""

    legion: int
    _q: deque = field(default_factory=deque)

    def push(self, req: Request) -> None:
        req.legion = self.legion
        self._q.append(req)

    def push_front(self, req: Request) -> None:
        """Redelivery path: re-enqueued requests skip the line."""
        req.legion = self.legion
        self._q.appendleft(req)

    def pop_batch(self, n: int) -> list[Request]:
        take = []
        while self._q and len(take) < n:
            take.append(self._q.popleft())
        return take

    def drain(self) -> list[Request]:
        """Empty the queue (legion left the ring — requests re-route)."""
        out = list(self._q)
        self._q.clear()
        return out

    def __len__(self) -> int:
        return len(self._q)
