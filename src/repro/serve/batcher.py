"""Micro-batcher — turns a legion queue into per-node dispatch batches.

Batch size comes from ``LegioPolicy.serve_microbatch``: each free window
slot admits up to that many requests. Smaller batches bound the blast
radius of a fault (at most ``serve_microbatch`` requests ride on any one
slot) at the cost of more dispatch rounds; the serve_latency benchmark
sweeps the trade.

Batch *composition* is deadline-aware: once any queued request carries an
SLO deadline, :meth:`form_one` hands the queue a slack key (deadline minus
now minus remaining service) and the queue yields the tightest requests
first — earliest-deadline-first over remaining work, instead of pure FIFO.
Deadline-less queues stay strictly FIFO, so the legacy dispatch order is
byte-identical to the pre-SLO engine.
"""
from __future__ import annotations

from repro.serve.queue import LegionQueue, Request


class MicroBatcher:
    """Stateless batch former: policy-sized, slack-ordered queue slices."""

    def __init__(self, microbatch: int):
        if microbatch <= 0:
            raise ValueError(f"microbatch must be positive, got {microbatch}")
        self.microbatch = microbatch

    def form_one(self, queue: LegionQueue, *, now: float = 0.0,
                 tick_seconds: float = 1.0) -> list[Request]:
        """One micro-batch for one free window slot. SLO slack orders the
        pick when the queue holds any deadlined request; otherwise FIFO."""
        key = None
        if queue.has_deadlines:
            key = lambda r: r.slack(now, tick_seconds)    # noqa: E731
        return queue.pop_batch(self.microbatch, key=key)

    def form(self, queue: LegionQueue,
             members: list[int]) -> dict[int, list[Request]]:
        """One batch per live member, in member order — the lock-step
        baseline's dispatch (and the legacy surface): the queue keeps
        anything beyond this round's capacity."""
        batches: dict[int, list[Request]] = {}
        for node in members:
            batch = queue.pop_batch(self.microbatch)
            if not batch:
                break
            batches[node] = batch
        return batches
