"""Micro-batcher — turns a legion queue into per-node dispatch batches.

Batch size comes from ``LegioPolicy.serve_microbatch``: each live member of
a legion drains up to that many requests per round. Smaller batches bound
the blast radius of a fault (at most ``serve_microbatch`` requests ride on
any one node) at the cost of more dispatch rounds; the serve_latency
benchmark sweeps the trade.
"""
from __future__ import annotations

from repro.serve.queue import LegionQueue, Request


class MicroBatcher:
    """Stateless batch former: policy-sized slices of a legion queue."""

    def __init__(self, microbatch: int):
        if microbatch <= 0:
            raise ValueError(f"microbatch must be positive, got {microbatch}")
        self.microbatch = microbatch

    def form(self, queue: LegionQueue,
             members: list[int]) -> dict[int, list[Request]]:
        """One round of batches for a legion: up to ``microbatch`` requests
        per live member, in member order — the queue keeps anything beyond
        this round's capacity."""
        batches: dict[int, list[Request]] = {}
        for node in members:
            batch = queue.pop_batch(self.microbatch)
            if not batch:
                break
            batches[node] = batch
        return batches
